"""Quickstart: build a model from the registry, train a few steps, save a
checkpoint, restore it, and generate greedily.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as CK
from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticPipeline
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.adamw import OptConfig
from repro.training.step import make_train_step


def main():
    cfg = get_smoke("llama3.2-1b")              # any of the 10 arch ids
    shape = ShapeConfig("quick", seq_len=64, global_batch=8, kind="train")
    pipeline = SyntheticPipeline(cfg, shape)
    opt = OptConfig(lr=3e-3, warmup_steps=2, total_steps=40)

    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    state = adamw.init_state(params, opt)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))

    print(f"training {cfg.name} (smoke): "
          f"{sum(x.size for x in jax.tree.leaves(params))/1e3:.0f}k params")
    for step in range(40):
        batch = {k: jnp.asarray(v) for k, v in pipeline.batch_at(step).items()}
        state, m = step_fn(state, batch)
        if step % 10 == 0:
            print(f"  step {step:3d} loss={float(m['loss']):.4f}")

    with tempfile.TemporaryDirectory() as d:
        CK.save(state, d, int(state.step))
        restored, at = CK.restore(state, d)
        print(f"checkpoint roundtrip at step {at}: ok")

    # greedy generation with the KV cache
    prompt = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, 8)), jnp.int32)
    cache, _ = T.init_cache(cfg, 1, 8 + 12)
    lg, cache = T.prefill(cfg, state.params, prompt, cache)
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    out = []
    for i in range(12):
        out.append(int(tok[0, 0]))
        lg, cache = T.decode_step(cfg, state.params, tok, cache,
                                  jnp.int32(8 + i))
        tok = jnp.argmax(lg[:, -1], -1)[:, None]
    print(f"generated: {out}")


if __name__ == "__main__":
    main()
