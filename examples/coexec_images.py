"""Co-execute the paper's Gaussian-blur workload across three heterogeneous
device groups with every scheduler; verify exactness and show the paper's
metrics (balance / speedup / efficiency) on the real threaded Engine.

    PYTHONPATH=src python examples/coexec_images.py
"""
import numpy as np

from repro.core import metrics as M
from repro.core import programs as P
from repro.core.device import DeviceGroup
from repro.core.runtime import Engine


def main():
    kw = dict(h=512, w=256)
    ref = P.reference_output("gaussian", **kw)
    print("single-device reference computed; co-executing with 3 groups\n")
    print(f"{'scheduler':14s}{'roi_ms':>9s}{'binary_ms':>11s}"
          f"{'packets':>9s}{'balance':>9s}{'exact':>7s}")
    for sched in ("static", "static_rev", "dynamic", "hguided",
                  "hguided_opt"):
        devs = [DeviceGroup("cpu", throttle=4.0),
                DeviceGroup("igpu", throttle=2.0),
                DeviceGroup("gpu", throttle=1.0)]
        prog = P.PROGRAMS["gaussian"](**kw)
        eng = Engine(prog, devs, scheduler=sched,
                     scheduler_kwargs={"n_packets": 16}
                     if sched == "dynamic" else {})
        res = eng.run()
        exact = np.allclose(res.output, ref, rtol=1e-5, atol=1e-5)
        print(f"{sched:14s}{res.total_time*1e3:9.1f}"
              f"{res.binary_time*1e3:11.1f}{len(res.packets):9d}"
              f"{M.balance(res):9.3f}{str(exact):>7s}")

    # fault tolerance: the fastest group dies mid-run
    devs = [DeviceGroup("cpu", throttle=4.0),
            DeviceGroup("igpu", throttle=2.0),
            DeviceGroup("gpu", throttle=1.0, fail_after=1)]
    eng = Engine(P.PROGRAMS["gaussian"](**kw), devs, scheduler="hguided_opt")
    res = eng.run()
    exact = np.allclose(res.output, ref, rtol=1e-5, atol=1e-5)
    print(f"\nwith gpu failure mid-run: output exact={exact} "
          f"(packets requeued to survivors)")


if __name__ == "__main__":
    main()
