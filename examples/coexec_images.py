"""Co-execute the paper's Gaussian-blur workload across three heterogeneous
device groups through the tiered API: Tier-1 ``coexec`` for the scheduler
comparison, Tier-2 ``EngineSession`` for async submits that amortize init
cost, and the offload modes (BINARY one-shots vs warm ROI re-offloads of a
registered 2-D workload); verify exactness and show the paper's metrics
(balance / speedup / efficiency proxies) on the real threaded engine.

    PYTHONPATH=src python examples/coexec_images.py
"""
import numpy as np

from repro.api import EngineSession, OffloadMode, Region, coexec
from repro.core import metrics as M
from repro.core import programs as P
from repro.core.device import DeviceGroup


def devices3():
    return [DeviceGroup("cpu", throttle=4.0),
            DeviceGroup("igpu", throttle=2.0),
            DeviceGroup("gpu", throttle=1.0)]


def main():
    kw = dict(h=512, w=256)
    ref = P.reference_output("gaussian", **kw)
    print("single-device reference computed; co-executing with 3 groups\n")
    print(f"{'scheduler':14s}{'roi_ms':>9s}{'binary_ms':>11s}"
          f"{'packets':>9s}{'balance':>9s}{'exact':>7s}")
    for sched in ("static", "static_rev", "dynamic", "hguided",
                  "hguided_opt"):
        prog = P.PROGRAMS["gaussian"](**kw)
        res = coexec(prog, devices3(), scheduler=sched,
                     scheduler_kwargs={"n_packets": 16}
                     if sched == "dynamic" else {})
        exact = np.allclose(res.output, ref, rtol=1e-5, atol=1e-5)
        print(f"{sched:14s}{res.total_time*1e3:9.1f}"
              f"{res.binary_time*1e3:11.1f}{len(res.packets):9d}"
              f"{M.balance(res):9.3f}{str(exact):>7s}")

    # Tier-2: one session, many submits — executables are cached, so the
    # (emulated 131 ms/device) init cost is paid once; RunHandles let the
    # caller overlap its own work with in-flight runs
    print("\nEngineSession: 3 async submits of the same program "
          "(init cost paid once)")
    with EngineSession(devices3(), init_cost_s=0.131) as session:
        prog = P.PROGRAMS["gaussian"](**kw)
        handles = [session.submit(prog) for _ in range(3)]
        for i, h in enumerate(handles):            # overlap prep with runs
            res = h.result()
            exact = np.allclose(res.output, ref, rtol=1e-5, atol=1e-5)
            print(f"  submit {i}: binary={res.binary_time*1e3:7.1f}ms "
                  f"exact={exact}")
        print(f"  executable builds (init payments): "
              f"{session.init_payments} (= 3 devices, not 9)")

    # Offload modes: register the 2-D image workload once (init paid at
    # registration), then re-offload a centered ROI repeatedly — the
    # paper's ROI-based offloading vs self-contained BINARY one-shots
    print("\nOffload modes on the 2-D NDRange workload (256x256 blur):")
    prog2d = P.PROGRAMS["gaussian2d"](h=256, w=256)
    ref2d = P.reference_output("gaussian2d", h=256, w=256)
    roi = Region.rect(128, 128, lws=(32, 32), offset=(64, 64))
    # fixed equal-chunk carving pins the packet (tile) shapes so repeated
    # offloads re-launch the same compiled executables
    skw = dict(scheduler="dynamic", scheduler_kwargs={"n_packets": 4})
    with EngineSession(devices3(), init_cost_s=0.131) as session:
        session.register_workload(prog2d)
        session.submit(prog2d, region=roi, mode=OffloadMode.ROI,
                       **skw).result()                   # pin tile shapes
        warm = session.submit(prog2d, region=roi,
                              mode=OffloadMode.ROI, **skw).result()
        session.unregister_workload("gaussian2d")    # BINARY = standalone
        cold = session.submit(prog2d, region=roi,
                              mode=OffloadMode.BINARY, **skw).result()
    exact = np.allclose(warm.output, ref2d[64:192, 64:192],
                        rtol=1e-5, atol=1e-5)
    for tag, r in (("ROI (warm)", warm), ("BINARY", cold)):
        p = r.phases
        print(f"  {tag:11s} init={p.init_s*1e3:7.1f}ms "
              f"roi={p.roi_s*1e3:7.1f}ms teardown={p.teardown_s*1e3:5.1f}ms "
              f"total={p.binary*1e3:7.1f}ms")
    print(f"  ROI output == full-blur slice: {exact}")

    # fault tolerance: the fastest group dies mid-run; its packet is
    # requeued (same seq, retried=True) and survivors absorb the work
    devs = devices3()
    devs[2].fail_after = 1
    res = coexec(P.PROGRAMS["gaussian"](**kw), devs)
    exact = np.allclose(res.output, ref, rtol=1e-5, atol=1e-5)
    print(f"\nwith gpu failure mid-run: output exact={exact} "
          f"({res.retries} packet(s) requeued to survivors)")


if __name__ == "__main__":
    main()
