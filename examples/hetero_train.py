"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps with heterogeneity-aware co-execution (3 unequal device
groups), mid-run failure injection, elastic scale-up, checkpoint/restart.

    PYTHONPATH=src python examples/hetero_train.py            # full (~100M)
    PYTHONPATH=src python examples/hetero_train.py --small    # CI-sized
"""
import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro.ckpt import checkpoint as CK
from repro.configs import get_config
from repro.configs.base import ShapeConfig, reduce_config
from repro.core.device import DeviceGroup
from repro.core.hetero_dp import HeteroDPTrainer
from repro.data.pipeline import SyntheticPipeline
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.adamw import OptConfig


def model_100m():
    base = get_config("llama3.2-1b")
    # ~100M params: 8L, d=512, 8 heads, vocab 32768
    return dataclasses.replace(
        base, name="llama-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
        dtype="float32", tie_embeddings=True, attn_chunk=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.small:
        cfg = reduce_config(get_config("llama3.2-1b"))
        shape = ShapeConfig("ht", seq_len=64, global_batch=16, kind="train")
        steps = args.steps or 12
    else:
        cfg = model_100m()
        shape = ShapeConfig("ht", seq_len=256, global_batch=16, kind="train")
        steps = args.steps or 300

    pipeline = SyntheticPipeline(cfg, shape)
    opt = OptConfig(lr=1e-3, warmup_steps=max(steps // 20, 1),
                    total_steps=steps)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    total, _ = T.param_count(cfg)
    state = adamw.init_state(params, opt)
    print(f"model {cfg.name}: {total/1e6:.1f}M params; "
          f"{shape.global_batch}x{shape.seq_len} tokens/step; {steps} steps")

    # heterogeneous groups: 'fast' pod slice, mid slice, degraded host —
    # the degraded one will also FAIL mid-training
    groups = [DeviceGroup("fast", throttle=1.0),
              DeviceGroup("mid", throttle=2.0),
              DeviceGroup("degraded", throttle=4.0,
                          fail_after=max(2 * steps, 6))]
    trainer = HeteroDPTrainer(cfg, opt, shape, groups, pipeline, lws=2)

    ckdir = tempfile.mkdtemp(prefix="hetero_ck_")
    ck = CK.AsyncCheckpointer(ckdir, keep=2)
    losses = []
    for step in range(steps):
        state, rep = trainer.step(state, step)
        losses.append(rep.loss)
        if step == steps // 3:
            # elastic scale-up mid-run
            trainer.add_device(DeviceGroup("joined", throttle=1.5))
            print(f"  [elastic] group 'joined' added at step {step}")
        if step % max(steps // 10, 1) == 0:
            rows = " ".join(f"{k}:{v}" for k, v in rep.device_rows.items())
            print(f"step {step:4d} loss={rep.loss:.4f} "
                  f"t={rep.step_time_s*1e3:.0f}ms balance={rep.balance:.2f} "
                  f"[{rows}]" + (" FAILURES!" if rep.failures else ""))
        if step and step % max(steps // 4, 1) == 0:
            ck.save(state, step)
    ck.save(state, steps)
    ck.wait()

    # restart from the checkpoint and take one more step (restart proof)
    restored, at = CK.restore(state, ckdir)
    restored = jax.tree.map(jax.numpy.asarray, restored)
    state2, rep = trainer.step(restored, steps)
    trainer.close()
    print(f"\nrestart from step {at}: next loss {rep.loss:.4f}")
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
