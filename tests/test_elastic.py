"""Elastic membership under load: ``add_device`` / ``remove_device``
interleaved with in-flight submits.

The session contract these tests lock:

  (a) membership edits NEVER touch a run already dispatched — the device
      list is snapshotted at dispatch, so a mid-flight join/leave changes
      neither the packet cover nor a bit of the output;
  (b) the NEXT submit sees the edited fleet (new groups get packets,
      removed groups get none);
  (c) degenerate edits fail loudly: duplicate joins raise, and a fleet
      emptied of devices refuses new work instead of hanging.

The threaded fleet tier rides the same hooks: ReplicaWorker.activate /
deactivate and a FleetServer round-trip with a standby worker.
"""
import threading

import numpy as np
import pytest

from repro.api import EngineSession
from repro.core.device import DeviceGroup
from repro.core.runtime import Program

WIDTH = 8


def _program(name, G, lws=4, *, started=None, release=None, seed=0):
    """Rows of a seeded random matrix; optionally gate the FIRST packet
    on ``release`` (set ``started`` when execution begins) so the main
    thread can edit membership while the run is provably in flight."""
    base = np.random.default_rng(seed).random((G, WIDTH), dtype=np.float32)

    def build(dev):
        def run(offset, size):
            if started is not None:
                started.set()
            if release is not None:
                assert release.wait(timeout=30.0)
            return base[offset:offset + size]
        return run

    prog = Program(name=name, total_work=G, lws=lws, build=build,
                   out_rows_per_wg=1, out_cols=WIDTH,
                   out_dtype=np.float32)
    return prog, base


def assert_exact_cover(packets, G):
    spans = sorted((p.offset, p.offset + p.size) for p in packets)
    cursor = 0
    for a, b in spans:
        assert a == cursor, f"gap/overlap at {a} (expected {cursor})"
        cursor = b
    assert cursor == G


def _devices(n):
    return [DeviceGroup(f"d{i}") for i in range(n)]


# ------------------------------------------------------ membership edits

def test_duplicate_add_raises():
    with EngineSession(_devices(2), name="elastic-dup") as s:
        with pytest.raises(ValueError, match="already in session"):
            s.add_device(DeviceGroup("d1"))
        assert [d.name for d in s.devices] == ["d0", "d1"]


def test_remove_all_devices_rejects_new_work():
    with EngineSession(_devices(2), name="elastic-empty") as s:
        s.remove_device("d0")
        s.remove_device("d1")
        assert s.devices == []
        prog, _ = _program("orphan", 16)
        h = s.submit(prog, cache=False)
        with pytest.raises(RuntimeError, match="no live devices"):
            h.result(timeout=30)


def test_remove_purges_device_caches():
    with EngineSession(_devices(2), name="elastic-purge") as s:
        prog, base = _program("warm", 16)
        res = s.submit(prog, cache=True).result(timeout=30)
        np.testing.assert_array_equal(res.output, base)
        assert any(k[1] == "d1" for k in s.executables)
        s.remove_device("d1")
        assert not any(k[1] == "d1" for k in s.executables)
        assert not any(k[1] == "d1" for k in s.buffer_registry)


# ------------------------------------------- edits while a run is in flight

def test_add_device_midflight_uses_dispatch_snapshot():
    started, release = threading.Event(), threading.Event()
    with EngineSession(_devices(2), scheduler="static",
                       name="elastic-add") as s:
        prog, base = _program("inflight", 32, started=started,
                              release=release)
        h = s.submit(prog, cache=False)
        assert started.wait(timeout=30.0)    # provably mid-run
        s.add_device(DeviceGroup("late"))
        release.set()
        res = h.result(timeout=60)
        # (a) the in-flight run is untouched by the join
        assert len(res.device_busy) == 2
        assert_exact_cover(res.packets, 32)
        np.testing.assert_array_equal(res.output, base)
        # (b) the next submit runs on the grown fleet, newcomer included
        # (equal powers: the static carve gives a never-measured device
        # nothing by default)
        prog2, base2 = _program("after", 32, seed=1)
        res2 = s.submit(prog2, cache=False,
                        powers=[1.0, 1.0, 1.0]).result(timeout=60)
        assert len(res2.device_busy) == 3
        assert 2 in {p.device for p in res2.packets}
        assert_exact_cover(res2.packets, 32)
        np.testing.assert_array_equal(res2.output, base2)


def test_remove_device_midflight_run_unaffected():
    started, release = threading.Event(), threading.Event()
    with EngineSession(_devices(3), scheduler="static",
                       name="elastic-rm") as s:
        prog, base = _program("inflight", 48, started=started,
                              release=release)
        h = s.submit(prog, cache=False)
        assert started.wait(timeout=30.0)
        s.remove_device("d2")                # leave mid-run
        release.set()
        res = h.result(timeout=60)
        # the dispatched snapshot kept all three: full cover, exact output
        assert len(res.device_busy) == 3
        assert_exact_cover(res.packets, 48)
        np.testing.assert_array_equal(res.output, base)
        # new work runs on the shrunk fleet only
        prog2, base2 = _program("after", 48, seed=2)
        res2 = s.submit(prog2, cache=False).result(timeout=60)
        assert len(res2.device_busy) == 2
        assert {p.device for p in res2.packets} <= {0, 1}
        assert_exact_cover(res2.packets, 48)
        np.testing.assert_array_equal(res2.output, base2)


def test_membership_churn_across_dag_chain():
    """A dependency chain whose feed hooks join/leave devices between
    stages: every stage still tiles exactly and matches its oracle, and
    each stage's dispatch snapshot reflects the membership at ITS start."""
    edits = {1: lambda s: s.add_device(DeviceGroup("x0")),
             2: lambda s: s.remove_device("d1"),
             3: lambda s: s.add_device(DeviceGroup("x1"))}
    expected_fleet = {0: 2, 1: 3, 2: 2, 3: 3}
    with EngineSession(_devices(2), scheduler="static",
                       name="elastic-dag") as s:
        progs, handles = [], []
        for i in range(4):
            prog, base = _program(f"n{i}", 32, seed=10 + i)
            progs.append((prog, base))
            deps = [handles[-1]] if handles else []
            edit = edits.get(i)
            feed = (lambda _deps, e=edit: e(s)) if edit else None
            handles.append(s.submit(prog, deps=deps, feed=feed,
                                    cache=False))
        results = [h.result(timeout=120) for h in handles]
    for i, ((prog, base), res) in enumerate(zip(progs, results)):
        assert len(res.device_busy) == expected_fleet[i], f"stage {i}"
        assert_exact_cover(res.packets, 32)
        np.testing.assert_array_equal(res.output, base)


def test_concurrent_submits_straddle_an_edit():
    """Two overlapping in-flight runs and an edit between their
    dispatches: each run keeps its own snapshot."""
    s1, r1 = threading.Event(), threading.Event()
    s2, r2 = threading.Event(), threading.Event()
    with EngineSession(_devices(2), scheduler="static", max_inflight=2,
                       name="elastic-straddle") as s:
        p1, b1 = _program("first", 32, started=s1, release=r1)
        h1 = s.submit(p1, cache=False)
        assert s1.wait(timeout=30.0)
        s.add_device(DeviceGroup("mid"))     # lands between dispatches
        p2, b2 = _program("second", 32, started=s2, release=r2, seed=3)
        h2 = s.submit(p2, cache=False)
        assert s2.wait(timeout=30.0)
        r1.set()
        r2.set()
        res1, res2 = h1.result(timeout=60), h2.result(timeout=60)
    assert len(res1.device_busy) == 2 and len(res2.device_busy) == 3
    for res, base in ((res1, b1), (res2, b2)):
        assert_exact_cover(res.packets, 32)
        np.testing.assert_array_equal(res.output, base)


# --------------------------------------------------- threaded fleet tier

@pytest.fixture(scope="module")
def smoke_model():
    import jax
    from repro.configs import get_smoke
    from repro.models import transformer as T
    cfg = get_smoke("llama3.2-1b")
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (8, 16)).astype(np.int32)
    return cfg, params, prompts


def _worker(name, smoke_model, power=4.0):
    from repro.fleet import ReplicaWorker
    from repro.serve import Replica, ServerConfig
    cfg, params, _ = smoke_model
    scfg = ServerConfig(scheduler="hguided_deadline", lws=2, gen=2)
    return ReplicaWorker(name, [Replica(name + ".a", cfg, params)], scfg,
                         declared_power=power)


def test_worker_activate_deactivate_toggles_session(smoke_model):
    w = _worker("w0", smoke_model)
    try:
        assert [d.name for d in w.server.session.devices] == ["w0.a"]
        w.deactivate()
        assert w.server.session.devices == []
        w.activate()
        assert [d.name for d in w.server.session.devices] == ["w0.a"]
        with pytest.raises(ValueError, match="already in session"):
            w.server.session.add_device(DeviceGroup("w0.a"))
    finally:
        w.stop()


def test_fleet_server_round_trip_matches_solo(smoke_model):
    from repro.fleet import FleetServer, RouterConfig
    from repro.serve import (CoexecServer, Replica, RequestQueue,
                             ServerConfig, make_requests)
    cfg, params, prompts = smoke_model

    def reqs():
        return make_requests([0.0] * len(prompts), slo=300.0,
                             prompt_fn=lambda i: prompts[i])

    fleet = FleetServer([_worker("w0", smoke_model),
                         _worker("w1", smoke_model)],
                        RouterConfig(placement="least_residual",
                                     admit="none"))
    out = fleet.run(RequestQueue(reqs()))
    assert out.stats.served == len(prompts) and out.stats.shed == 0

    solo = CoexecServer([Replica("solo", cfg, params)],
                        ServerConfig(scheduler="hguided_deadline", lws=2,
                                     gen=2, policy="none"))
    try:
        ref = solo.run(RequestQueue(reqs()))
    finally:
        solo.close()

    assert set(out.results) == set(ref.results)
    for rid in ref.results:
        np.testing.assert_array_equal(out.results[rid], ref.results[rid])
    # dispatch is namespaced per worker and accounts for every request
    assert all(":" in k for k in out.stats.dispatch)
    assert sum(out.stats.dispatch.values()) == len(prompts)


def test_fleet_server_standby_worker_serves_nothing(smoke_model):
    from repro.fleet import FleetServer, RouterConfig
    from repro.serve import RequestQueue, make_requests
    _, _, prompts = smoke_model
    reqs = make_requests([0.0] * len(prompts), slo=300.0,
                         prompt_fn=lambda i: prompts[i])
    spare = _worker("spare", smoke_model, power=50.0)
    fleet = FleetServer([_worker("w0", smoke_model), spare],
                        RouterConfig(placement="least_residual",
                                     admit="none"),
                        standby=["spare"])
    assert spare.server.session.devices == []    # detached at init
    out = fleet.run(RequestQueue(reqs))
    assert out.stats.served == len(prompts)
    assert not any(k.startswith("spare:") for k in out.stats.dispatch)
