"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------- gaussian
@pytest.mark.parametrize("h,w,ksize,tile", [(128, 64, 7, 16), (128, 256, 31, 64),
                                            (256, 128, 15, 32)])
def test_gaussian_kernel(h, w, ksize, tile):
    from repro.kernels.gaussian import kernel as K, ref as R
    img = RNG.standard_normal((h, w)).astype(np.float32)
    pad = ksize // 2
    ip = jnp.asarray(np.pad(img, pad, mode="edge"))
    wts = jnp.asarray(R.gaussian_weights(ksize))
    ref = R.blur_rows_ref(ip, wts, 0, h)
    got = K.blur_rows(ip, wts, tile_h=tile, interpret=True)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_gaussian_range_consistency():
    from repro.kernels.gaussian import ops, ref as R
    img = RNG.standard_normal((256, 128)).astype(np.float32)
    ip, w = ops.prepare(img)
    ipj, wj = jnp.asarray(ip), jnp.asarray(w)
    full = R.blur_full_ref(jnp.asarray(img))
    parts = [ops.run_range(ipj, wj, i, 1) for i in range(ops.total_work(img))]
    np.testing.assert_allclose(jnp.concatenate(parts, 0), full,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- binomial
@pytest.mark.parametrize("n,steps,tile", [(256, 64, 64), (512, 254, 128)])
def test_binomial_kernel(n, steps, tile):
    from repro.kernels.binomial import kernel as K, ops, ref as R
    s0, k0, ty = map(jnp.asarray, ops.make_inputs(n))
    ref = R.price_options(s0, k0, ty, steps=steps)
    got = K.price_options(s0, k0, ty, steps=steps, tile=tile, interpret=True)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-3)


def test_binomial_monotone_in_spot():
    """Option value increases with the spot price (sanity property)."""
    from repro.kernels.binomial import ref as R
    s0 = jnp.linspace(5.0, 50.0, 20)
    k0 = jnp.full((20,), 25.0)
    ty = jnp.full((20,), 2.0)
    v = R.price_options(s0, k0, ty)
    assert bool(jnp.all(jnp.diff(v) >= -1e-5))


# -------------------------------------------------------------- mandelbrot
@pytest.mark.parametrize("w,h,iters", [(64, 64, 64), (128, 32, 200)])
def test_mandelbrot_kernel(w, h, iters):
    from repro.kernels.mandelbrot import kernel as K, ref as R
    ref = R.escape_counts(0, h, w, h, iters)
    got = K.escape_counts(0, h, w, h, iters, tile_h=8, interpret=True)
    assert (np.asarray(ref) == np.asarray(got)).all()


def test_mandelbrot_interior_maxes_out():
    from repro.kernels.mandelbrot import ref as R
    # the set's interior (c ~ -0.1 + 0i is inside) never escapes
    cnt = R.escape_counts(30, 4, 64, 64, 50)   # middle rows
    assert int(cnt.max()) == 50


# ------------------------------------------------------------------ nbody
@pytest.mark.parametrize("n,tile_t,tile_s", [(256, 64, 128), (512, 128, 256)])
def test_nbody_kernel(n, tile_t, tile_s):
    from repro.kernels.nbody import kernel as K, ops, ref as R
    pm, vel = ops.make_inputs(n)
    ref = R.accelerations(jnp.asarray(pm), 0, tile_t)
    got = K.accelerations(jnp.asarray(pm[:tile_t]), jnp.asarray(pm),
                          tile_t=tile_t, tile_s=tile_s, interpret=True)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_nbody_momentum_conservation():
    """Equal masses: total acceleration ~ 0 (Newton's third law)."""
    from repro.kernels.nbody import ref as R
    pm, _ = __import__("repro.kernels.nbody.ops", fromlist=["make_inputs"]) \
        .make_inputs(128)
    pm[:, 3] = 1.0
    acc = R.accelerations(jnp.asarray(pm), 0, 128)
    total = np.asarray(jnp.sum(acc * pm[:, 3:4], axis=0))
    assert np.abs(total).max() < 1e-2


# ---------------------------------------------------------------- ray
def test_ray_scenes_differ_and_shade():
    from repro.kernels.ray import ref as R
    s1, s2 = R.make_scene(1), R.make_scene(2)
    img1 = R.render_rows(s1, 0, 64, 64, 64)
    img2 = R.render_rows(s2, 0, 64, 64, 64)
    assert img1.shape == (64, 64, 3)
    assert float(jnp.abs(img1 - img2).max()) > 0.1
    assert bool(jnp.isfinite(img1).all())
    assert float(img1.max()) <= 1.5 and float(img1.min()) >= 0.0


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,S,H,KH,D,bq,bk,dtype", [
    (2, 128, 4, 4, 64, 64, 64, jnp.float32),
    (1, 256, 8, 2, 64, 128, 64, jnp.float32),
    (1, 256, 4, 1, 128, 64, 128, jnp.float32),
    (2, 128, 8, 4, 80, 128, 32, jnp.float32),
    (1, 128, 4, 2, 64, 64, 64, jnp.bfloat16),
])
def test_flash_attention_kernel(B, S, H, KH, D, bq, bk, dtype):
    from repro.kernels.flash_attention import kernel as K, ref as R
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, KH, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, KH, D)), dtype)
    ref = R.attention_ref(q, k, v)
    got = K.flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=atol)


def test_flash_matches_blocked_jnp_path():
    from repro.kernels.flash_attention import ops
    from repro.kernels.flash_attention import ref as R
    q = jnp.asarray(RNG.standard_normal((1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 256, 2, 64)), jnp.float32)
    blocked = ops.attention(q, k, v, chunk=64)
    np.testing.assert_allclose(blocked, R.attention_ref(q, k, v),
                               rtol=1e-4, atol=2e-5)


# ---------------------------------------------------------- mamba scan
@pytest.mark.parametrize("B,S,di,ds,chunk,tile_d", [
    (1, 64, 32, 8, 16, 32),
    (2, 128, 64, 16, 64, 32),
    (2, 96, 48, 16, 32, 48),
])
def test_mamba_scan_kernel(B, S, di, ds, chunk, tile_d):
    from repro.kernels.mamba_scan import kernel as K, ref as R
    a = jnp.asarray(RNG.uniform(0.5, 0.99, (B, S, di, ds)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((B, S, di, ds)) * 0.1, jnp.float32)
    C = jnp.asarray(RNG.standard_normal((B, S, ds)), jnp.float32)
    yr, hr = R.selective_scan_ref(a, b, C)
    yp, hp = K.selective_scan(a, b, C, chunk=chunk, tile_d=tile_d,
                              interpret=True)
    np.testing.assert_allclose(yp, yr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(hp, hr, rtol=1e-4, atol=1e-5)


def test_mamba_chunked_jnp_matches_ref():
    from repro.kernels.mamba_scan import ops, ref as R
    a = jnp.asarray(RNG.uniform(0.5, 0.99, (2, 128, 32, 8)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((2, 128, 32, 8)) * 0.1, jnp.float32)
    C = jnp.asarray(RNG.standard_normal((2, 128, 8)), jnp.float32)
    y1, h1 = ops.selective_scan(a, b, C, chunk=32)
    y2, h2 = R.selective_scan_ref(a, b, C)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------- flash decode
@pytest.mark.parametrize("B,S,H,KH,D,bk,pos", [
    (2, 256, 8, 4, 64, 64, 255),
    (1, 512, 4, 1, 128, 128, 300),     # masked tail inside a block
    (2, 256, 8, 8, 64, 256, 17),       # most blocks skipped
    (1, 128, 16, 2, 64, 32, 127),
])
def test_flash_decode_kernel(B, S, H, KH, D, bk, pos):
    from repro.kernels.flash_decode import kernel as K, ref as R
    q = jnp.asarray(RNG.standard_normal((B, H, D)), jnp.float32)
    kc = jnp.asarray(RNG.standard_normal((B, S, KH, D)), jnp.bfloat16)
    vc = jnp.asarray(RNG.standard_normal((B, S, KH, D)), jnp.bfloat16)
    ref = R.decode_attention_ref(q, kc, vc, jnp.int32(pos))
    got = K.flash_decode(q, kc, vc, jnp.int32(pos), bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_decode_matches_model_path():
    from repro.kernels.flash_decode import ops
    q = jnp.asarray(RNG.standard_normal((2, 8, 64)), jnp.float32)
    kc = jnp.asarray(RNG.standard_normal((2, 128, 4, 64)), jnp.float32)
    vc = jnp.asarray(RNG.standard_normal((2, 128, 4, 64)), jnp.float32)
    a = ops.decode_attention(q, kc, vc, jnp.int32(100))
    b = ops.decode_attention(q, kc, vc, jnp.int32(100), use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)
