"""Energy-subsystem tests: PowerModel, EnergyMeter, joule identity,
the budget-capped scheduler, and energy-aware fleet placement.

Mirrors the phase-identity style of tests/test_membuf.py: the accounting
identity (total == busy + idle + lock + xfer joules) must hold to float
precision on EVERY executor — threaded engine, ``simulate``,
``simulate_serving`` — across every registered scheduler, under requeue
and device death.  Zero-power defaults must stay joule-blind
(``energy_j == 0``) with behavior unchanged.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import BufferPolicy, available_schedulers, coexec
from repro.core import programs as P
from repro.core.device import DeviceGroup
from repro.core.simulate import (SimConfig, SimDevice, simulate,
                                 simulate_serving)
from repro.energy import (PRESETS, ZERO_POWER, EnergyMeter, PowerModel,
                          zero_report)

GAUSS_KW = dict(h=64, w=96, lws=(8, 8))

GPU_PM = PowerModel(busy_w=180.0, idle_w=10.0, lock_j=2e-4,
                    xfer_j_per_byte=6e-9)
CPU_PM = PowerModel(busy_w=65.0, idle_w=5.0, lock_j=2e-4)
IGPU_PM = PowerModel(busy_w=28.0, idle_w=3.0, lock_j=2e-4)

IDENTITY_TOL = 1e-9


def sim_devices(fail_dgpu_at=None):
    return [
        SimDevice("dgpu", 1000.0, transfer_in=1e-4, transfer_out=1e-4,
                  jitter=0.05, fail_at=fail_dgpu_at, power_model=GPU_PM,
                  stage_in_bytes=1e6, xfer_bytes_per_wg=128.0),
        SimDevice("cpu", 300.0, zero_copy=True, jitter=0.05,
                  irregularity=lambda x: 1.0 + 0.5 * x,
                  power_model=CPU_PM),
        SimDevice("igpu", 450.0, zero_copy=True, jitter=0.05,
                  power_model=IGPU_PM),
    ]


# ------------------------------------------------------------- model/meter


def test_power_model_joules_and_zero():
    pm = PowerModel(busy_w=100.0, idle_w=10.0, lock_j=1e-3,
                    xfer_j_per_byte=1e-9)
    assert pm.joules(2.0, 3.0, crossings=5, bytes_moved=1e6) == \
        pytest.approx(200.0 + 30.0 + 5e-3 + 1e-3)
    assert not pm.is_zero
    assert ZERO_POWER.is_zero
    assert ZERO_POWER.joules(10.0, 10.0, crossings=99,
                             bytes_moved=1e9) == 0.0
    for name in ("cpu", "igpu", "gpu"):
        assert PRESETS[name].busy_w > PRESETS[name].idle_w > 0


def test_meter_last_sample_wins_and_identity():
    m = EnergyMeter()
    m.add("d0", GPU_PM, busy_s=1.0, window_s=2.0)
    m.add("d0", GPU_PM, busy_s=2.0, window_s=4.0, crossings=10,
          bytes_moved=1e6)
    rep = m.report()
    assert len(rep.devices) == 1
    d = rep.by_name("d0")
    assert d.busy_s == 2.0 and d.idle_s == 2.0
    assert rep.total_j == pytest.approx(
        2.0 * 180.0 + 2.0 * 10.0 + 10 * 2e-4 + 1e6 * 6e-9)
    assert rep.identity_gap() < IDENTITY_TOL


def test_zero_report_is_joule_blind():
    rep = zero_report(["a", "b"])
    assert rep.total_j == 0.0 and len(rep.devices) == 2


# ------------------------------------------------- identity across schedulers


@settings(max_examples=20, deadline=None)
@given(scheduler=st.sampled_from(sorted(available_schedulers())),
       seed=st.integers(min_value=0, max_value=2**31 - 1),
       fail_at=st.sampled_from([None, 0.05, 0.2, 0.8, 2.0]))
def test_joule_identity_all_schedulers_with_death(scheduler, seed, fail_at):
    """Joule identity holds for every registered scheduler under jitter,
    irregularity, requeue and device death; busy never exceeds a device's
    powered window; a dead device's window ends at its death."""
    res = simulate(4096, 16, sim_devices(fail_dgpu_at=fail_at),
                   SimConfig(scheduler=scheduler, buffer_policy="pooled",
                             dispatch="leased", seed=seed))
    rep = res.energy
    assert rep is not None
    assert rep.identity_gap() < IDENTITY_TOL * max(1.0, rep.total_j)
    assert rep.total_j > 0
    for d in rep.devices:
        assert 0.0 <= d.busy_s <= d.window_s + 1e-12
        assert d.window_s <= res.total_time + 1e-12
    if fail_at is not None and res.aborted_devices:
        assert rep.by_name("dgpu").window_s == pytest.approx(
            min(fail_at, res.total_time))


def test_joule_identity_simulate_serving():
    reqs = [type("R", (), dict(rid=i, arrival=0.02 * i, deadline=10.0,
                               size=32, finish=None, shed=False,
                               replica=None, degraded=False))()
            for i in range(24)]
    res = simulate_serving(reqs, 8, sim_devices(),
                           SimConfig(scheduler="hguided_opt",
                                     buffer_policy="pooled", seed=0),
                           policy="none")
    rep = res.energy
    assert rep is not None and rep.total_j > 0
    assert rep.identity_gap() < IDENTITY_TOL * rep.total_j
    assert res.energy_j == rep.total_j


# ------------------------------------------------------- zero-power defaults


def test_zero_power_defaults_sim_and_threaded():
    """Without power models everything stays joule-blind: energy_j == 0,
    and the RunResult otherwise matches a pre-energy run shape."""
    r = simulate(2048, 8, [SimDevice("a", 500.0), SimDevice("b", 250.0)],
                 SimConfig(scheduler="hguided", seed=0))
    assert r.energy is not None and r.energy_j == 0.0
    assert all(d.total_j == 0.0 for d in r.energy.devices)

    prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
    res = coexec(prog, [DeviceGroup("cpu", throttle=2.0),
                        DeviceGroup("gpu", throttle=1.0)])
    assert res.energy is not None and res.energy_j == 0.0


def test_packet_cost_busy_stall_split():
    """PacketCost exposes the busy/stall split exactly: t == busy + stall,
    and tuple indexing stays compatible ([0] is total time)."""
    d = SimDevice("g", 1000.0, transfer_in=1e-4, transfer_out=2e-4,
                  launch_overhead=1e-3)
    cost = d.packet_cost(0, 64, 4096, 0.0, "per_packet", first=True)
    assert cost[0] == cost.t
    assert cost.t == pytest.approx(cost.busy_s + cost.stall_s)
    assert cost.stall_s == pytest.approx(cost.h2d + cost.d2h)
    zc = SimDevice("c", 1000.0, zero_copy=True)
    czc = zc.packet_cost(0, 64, 4096, 0.0, "per_packet", first=True)
    assert czc.stall_s == 0.0 and czc.t == czc.busy_s


# -------------------------------------------------------- threaded metering


def test_threaded_energy_and_sim_agreement():
    """The threaded engine meters real busy windows; a simulator run
    calibrated from the measured throughputs charges the same PowerModels
    and must land in the same ballpark (generous tolerance — container
    timing drifts, the power math must not)."""
    prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
    devs = [DeviceGroup("cpu", throttle=3.0, power_model=CPU_PM),
            DeviceGroup("gpu", throttle=1.0, power_model=GPU_PM)]
    res = coexec(prog, devs, scheduler="hguided",
                 buffer_policy=BufferPolicy.POOLED)
    rep = res.energy
    assert rep is not None and rep.total_j > 0
    assert rep.identity_gap() < IDENTITY_TOL * rep.total_j
    for d in rep.devices:
        assert 0.0 <= d.busy_s <= d.window_s + 1e-9

    # calibrate sim devices from the measured run and re-meter
    work = {d.name: 0.0 for d in devs}
    for p in res.packets:
        work[devs[p.device].name] += p.size
    sim_devs = []
    for i, d in enumerate(devs):
        busy = max(res.device_busy[i], 1e-9)
        sim_devs.append(SimDevice(d.name, work[d.name] / busy,
                                  zero_copy=True, launch_overhead=0.0,
                                  power_model=d.power_model))
    total = sum(int(w) for w in work.values())
    # strip the simulator's fixed desktop-scale overhead constants: this
    # threaded run is milliseconds long, so the comparison is busy/idle
    # integration only
    sr = simulate(total, prog.lws if isinstance(prog.lws, int) else 8,
                  sim_devs, SimConfig(scheduler="hguided", seed=0,
                                      sync_cost=0.0,
                                      sync_cost_optimized=0.0,
                                      host_cost_per_packet=0.0))
    assert sr.energy_j == pytest.approx(res.energy_j, rel=0.5)


def test_threaded_energy_survives_device_death():
    """A dying device under power models: run stays exact, identity
    holds, and the dead device's powered window ends at its death (its
    window is strictly inside the survivors' ROI window)."""
    ref = P.reference_output("gaussian2d", **GAUSS_KW)
    devs = [DeviceGroup("flaky", throttle=1.5, fail_after=0,
                        power_model=GPU_PM),
            DeviceGroup("cpu", throttle=2.0, power_model=CPU_PM),
            DeviceGroup("gpu", throttle=1.0, power_model=IGPU_PM)]
    prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
    res = coexec(prog, devs, scheduler="dynamic",
                 scheduler_kwargs={"n_packets": 6},
                 buffer_policy=BufferPolicy.POOLED)
    assert res.aborted_devices == 1
    np.testing.assert_array_equal(res.output, ref)
    rep = res.energy
    assert rep.identity_gap() < IDENTITY_TOL * max(1.0, rep.total_j)
    alive = [d for d in rep.devices if d.name != "flaky"]
    assert rep.by_name("flaky").window_s <= min(d.window_s for d in alive)


# --------------------------------------------------- energy-capped scheduler


def _energy_run(budget):
    skw = {} if budget is None else {"energy_budget_j": budget}
    return simulate(16000, 16, sim_devices(),
                    SimConfig(scheduler="hguided_energy",
                              buffer_policy="pooled", dispatch="leased",
                              opt_init=True, seed=0,
                              scheduler_kwargs=skw))


def test_hguided_energy_budget_trades_time_for_joules():
    base = _energy_run(None)
    capped = _energy_run(0.7 * base.energy_j)
    tighter = _energy_run(0.5 * base.energy_j)
    assert capped.energy_j < base.energy_j
    assert tighter.energy_j < capped.energy_j
    assert capped.total_time > base.total_time
    assert tighter.total_time > capped.total_time
    for r in (base, capped, tighter):
        assert r.energy.identity_gap() < IDENTITY_TOL * r.energy.total_j


def test_hguided_energy_uncapped_matches_deadline_scheduler():
    """With no budget the energy scheduler degenerates to
    HGuidedDeadline exactly (same carve decisions, same seed stream)."""
    kw = dict(buffer_policy="pooled", dispatch="leased", seed=3)
    a = simulate(8192, 16, sim_devices(),
                 SimConfig(scheduler="hguided_energy", **kw))
    b = simulate(8192, 16, sim_devices(),
                 SimConfig(scheduler="hguided_deadline", **kw))
    assert a.total_time == b.total_time
    assert a.energy_j == b.energy_j


def test_hguided_energy_drains_under_tight_budget_and_death():
    """Even an absurdly tight budget must drain all work (the most
    efficient *alive* device is never denied), including when that
    device itself dies mid-run."""
    devs = sim_devices()
    devs[2].fail_at = 0.5          # igpu (most efficient) dies
    r = simulate(8192, 16, devs,
                 SimConfig(scheduler="hguided_energy",
                           buffer_policy="pooled", dispatch="leased",
                           seed=0, scheduler_kwargs={"energy_budget_j": 1.0}))
    assert sum(p.size for p in r.packets) == 8192
    assert r.aborted_devices == 1


def test_hguided_energy_registered():
    assert "hguided_energy" in available_schedulers()


# ------------------------------------------------------------ fleet routing


def _fleet_reps():
    from repro.fleet import SimReplica
    return [
        SimReplica("big", [SimDevice("gpu", 1200.0, jitter=0.02,
                                     power_model=GPU_PM)], lws=8),
        SimReplica("eff", [SimDevice("igpu", 500.0, zero_copy=True,
                                     jitter=0.02,
                                     power_model=IGPU_PM)], lws=8),
    ]


def test_energy_placement_registered():
    from repro.fleet.placement import PLACEMENTS
    assert "energy" in PLACEMENTS


def test_energy_placement_prefers_efficient_replica_under_slack():
    """With slack deadlines the energy router probes both replicas, then
    routes to the cheaper one: fewer J/request than the deadline router
    at no worse SLO attainment."""
    from repro.fleet import RouterConfig, simulate_fleet
    from repro.serve import ARRIVALS, make_requests

    def run(placement):
        rng = np.random.default_rng(0)
        reqs = make_requests(ARRIVALS["poisson"](32, 10.0, rng), 6.0,
                             size=64)
        return simulate_fleet(reqs, _fleet_reps(),
                              SimConfig(scheduler="hguided_opt",
                                        buffer_policy="pooled", seed=0),
                              RouterConfig(placement=placement),
                              epoch_s=0.5)

    e, d = run("energy"), run("deadline")
    assert e.stats.slo_attainment >= d.stats.slo_attainment
    assert 0 < e.stats.energy_j < d.stats.energy_j
    assert e.stats.j_per_request < d.stats.j_per_request
    # the probe measured both replicas, then concentrated on the cheap one
    assert len(e.replica_requests["eff"]) > len(e.replica_requests["big"])
    assert len(e.replica_requests["big"]) >= 1


def test_serve_stats_energy_row_and_j_per_request():
    from repro.serve.stats import ServeStats
    s = ServeStats(n_requests=4, served=4, shed=0, missed=0, degraded=0,
                   p50_latency=0.1, p99_latency=0.2, mean_latency=0.1,
                   slo_attainment=1.0, goodput_wg_s=10.0,
                   throughput_wg_s=10.0, duration=1.0, energy_j=8.0)
    assert s.j_per_request == 2.0
    assert "energy=8.0J" in s.row()
    s0 = ServeStats(n_requests=0, served=0, shed=0, missed=0, degraded=0,
                    p50_latency=0.0, p99_latency=0.0, mean_latency=0.0,
                    slo_attainment=0.0, goodput_wg_s=0.0,
                    throughput_wg_s=0.0, duration=0.0)
    assert s0.j_per_request == 0.0 and "energy" not in s0.row()
