"""Memory-subsystem tests: BufferArena, TransferPipeline, POOLED runs.

Covers the arena's ring/recycle/LRU contracts (including hypothesis-driven
submit sequences), pooled-vs-per-packet output equality on every registered
scheduler, the exact five-window phase identity, fault tolerance under the
pipelined device loop, the simulator's overlap model, and the close()
drain-then-release ordering regression.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (
    BufferPolicy,
    EngineSession,
    OffloadMode,
    available_schedulers,
    coexec,
)
from repro.core import programs as P
from repro.core.device import DeviceGroup
from repro.core.membuf import BufferArena, TransferPipeline, bucket_bytes
from repro.core.runtime import WorkerPool
from repro.core.simulate import SimConfig, SimDevice, simulate

MANDEL_KW = dict(px=48, max_iter=64, lws=(8, 8))
GAUSS_KW = dict(h=64, w=96, lws=(8, 8))


def devices3():
    return [
        DeviceGroup("cpu", throttle=4.0),
        DeviceGroup("igpu", throttle=2.0),
        DeviceGroup("gpu", throttle=1.0),
    ]


# ------------------------------------------------------------------ arena


def test_bucket_bytes_size_classes():
    assert bucket_bytes(1) == 256
    assert bucket_bytes(256) == 256
    assert bucket_bytes(257) == 512
    assert bucket_bytes(8192) == 8192
    assert bucket_bytes(8193) == 16384


def test_ring_hit_then_recycle():
    arena = BufferArena(ring=2)
    l1 = arena.acquire("p", "host", (16, 16), np.float32)
    l2 = arena.acquire("p", "host", (16, 16), np.float32)
    assert not np.shares_memory(l1.array, l2.array)
    # ring full, both leased: the third acquire recycles the OLDEST lease
    l3 = arena.acquire("p", "host", (16, 16), np.float32)
    assert np.shares_memory(l1.array, l3.array)
    s = arena.stats
    assert s.misses == 2 and s.recycles == 1
    assert s.entries == 2 and s.leases_out == 2


def test_release_makes_free_entry_hit():
    arena = BufferArena(ring=2)
    l1 = arena.acquire("p", "host", (8, 8), np.float32)
    arena.release(l1)
    l2 = arena.acquire("p", "host", (8, 8), np.float32)
    assert np.shares_memory(l1.array, l2.array)
    assert arena.stats.hits == 1


def test_rekey_steals_lru_free_entry_from_same_bucket():
    arena = BufferArena(ring=2)
    l1 = arena.acquire("a", "host", (32,), np.float32)  # 128B -> 256B bucket
    arena.release(l1)
    l2 = arena.acquire("b", "host", (64,), np.uint8)  # same 256B bucket
    assert np.shares_memory(l1.array, l2.array)
    s = arena.stats
    assert s.rekeys == 1 and s.misses == 1


def test_register_prepopulates_ring():
    arena = BufferArena(ring=2)
    arena.register("p", "host", (128, 4), np.float32)
    assert arena.stats.entries == 2
    arena.acquire("p", "host", (128, 4), np.float32)
    s = arena.stats
    assert s.hits == 1 and s.misses == 0


def test_evict_drops_only_that_program():
    arena = BufferArena(ring=2)
    arena.register("keep", "host", (64,), np.float32)
    arena.register("drop", "host", (64,), np.float32)
    assert arena.evict("drop") == 2
    s = arena.stats
    assert s.entries == 2  # keep's ring intact
    assert arena.evict("keep") == 2
    assert arena.stats.entries == 0


def test_close_refuses_further_acquires():
    arena = BufferArena()
    lease = arena.acquire("p", "host", (4,), np.float32)
    arena.close()
    assert arena.stats.entries == 0
    lease.array[:] = 1.0  # holder's view stays valid
    with pytest.raises(RuntimeError, match="closed"):
        arena.acquire("p", "host", (4,), np.float32)


def test_capacity_bounds_free_pool_lru():
    arena = BufferArena(capacity_bytes=4096, ring=4)
    leases = [
        arena.acquire("p", "host", (2048,), np.uint8) for _ in range(4)
    ]
    for lease in leases:
        arena.release(lease)  # 4 x 2048B free > 4096B capacity
    s = arena.stats
    assert s.bytes_pooled <= 4096
    assert s.evictions >= 2


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(min_value=0, max_value=2),  # program index
            st.integers(min_value=0, max_value=3),  # shape index
            st.integers(min_value=0, max_value=2),  # 0/1 acquire, 2 release
        ),
        min_size=1,
        max_size=60,
    )
)
def test_arena_invariants_under_submit_sequences(ops):
    """LRU eviction bounds: whatever the submit sequence, the free pool
    never exceeds capacity, per-key entries never exceed the ring, and
    every lease stays usable."""
    capacity = 1 << 14
    ring = 2
    arena = BufferArena(capacity_bytes=capacity, ring=ring)
    shapes = [(256,), (1024,), (333,), (2048,)]
    held = []
    for prog_i, shape_i, kind in ops:
        if kind == 2 and held:
            arena.release(held.pop(0))
        else:
            lease = arena.acquire(
                f"prog{prog_i}", "host", shapes[shape_i], np.float32
            )
            lease.array.fill(prog_i)  # the view must be writable
            held.append(lease)
        s = arena.stats
        assert s.bytes_pooled <= capacity          # LRU bound on free pool
        assert s.leases_out <= s.entries
        assert s.bytes_total == s.bytes_pooled + s.bytes_leased
        assert s.acquires == s.hits + s.rekeys + s.misses + s.recycles
    # tracked entries per key never exceed the ring
    for ents in arena._by_key.values():
        assert len(ents) <= ring


# --------------------------------------------------------------- pipeline


def test_pipeline_prefetch_and_staged_commits():
    pool = WorkerPool(name="pipe-test")
    pipe = TransferPipeline(pool, async_threshold_bytes=1024)
    pipe.start()
    fut = pipe.prefetch(lambda: 41 + 1)
    assert fut.result() == 42
    out = np.zeros(8, np.int64)

    def commit_small():
        out[0] = 1

    def commit_large():
        out[1] = 2

    pipe.stage_out(commit_small, nbytes=64)  # below threshold: inline
    assert out[0] == 1
    pipe.stage_out(commit_large, nbytes=4096)  # above: committer thread
    pipe.flush()
    assert out[1] == 2
    assert pipe.commits == 2
    pipe.close()
    pool.close()


def test_pipeline_prefetch_error_surfaces_at_result():
    pool = WorkerPool(name="pipe-err")
    pipe = TransferPipeline(pool)
    pipe.start()

    def boom():
        raise ValueError("staging failed")

    fut = pipe.prefetch(boom)
    with pytest.raises(ValueError, match="staging failed"):
        fut.result()
    pipe.close()
    pool.close()


# ------------------------------------------------------------ pooled runs


def test_pooled_bit_identical_outputs_all_schedulers():
    """Integer mandelbrot: pooled and per-packet outputs must be
    bit-identical under every registered scheduler (and match the
    single-device oracle)."""
    ref = P.reference_output("mandelbrot2d", **MANDEL_KW)
    for name in available_schedulers():
        outs = {}
        for policy in (BufferPolicy.POOLED, BufferPolicy.PER_PACKET):
            prog = P.PROGRAMS["mandelbrot2d"](**MANDEL_KW)
            res = coexec(
                prog, devices3(), scheduler=name, buffer_policy=policy
            )
            outs[policy] = np.array(res.output, copy=True)
        np.testing.assert_array_equal(
            outs[BufferPolicy.POOLED], outs[BufferPolicy.PER_PACKET],
            err_msg=f"scheduler {name}",
        )
        np.testing.assert_array_equal(
            outs[BufferPolicy.POOLED], ref, err_msg=f"scheduler {name}"
        )


def test_pooled_float_outputs_match_reference_all_schedulers():
    ref = P.reference_output("gaussian2d", **GAUSS_KW)
    for name in available_schedulers():
        prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
        res = coexec(
            prog, devices3(), scheduler=name,
            buffer_policy=BufferPolicy.POOLED,
        )
        np.testing.assert_allclose(
            res.output, ref, rtol=1e-5, atol=1e-5, err_msg=f"scheduler {name}"
        )


def test_roi_submits_default_to_pooled_and_recycle_the_ring():
    prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
    with EngineSession(devices3()) as session:
        session.register_workload(prog)
        assert session.arena_stats.entries == 2  # ring pre-registered
        r1 = session.submit(prog, mode=OffloadMode.ROI).result()
        r2 = session.submit(prog, mode=OffloadMode.ROI).result()
        r3 = session.submit(prog, mode=OffloadMode.ROI).result()
        # double-buffer contract: the ring cycles every `ring` submits
        assert not np.shares_memory(r1.output, r2.output)
        assert np.shares_memory(r1.output, r3.output)
        s = session.arena_stats
        assert s.acquires == 3 and s.misses == 0
        # an explicit REGISTERED submit must not touch the arena
        session.submit(
            prog, mode=OffloadMode.ROI,
            buffer_policy=BufferPolicy.REGISTERED,
        ).result()
        assert session.arena_stats.acquires == 3


def test_unregister_workload_evicts_arena_entries():
    prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
    with EngineSession(devices3()) as session:
        session.register_workload(prog)
        session.submit(prog, mode=OffloadMode.ROI).result()
        assert session.arena_stats.entries > 0
        session.unregister_workload(prog.name)
        assert session.arena_stats.entries == 0


def test_phase_identity_all_policies():
    """The five phase windows are disjoint wall segments:
    init + h2d + roi + d2h + teardown == wall, exactly."""
    for policy in (
        BufferPolicy.POOLED,
        BufferPolicy.REGISTERED,
        BufferPolicy.PER_PACKET,
    ):
        prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
        res = coexec(prog, devices3(), buffer_policy=policy)
        ph = res.phases
        wall = ph.init_s + ph.h2d_s + ph.roi_s + ph.d2h_s + ph.teardown_s
        assert wall == pytest.approx(res.binary_time, rel=1e-6), policy
        assert ph.offload_s == pytest.approx(
            ph.h2d_s + ph.roi_s + ph.d2h_s, rel=1e-6
        ), policy
        assert ph.roi_s == res.total_time
        assert ph.binary == pytest.approx(res.binary_time, rel=1e-6)


def test_pooled_fault_tolerance_requeues_and_stays_exact():
    """A device dying mid-run under the pipelined loop: its packet is
    requeued and the survivors produce the exact output."""
    ref = P.reference_output("mandelbrot2d", **MANDEL_KW)
    devs = [
        DeviceGroup("flaky", throttle=1.5, fail_after=0),
        DeviceGroup("igpu", throttle=2.0),
        DeviceGroup("gpu", throttle=1.0),
    ]
    prog = P.PROGRAMS["mandelbrot2d"](**MANDEL_KW)
    res = coexec(
        prog, devs, scheduler="dynamic",
        scheduler_kwargs={"n_packets": 6},
        buffer_policy=BufferPolicy.POOLED,
    )
    assert res.aborted_devices == 1
    assert res.retries >= 1
    np.testing.assert_array_equal(res.output, ref)


def test_pooled_stage_in_failure_releases_device(monkeypatch):
    """A stage-in (launch-bind) failure under the pipelined loop must mark
    the device dead and release its pre-assigned chunk — survivors absorb
    the work instead of livelocking on a stranded static chunk."""
    from repro.core import runtime as R

    orig = R._RunContext._invoke
    tripped = {"n": 0}

    def flaky_invoke(self, fn, region):
        if tripped["n"] == 0:
            tripped["n"] += 1
            raise ValueError("bad geometry")
        return orig(self, fn, region)

    monkeypatch.setattr(R._RunContext, "_invoke", flaky_invoke)
    ref = P.reference_output("mandelbrot2d", **MANDEL_KW)
    prog = P.PROGRAMS["mandelbrot2d"](**MANDEL_KW)
    res = coexec(
        prog, devices3(), scheduler="static",
        buffer_policy=BufferPolicy.POOLED,
    )
    assert tripped["n"] == 1
    assert res.aborted_devices == 1
    np.testing.assert_array_equal(res.output, ref)


# -------------------------------------------------------------- simulator


def test_simulator_pooled_overlap_ordering_and_phases():
    dev = [SimDevice("gpu", 1000.0, transfer_in=2e-4, transfer_out=2e-4)]
    times = {}
    for policy in ("per_packet", "registered", "pooled"):
        r = simulate(
            4096, 8, dev,
            SimConfig(
                scheduler="dynamic",
                scheduler_kwargs={"n_packets": 16},
                buffer_policy=policy,
            ),
        )
        times[policy] = r.total_time
        assert r.phases.roi_s == r.total_time
        if policy == "pooled":
            # only the pipeline fill is unhidden
            assert r.phases.h2d_s < times_reg_h2d
            assert r.phases.d2h_s <= times_reg_d2h
        elif policy == "registered":
            times_reg_h2d = r.phases.h2d_s
            times_reg_d2h = r.phases.d2h_s
            assert r.phases.h2d_s > 0 and r.phases.d2h_s > 0
    assert times["pooled"] < times["registered"] < times["per_packet"]


def test_simconfig_policy_resolution_backcompat():
    assert SimConfig().policy == "per_packet"
    assert SimConfig(opt_buffers=True).policy == "registered"
    cfg = SimConfig(opt_buffers=True, buffer_policy="pooled")
    assert cfg.policy == "pooled"


# ------------------------------------------------- close-ordering bugfix


def test_close_drains_inflight_pooled_submits_without_leaking_arena():
    """Regression: close() must drain the dispatch queue and release the
    arena BEFORE WorkerPool.close() — a close racing in-flight pooled
    submits must not leak arena entries (or wedge on a dead pool)."""
    prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
    ref = P.reference_output("gaussian2d", **GAUSS_KW)
    session = EngineSession(devices3())
    session.register_workload(prog)
    handles = [
        session.submit(prog, mode=OffloadMode.ROI) for _ in range(5)
    ]
    session.close()  # races the queued submits: drain, then release
    for h in handles:
        res = h.result(timeout=60)  # every queued run completed
        np.testing.assert_allclose(res.output, ref, rtol=1e-5, atol=1e-5)
    s = session.arena_stats
    assert s.entries == 0 and s.bytes_total == 0
    with pytest.raises(RuntimeError, match="closed"):
        session.submit(prog, mode=OffloadMode.ROI)


def test_close_drains_pending_graph_submits_without_leaking_arena():
    """Graph variant of the close race: close() arriving while DEPENDENT
    pooled submits are still pending must drain the graph topologically
    (dependents run after their predecessors, before the arena/pool shut
    down) — no leaked _Submissions, no leaked arena entries."""
    prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
    ref = P.reference_output("gaussian2d", **GAUSS_KW)
    session = EngineSession(devices3(), max_inflight=2)
    session.register_workload(prog)
    seen = []
    root = session.submit(prog, mode=OffloadMode.ROI)
    mids = [
        session.submit(
            prog,
            mode=OffloadMode.ROI,
            deps=[root],
            feed=lambda results: seen.append(len(results)),
        )
        for _ in range(3)
    ]
    leaf = session.submit(prog, mode=OffloadMode.ROI, deps=mids)
    session.close()  # must drain root -> mids -> leaf, then release
    for h in [root, *mids, leaf]:
        res = h.result(timeout=60)
        np.testing.assert_allclose(res.output, ref, rtol=1e-5, atol=1e-5)
    assert seen == [1, 1, 1]  # every mid's feed saw its predecessor
    assert len(session._pending) == 0 and session._inflight == 0
    s = session.arena_stats
    assert s.entries == 0 and s.bytes_total == 0


def test_close_is_idempotent_and_arena_closed():
    session = EngineSession(devices3())
    session.close()
    session.close()
    with pytest.raises(RuntimeError, match="closed"):
        session.arena.acquire("p", "host", (4,), np.float32)
