"""The tiered co-execution API: Tier-1 coexec, Tier-2 EngineSession +
RunHandles, Tier-3 extension points."""

import numpy as np
import pytest

from repro.api import (BufferPolicy, CancelledError, DevicePolicy,
                       EngineSession, Program, StaticDevicePolicy,
                       available_schedulers, coexec, register_scheduler,
                       scheduler_accepts, unregister_scheduler)
from repro.core import programs as P
from repro.core.device import DeviceGroup
from repro.core.scheduler import DynamicScheduler


def devices3():
    return [DeviceGroup("cpu", throttle=3.0),
            DeviceGroup("igpu", throttle=1.5),
            DeviceGroup("gpu", throttle=1.0)]


BINOMIAL_KW = dict(n_options=2048)


@pytest.fixture(scope="module")
def binomial_ref():
    return P.reference_output("binomial", **BINOMIAL_KW)


# ------------------------------------------------------------------ Tier-1

def test_coexec_single_call_exact(binomial_ref):
    res = coexec(P.PROGRAMS["binomial"](**BINOMIAL_KW), devices3())
    np.testing.assert_allclose(res.output, binomial_ref,
                               rtol=1e-5, atol=1e-5)
    assert res.total_time > 0
    assert res.binary_time >= res.total_time


def test_coexec_discovers_devices(binomial_ref):
    # devices=None -> DevicePolicy discovery (one group per JAX device)
    res = coexec(P.PROGRAMS["binomial"](**BINOMIAL_KW))
    np.testing.assert_allclose(res.output, binomial_ref,
                               rtol=1e-5, atol=1e-5)


def test_coexec_per_packet_buffer_policy(binomial_ref):
    res = coexec(P.PROGRAMS["binomial"](**BINOMIAL_KW), devices3(),
                 buffer_policy=BufferPolicy.PER_PACKET)
    np.testing.assert_allclose(res.output, binomial_ref,
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- Tier-2: sessions

def test_submit_bit_identical_to_blocking_coexec():
    """Acceptance: async RunHandle results == blocking Tier-1 run, bitwise."""
    prog = P.PROGRAMS["binomial"](**BINOMIAL_KW)
    blocking = coexec(prog, devices3())
    with EngineSession(devices3()) as session:
        async_res = session.submit(prog).result()
    assert np.array_equal(async_res.output, blocking.output)


def test_session_pays_init_cost_once_across_submits():
    """Acceptance: two consecutive submits of one program pay init_cost_s
    at most once (per device), amortized by the executable cache."""
    prog = P.PROGRAMS["binomial"](**BINOMIAL_KW)
    with EngineSession(devices3(), init_cost_s=0.05) as session:
        r1 = session.submit(prog).result()
        r2 = session.submit(prog).result()
        assert session.init_payments == 3          # once per device
        assert set(session.executables) == {("binomial", d) for d in
                                            ("cpu", "igpu", "gpu")}
        assert all(v == 1 for v in session.buffer_registry.values())
    # warm run must not pay the 3 x 50 ms init again
    assert r2.binary_time < r1.binary_time
    assert r2.binary_time < 0.15
    assert np.array_equal(r1.output, r2.output)


def test_session_multi_program_cache_keys(binomial_ref):
    gauss_kw = dict(h=256, w=128)
    gauss_ref = P.reference_output("gaussian", **gauss_kw)
    with EngineSession(devices3()) as session:
        rb = session.run(P.PROGRAMS["binomial"](**BINOMIAL_KW))
        rg = session.run(P.PROGRAMS["gaussian"](**gauss_kw))
        # one cache entry per (program, device): 2 programs x 3 devices
        assert session.init_payments == 6
        session.run(P.PROGRAMS["binomial"](**BINOMIAL_KW))
        assert session.init_payments == 6          # still warm
        keys = set(session.executables)
    assert {k[0] for k in keys} == {"binomial", "gaussian"}
    np.testing.assert_allclose(rb.output, binomial_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(rg.output, gauss_ref, rtol=1e-5, atol=1e-5)


def test_run_handles_overlap_and_done(binomial_ref):
    with EngineSession(devices3()) as session:
        prog = P.PROGRAMS["binomial"](**BINOMIAL_KW)
        handles = [session.submit(prog) for _ in range(3)]
        # submits are non-blocking; results arrive in order
        for h in handles:
            res = h.result(timeout=60)
            assert h.done() and not h.cancelled()
            np.testing.assert_allclose(res.output, binomial_ref,
                                       rtol=1e-5, atol=1e-5)


def test_run_handle_cancel_queued():
    prog = P.PROGRAMS["binomial"](**BINOMIAL_KW)
    with EngineSession(devices3(), init_cost_s=0.2) as session:
        h1 = session.submit(prog)          # holds the dispatcher >= 0.2 s
        h2 = session.submit(prog)
        assert h2.cancel()                 # still queued behind h1
        assert not h2.cancel()             # second cancel is a no-op
        r1 = h1.result()
        assert r1.total_time > 0
        assert h2.cancelled() and h2.done()
        with pytest.raises(CancelledError):
            h2.result()
    # cancelling a completed handle is a no-op
    assert not h1.cancel()


def test_cancel_queued_removes_submission_without_paying_init():
    """Regression: cancelling a not-yet-dispatched submission must remove
    it from the session queue immediately — done() flips right away, the
    dispatcher never claims it, and no init is paid for it."""
    slow = P.PROGRAMS["binomial"](**BINOMIAL_KW)

    def build(dev):
        def fn(offset, size):  # pragma: no cover - must never run
            raise AssertionError("cancelled submission was dispatched")
        return fn

    doomed = Program("doomed", 16, 1, build)
    with EngineSession(devices3(), init_cost_s=0.2) as session:
        h1 = session.submit(slow)          # occupies the dispatcher
        h2 = session.submit(doomed)
        assert len(session._pending) >= 1    # doomed is queued
        assert h2.cancel()
        assert h2.done() and h2.cancelled()      # flips immediately...
        assert all(s.handle is not h2 for s in session._pending)  # ...and gone
        h1.result()
        h3 = session.submit(slow)          # queue still serviceable
        h3.result()
        # the cancelled program's executables were never built: init was
        # paid only for the real program (once per device)
        assert all(k[0] != "doomed" for k in session.executables)
        assert session.init_payments == 3


def test_session_elastic_membership(binomial_ref):
    prog = P.PROGRAMS["binomial"](**BINOMIAL_KW)
    with EngineSession(devices3()[:2]) as session:
        session.run(prog)
        session.add_device(DeviceGroup("late", throttle=1.0))
        r2 = session.run(prog)
        assert len(r2.device_busy) == 3
        np.testing.assert_allclose(r2.output, binomial_ref,
                                   rtol=1e-5, atol=1e-5)
        session.remove_device("late")
        assert ("binomial", "late") not in session.executables
        r3 = session.run(prog)
        assert len(r3.device_busy) == 2
        np.testing.assert_allclose(r3.output, binomial_ref,
                                   rtol=1e-5, atol=1e-5)
        with pytest.raises(ValueError):
            session.add_device(DeviceGroup("cpu"))   # duplicate name


def test_session_closed_rejects_submits():
    session = EngineSession(devices3())
    session.close()
    with pytest.raises(RuntimeError, match="closed"):
        session.submit(P.PROGRAMS["binomial"](**BINOMIAL_KW))
    session.close()                                  # idempotent


def test_session_run_error_surfaces_on_handle():
    def build(dev):
        def fn(offset, size):
            raise RuntimeError("executor exploded")
        return fn

    bad = Program("bad_kernel", 16, 1, build)
    with EngineSession(devices3()) as session:
        handle = session.submit(bad)
        with pytest.raises(RuntimeError, match="unprocessed"):
            handle.result()
        assert isinstance(handle.exception(), RuntimeError)


def test_commit_path_error_absorbed_by_survivors(binomial_ref):
    """A mis-shaped result must kill only the offending device (packet
    requeued, device dead), never hang the run — and the session's thread
    pool must stay serviceable afterwards."""
    import numpy as _np

    def build(dev):
        def fn(offset, size):
            if dev.name == "gpu":
                return _np.zeros(3)          # wrong shape -> reshape raises
            return _np.full((size, 1), float(offset), _np.float32)
        return fn

    prog = Program("badshape", 64, 1, build)
    with EngineSession(devices3()) as session:
        res = session.submit(prog).result(timeout=60)
        assert res.aborted_devices == 1
        assert sum(p.size for p in res.packets) == 64
        # pool not poisoned: the next submit completes normally
        res2 = session.run(P.PROGRAMS["binomial"](**BINOMIAL_KW))
        np.testing.assert_allclose(res2.output, binomial_ref,
                                   rtol=1e-5, atol=1e-5)


def test_ephemeral_submits_do_not_grow_registries():
    def build(dev):
        def fn(offset, size):
            return np.zeros((size, 1), np.float32)
        return fn

    with EngineSession(devices3()) as session:
        for i in range(5):
            session.submit(Program(f"ephemeral{i}", 8, 1, build),
                           cache=False).result()
        assert session.executables == {}
        assert session.buffer_registry == {}
        assert session.init_payments == 15   # built, never cached


# --------------------------------------------- Program.build validation

def test_program_build_required_clear_error():
    unbuildable = Program("nobuild", 16, 1)
    with pytest.raises(ValueError, match="'build' must be a callable"):
        coexec(unbuildable, devices3())
    with EngineSession(devices3()) as session:
        with pytest.raises(ValueError, match="'build' must be a callable"):
            session.submit(unbuildable)
    with pytest.raises(ValueError, match="total_work"):
        Program("empty", 0, 1, lambda dev: (lambda o, s: None)).validate()


# -------------------------------------------------- Tier-3: extensions

class _EveryFour(DynamicScheduler):
    """Toy plugin: fixed 4-packet dynamic split."""

    def __init__(self, total_work, lws, devices, n_packets=4):
        super().__init__(total_work, lws, devices, n_packets=n_packets)


def test_register_scheduler_plugin(binomial_ref):
    register_scheduler("every4", _EveryFour, defaults={"n_packets": 4})
    try:
        assert "every4" in available_schedulers()
        res = coexec(P.PROGRAMS["binomial"](**BINOMIAL_KW), devices3(),
                     scheduler="every4")
        np.testing.assert_allclose(res.output, binomial_ref,
                                   rtol=1e-5, atol=1e-5)
    finally:
        unregister_scheduler("every4")
    assert "every4" not in available_schedulers()
    with pytest.raises(KeyError, match="unknown scheduler"):
        coexec(P.PROGRAMS["binomial"](**BINOMIAL_KW), devices3(),
               scheduler="every4")


def test_register_scheduler_guards():
    with pytest.raises(ValueError, match="already registered"):
        register_scheduler("static", DynamicScheduler)
    with pytest.raises(TypeError):
        register_scheduler("not_a_scheduler", dict)


def test_scheduler_capability_probe():
    assert scheduler_accepts("hguided_deadline", "slack_s")
    assert not scheduler_accepts("static", "slack_s")
    assert scheduler_accepts("static", "reverse")


def test_scheduler_capability_probe_sees_through_kwargs():
    from repro.core.scheduler import HGuidedDeadlineScheduler

    class Passthrough(HGuidedDeadlineScheduler):
        def __init__(self, total_work, lws, devices, **kw):
            super().__init__(total_work, lws, devices, **kw)

    register_scheduler("ddl_plugin", Passthrough)
    try:
        # slack_s lives on the base __init__; the **kw shim must not hide it
        assert scheduler_accepts("ddl_plugin", "slack_s")
        assert not scheduler_accepts("ddl_plugin", "n_packets")
    finally:
        unregister_scheduler("ddl_plugin")


def test_bad_scheduler_kwargs_error_does_not_wedge_session(binomial_ref):
    """make_scheduler raising mid-dispatch must release the barrier-parked
    device threads and leave the session serviceable."""
    prog = P.PROGRAMS["binomial"](**BINOMIAL_KW)
    with EngineSession(devices3()) as session:
        bad = session.submit(prog, scheduler="static",
                             scheduler_kwargs={"n_packets": 8})
        with pytest.raises(TypeError):
            bad.result(timeout=60)
        res = session.run(prog)        # pool threads were not wedged
        np.testing.assert_allclose(res.output, binomial_ref,
                                   rtol=1e-5, atol=1e-5)


def test_scheduler_override_drops_session_kwargs(binomial_ref):
    # session-level kwargs are tuned for the session scheduler; a per-submit
    # override must not inherit them
    with EngineSession(devices3(), scheduler="dynamic",
                       scheduler_kwargs={"n_packets": 16}) as session:
        prog = P.PROGRAMS["binomial"](**BINOMIAL_KW)
        res = session.submit(prog, scheduler="static").result(timeout=60)
        np.testing.assert_allclose(res.output, binomial_ref,
                                   rtol=1e-5, atol=1e-5)


def test_device_policy_hook(binomial_ref):
    class ReversedFleet(DevicePolicy):
        def discover(self):
            return devices3()

        def order(self, devices):
            return sorted(devices, key=lambda d: d.name, reverse=True)

    with EngineSession(device_policy=ReversedFleet()) as session:
        assert [d.name for d in session.devices] == ["igpu", "gpu", "cpu"]
        res = session.run(P.PROGRAMS["binomial"](**BINOMIAL_KW))
    np.testing.assert_allclose(res.output, binomial_ref,
                               rtol=1e-5, atol=1e-5)


def test_static_device_policy_fixed_fleet():
    policy = StaticDevicePolicy(devices3())
    with EngineSession(device_policy=policy) as session:
        assert [d.name for d in session.devices] == ["cpu", "igpu", "gpu"]


# ------------------------------------------------ provenance through API

def test_retried_packets_keep_seq_and_flag():
    prog = P.PROGRAMS["gaussian"](h=1024, w=128)
    devs = devices3()
    devs[2].fail_after = 0          # gpu dies on its first packet
    res = coexec(prog, devs, scheduler="static")
    assert res.aborted_devices == 1
    assert res.retries >= 1
    seqs = [p.seq for p in res.packets]
    # provenance: no fresh seq minted for requeues -> all seqs unique and
    # within the carved range
    assert len(seqs) == len(set(seqs))
    assert any(p.retried for p in res.packets)
    assert sum(p.size for p in res.packets) == prog.total_work
