"""EDF admission extraction: the shared policy object must reproduce the
inline procedures it replaced, at both attachment points.

* unit behavior: EDF ordering, quantum leftover, shed/degrade/none
  policies, calibration gate, residual-aware prediction;
* regression lock (simulator): ``simulate_serving(..., admission=...)``
  with the matching config is BIT-IDENTICAL to the inline path — same
  finish, shed and replica on every request;
* regression lock (server semantics): with ``unit_work=True`` the object
  makes exactly the decisions the old ``CoexecServer._admit`` made
  (shed bookkeeping through ``completed``, degrade token scaling).
"""
import math

import numpy as np
import pytest

from repro.core.simulate import SimConfig, SimDevice, simulate_serving
from repro.serve import (AdmissionConfig, EdfAdmission, make_requests,
                         poisson_arrivals)
from repro.serve.admission import sequence_total
from repro.serve.workload import Request


def _req(rid, arrival, deadline, size=1):
    return Request(rid=rid, arrival=arrival, deadline=deadline, size=size)


# ------------------------------------------------------------------ config

def test_bad_policy_rejected():
    with pytest.raises(ValueError, match="admission policy"):
        AdmissionConfig(policy="drop")
    with pytest.raises(ValueError):
        EdfAdmission(policy="yolo")


def test_kwargs_constructor_matches_config():
    a = EdfAdmission(policy="degrade", gen=8, min_gen=2)
    assert a.cfg == AdmissionConfig(policy="degrade", gen=8, min_gen=2)


# ------------------------------------------------------------ unit behavior

def test_edf_order_and_gen_reset():
    adm = EdfAdmission(policy="none", gen=4)
    pending = [_req(0, 0.0, 9.0), _req(1, 0.0, 1.0), _req(2, 0.0, 5.0)]
    admitted, leftover = adm.admit(pending, 0.0, total_power=1.0)
    assert [r.rid for r in admitted] == [1, 2, 0]
    assert leftover == []
    assert all(r.gen_alloc == 4 for r in admitted)


def test_quantum_leftover_and_first_fit():
    # power 1 wg/s, quantum 2 s => 2 wg per round; the first request
    # always admits even if it alone exceeds the cap
    adm = EdfAdmission(policy="none", round_quantum_s=2.0)
    pending = [_req(0, 0.0, 100.0, size=5), _req(1, 0.0, 101.0, size=1),
               _req(2, 0.0, 102.0, size=1)]
    admitted, leftover = adm.admit(pending, 0.0, total_power=1.0)
    assert [r.rid for r in admitted] == [0]
    assert [r.rid for r in leftover] == [1, 2]


def test_uncalibrated_admits_everything():
    adm = EdfAdmission(policy="shed")
    pending = [_req(0, 0.0, 1e-9, size=100)]      # hopeless deadline
    admitted, _ = adm.admit(pending, 0.0, total_power=1.0,
                            calibrated=False)
    assert [r.rid for r in admitted] == [0]
    assert not admitted[0].shed


def test_shed_frees_queue_behind_and_completed_bookkeeping():
    # power 1 wg/s: r0 (10 wg, deadline 1s) is doomed; shedding it must
    # let r1 (1 wg, deadline 2s) admit — and the shed request moves to
    # completed with finish=None (the threaded server's contract)
    adm = EdfAdmission(policy="shed")
    completed = []
    pending = [_req(0, 0.0, 1.0, size=10), _req(1, 0.0, 2.0, size=1)]
    admitted, _ = adm.admit(pending, 0.0, total_power=1.0,
                            completed=completed)
    assert [r.rid for r in admitted] == [1]
    assert not admitted[0].shed
    assert [r.rid for r in completed] == [0]
    assert completed[0].shed and completed[0].finish is None


def test_residual_pushes_predictions_out():
    adm = EdfAdmission(policy="shed")
    pending = [_req(0, 0.0, 2.0, size=1)]
    admitted, _ = adm.admit(pending, 0.0, total_power=1.0)
    assert admitted and not pending[0].shed       # 1s < 2s: feasible
    pending = [_req(1, 0.0, 2.0, size=1)]
    admitted, _ = adm.admit(pending, 0.0, total_power=1.0,
                            residual_wg=5.0)      # 6s > 2s: doomed
    assert admitted == [] and pending[0].shed


def test_degrade_scales_generation_never_drops():
    # old _admit math: slack=1, pred-now=2 => frac 0.5 => gen 8 of 16
    adm = EdfAdmission(policy="degrade", gen=16, min_gen=1, unit_work=True)
    pending = [_req(0, 0.0, 1.0), _req(1, 0.0, 1.0)]
    admitted, _ = adm.admit(pending, 0.0, total_power=1.0)
    assert [r.rid for r in admitted] == [0, 1]
    assert admitted[0].gen_alloc == 16 and not admitted[0].degraded
    assert admitted[1].gen_alloc == 8 and admitted[1].degraded
    # already-late work floors at min_gen, never sheds
    late = [_req(2, 0.0, -1.0)]
    admitted, _ = adm.admit(late, 0.0, total_power=1.0)
    assert admitted[0].gen_alloc == 1 and admitted[0].degraded


def test_unit_work_vs_size_pricing():
    pending = [_req(0, 0.0, 3.0, size=100)]
    # unit pricing: 1 unit / 1 power = 1s < 3s => admit
    adm_u = EdfAdmission(policy="shed", unit_work=True)
    admitted, _ = adm_u.admit(pending, 0.0, total_power=1.0)
    assert admitted and not pending[0].shed
    # size pricing: 100 wg / 1 wg/s = 100s > 3s => shed
    pending = [_req(1, 0.0, 3.0, size=100)]
    adm_s = EdfAdmission(policy="shed", unit_work=False)
    admitted, _ = adm_s.admit(pending, 0.0, total_power=1.0)
    assert admitted == [] and pending[0].shed


def test_zero_power_admits_unfiltered():
    adm = EdfAdmission(policy="shed", round_quantum_s=0.5)
    pending = [_req(0, 0.0, 1e-9, size=9), _req(1, 0.0, 1e-9, size=9)]
    admitted, leftover = adm.admit(pending, 0.0, total_power=0.0)
    assert len(admitted) == 2 and leftover == []
    assert not any(r.shed for r in admitted)


def test_sequence_total():
    reqs = [_req(0, 0, 1, size=3), _req(1, 0, 1, size=4)]
    assert sequence_total(reqs, unit_work=True) == 2.0
    assert sequence_total(reqs, unit_work=False) == 7.0


# ------------------------------------- simulator hook: bit-identical lock

def _fleet(seed=0):
    return [
        SimDevice("cpu", 30.0, launch_overhead=1e-3, jitter=0.05),
        SimDevice("gpu", 100.0, launch_overhead=1e-3, jitter=0.05,
                  profile_bias=0.8),
        SimDevice("igpu", 55.0, launch_overhead=1e-3, jitter=0.05),
    ]


@pytest.mark.parametrize("quantum", [math.inf, 0.08])
@pytest.mark.parametrize("sched", ["hguided_opt", "static"])
def test_sim_admission_hook_bit_identical(sched, quantum):
    rng = np.random.default_rng(3)
    arrivals = poisson_arrivals(250, 260.0, rng)   # ~1.4x fleet capacity

    def run(admission):
        reqs = make_requests(arrivals, slo=0.15, size=1)
        cfg = SimConfig(scheduler=sched, opt_init=True, opt_buffers=True,
                        host_cost_per_packet=1e-4, seed=7)
        res = simulate_serving(reqs, 1, _fleet(), cfg, policy="shed",
                               batch_window_s=0.02,
                               round_quantum_s=quantum,
                               admission=admission)
        return reqs, res

    inline_reqs, inline_res = run(None)
    hook = EdfAdmission(policy="shed", round_quantum_s=quantum,
                        unit_work=False)
    hook_reqs, hook_res = run(hook)

    assert inline_res.rounds == hook_res.rounds
    assert any(r.shed for r in inline_reqs)       # the lock is non-trivial
    for a, b in zip(inline_reqs, hook_reqs):
        assert (a.rid, a.shed, a.finish, a.replica) \
            == (b.rid, b.shed, b.finish, b.replica)


def test_sim_admission_none_policy_identical():
    rng = np.random.default_rng(1)
    arrivals = poisson_arrivals(120, 80.0, rng)

    def run(admission, policy):
        reqs = make_requests(arrivals, slo=0.5, size=1)
        cfg = SimConfig(scheduler="hguided_opt", opt_init=True,
                        opt_buffers=True, seed=2)
        simulate_serving(reqs, 1, _fleet(), cfg, policy=policy,
                         admission=admission)
        return reqs

    inline = run(None, "none")
    hooked = run(EdfAdmission(policy="none"), "none")
    for a, b in zip(inline, hooked):
        assert (a.shed, a.finish, a.replica) == (b.shed, b.finish, b.replica)
