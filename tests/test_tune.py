"""Autotuner tests: cache durability, fingerprint keying, fit math,
the search's structural never-worse guarantee, and session plumbing."""
import json
import os
from types import SimpleNamespace

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import EngineSession
from repro.core.device import DeviceGroup
from repro.core.membuf import TransferPipeline
from repro.core.scheduler import DeviceProfile, DynamicScheduler
from repro.tune import (Calibration, DeviceCalibration, Measurements,
                        TuneCache, TunedConfig, autotune, calibrate,
                        crossover_bytes, device_fingerprint, resolve_tuned,
                        search)
from repro.tune.calibrate import fit_line
from repro.tune.search import DEFAULT_N_PACKETS

FLEET = [DeviceGroup("d0", throttle=1.0), DeviceGroup("d1", throttle=2.0)]


def make_calibration(throughputs=(1e5, 5e4), overhead_s=1e-4,
                     sched_overhead_s=2e-4, wake_s=2e-4):
    return Calibration(
        kernels={"k": {f"d{i}": DeviceCalibration(tp, overhead_s)
                       for i, tp in enumerate(throughputs)}},
        sched_overhead_s=sched_overhead_s, wake_cost_s=wake_s,
        transfer_base_s=1e-6, transfer_s_per_byte=1e-10)


def make_config(**kw):
    base = dict(kernel="k", scheduler="dynamic",
                scheduler_kwargs={"n_packets": 16}, lws=8,
                lease_overhead_s=1e-4, lease_overhead_frac=0.05,
                lease_k_max=32, async_threshold_bytes=1 << 16,
                predicted_s=0.5, predicted_default_s=1.0)
    base.update(kw)
    return TunedConfig(**base)


# -- cache roundtrip -------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    path = tmp_path / "cache.json"
    cal = make_calibration()
    cfg = make_config()
    fp = device_fingerprint(FLEET)
    cache = TuneCache(path)
    cache.put_calibration(fp, cal)
    cache.put_winner(fp, "k", cfg)

    fresh = TuneCache(path)                 # re-read from disk
    got_cal = fresh.get_calibration(fp)
    assert got_cal is not None
    assert got_cal.to_dict() == cal.to_dict()
    got = fresh.get_winner(fp, "k")
    assert got == cfg
    assert fresh.winners(fp) == {"k": cfg}


def test_tuned_config_dict_roundtrip():
    cfg = make_config()
    assert TunedConfig.from_dict(cfg.to_dict()) == cfg
    # unknown keys from a newer writer are dropped, not fatal
    d = cfg.to_dict()
    d["shiny_new_field"] = 42
    assert TunedConfig.from_dict(d) == cfg


def test_cache_tolerates_corrupt_and_torn_files(tmp_path):
    fp = device_fingerprint(FLEET)
    for blob in ("not json at all", '{"version": 1, "entries": {',  # torn
                 '[1, 2, 3]', '{"version": 99, "entries": {}}',
                 '{"entries": "nope", "version": 1}'):
        path = tmp_path / "cache.json"
        path.write_text(blob)
        cache = TuneCache(path)
        assert cache.get_calibration(fp) is None
        assert cache.get_winner(fp, "k") is None
        # the next store rewrites the file cleanly
        cache.put_winner(fp, "k", make_config())
        assert TuneCache(path).get_winner(fp, "k") == make_config()


def test_cache_tolerates_missing_file_and_garbage_entry(tmp_path):
    path = tmp_path / "nope" / "cache.json"
    cache = TuneCache(path)                 # parent dir doesn't exist yet
    fp = device_fingerprint(FLEET)
    assert cache.get_winner(fp, "k") is None
    cache.put_winner(fp, "k", make_config())
    assert os.path.exists(path)
    # a hand-mangled winner entry degrades to a miss, not a crash
    raw = json.loads(path.read_text())
    raw["entries"][fp]["winners"]["k"] = "garbage"
    path.write_text(json.dumps(raw))
    assert TuneCache(path).get_winner(fp, "k") is None


# -- fingerprint invalidation ----------------------------------------------

def test_fingerprint_order_insensitive_but_fleet_sensitive():
    fp = device_fingerprint(FLEET)
    assert fp == device_fingerprint(FLEET[::-1])
    bigger = FLEET + [DeviceGroup("d2", throttle=4.0)]
    assert fp != device_fingerprint(bigger)
    rethrottled = [DeviceGroup("d0", throttle=1.0),
                   DeviceGroup("d1", throttle=3.0)]
    assert fp != device_fingerprint(rethrottled)


def test_different_fleet_misses_cached_winner(tmp_path):
    path = tmp_path / "cache.json"
    cache = TuneCache(path)
    cache.put_winner(device_fingerprint(FLEET), "k", make_config())
    other = FLEET + [DeviceGroup("d2", throttle=4.0)]
    assert cache.get_winner(device_fingerprint(other), "k") is None
    assert resolve_tuned(cache, devices=other, kernel="k") is None


# -- fit + crossover math --------------------------------------------------

def test_fit_line_recovers_synthetic_line():
    intercept, slope = fit_line({n: 1e-3 + n / 1e5
                                 for n in (64, 128, 256, 512)})
    assert intercept == pytest.approx(1e-3, rel=1e-6)
    assert 1.0 / slope == pytest.approx(1e5, rel=1e-6)


def test_crossover_branches():
    assert crossover_bytes(0.0, 0.0, 1e-4) == 256 << 10   # degenerate fit
    assert crossover_bytes(1e-3, 1e-9, 1e-4) == 0         # wake always wins
    assert crossover_bytes(0.0, 1e-9, 1e-4) == 100_000    # intersection


def test_calibrate_builds_terms_from_measurements():
    m = Measurements(
        kernels={"k": {"d0": {64: 1e-3 + 64 / 1e5, 256: 1e-3 + 256 / 1e5}}},
        crossing_s=3e-4, wake_s=1e-4,
        copy_s={1 << 10: 2e-6, 1 << 20: 1e-3}, n_timed_runs=10)
    cal = calibrate(m)
    assert cal.sched_overhead_s == pytest.approx(3e-4)
    assert cal.kernels["k"]["d0"].throughput == pytest.approx(1e5, rel=1e-6)
    assert cal.kernels["k"]["d0"].overhead_s == pytest.approx(1e-3, rel=1e-6)
    assert cal.transfer_s_per_byte > 0


# -- the search's structural guarantee -------------------------------------

@settings(max_examples=10, deadline=None)
@given(tp0=st.floats(1e3, 1e7), ratio=st.floats(1.0, 8.0),
       overhead=st.floats(0.0, 1e-3), crossing=st.floats(1e-6, 1e-3))
def test_search_winner_never_worse_than_defaults(tp0, ratio, overhead,
                                                 crossing):
    """Whatever the calibration says, the simulated winner is at least as
    good as the hand-picked defaults — the defaults are in the grid."""
    cal = make_calibration(throughputs=(tp0, tp0 / ratio),
                           overhead_s=overhead, sched_overhead_s=crossing,
                           wake_s=crossing)
    res = search(cal, "k", total_work=4096, lws=8, seeds=1)
    assert res.winner.predicted_s <= res.default.predicted_s
    assert res.default.scheduler_kwargs == {"n_packets": DEFAULT_N_PACKETS}
    assert res.predicted_gain_pct >= 0.0


# -- knob plumbing: scheduler, pipeline, session ---------------------------

def test_set_lease_params_validates_and_applies():
    sched = DynamicScheduler(1024, 8, [DeviceProfile("d0", 1.0)])
    out = sched.set_lease_params(lease_overhead_s=1e-3,
                                 lease_overhead_frac=0.1, lease_k_max=7)
    assert out is sched
    assert (sched.lease_overhead_s, sched.lease_overhead_frac,
            sched.lease_k_max) == (1e-3, 0.1, 7)
    # None leaves the class default in place
    sched2 = DynamicScheduler(1024, 8, [DeviceProfile("d0", 1.0)])
    sched2.set_lease_params(lease_k_max=9)
    assert sched2.lease_overhead_s == type(sched2).lease_overhead_s
    assert sched2.lease_k_max == 9
    for bad in (dict(lease_overhead_s=0.0), dict(lease_overhead_frac=0.0),
                dict(lease_overhead_frac=1.5), dict(lease_k_max=0)):
        with pytest.raises(ValueError):
            DynamicScheduler(1024, 8, [DeviceProfile("d0", 1.0)]
                             ).set_lease_params(**bad)


def test_transfer_pipeline_threshold_param():
    # the threshold is resolved and validated before the pool is touched
    assert TransferPipeline(None).async_threshold_bytes == \
        TransferPipeline.DEFAULT_ASYNC_THRESHOLD_BYTES
    assert TransferPipeline(None, 4096).async_threshold_bytes == 4096
    with pytest.raises(ValueError):
        TransferPipeline(None, -1)


def test_session_applies_tuned_config():
    cfg = make_config()
    with EngineSession(FLEET, tuned=cfg) as s:
        assert s.scheduler == "dynamic"
        assert s.scheduler_kwargs == {"n_packets": 16}
        assert s.lease_params == cfg.lease_params()
        assert s.async_threshold_bytes == 1 << 16
        assert s.tuned is cfg


def test_session_explicit_kwargs_beat_tuned():
    cfg = make_config()
    with EngineSession(FLEET, scheduler="static", lease_k_max=64,
                       tuned=cfg) as s:
        assert s.scheduler == "static"          # user choice wins
        assert s.scheduler_kwargs == {}         # tuned kwargs not grafted
        assert s.lease_params["lease_k_max"] == 64
        assert s.lease_params["lease_overhead_frac"] == 0.05  # still tuned
    with EngineSession(FLEET) as s:
        assert s.scheduler == "hguided_opt"     # untuned default unchanged
        assert s.lease_params is None


def test_resolve_tuned_forms(tmp_path):
    cfg = make_config()
    assert resolve_tuned(None) is None
    assert resolve_tuned(False) is None
    assert resolve_tuned(cfg) is cfg
    assert resolve_tuned(cfg.to_dict()) == cfg
    cfg_path = tmp_path / "cfg.json"
    cfg_path.write_text(json.dumps(cfg.to_dict()))
    assert resolve_tuned(str(cfg_path)) == cfg
    cache_path = tmp_path / "cache.json"
    cache = TuneCache(cache_path)
    cache.put_winner(device_fingerprint(FLEET), "k", cfg)
    assert resolve_tuned(str(cache_path), devices=FLEET, kernel="k") == cfg
    # sole stored winner resolves even without a kernel name
    assert resolve_tuned(cache, devices=FLEET) == cfg
    with pytest.raises(TypeError):
        resolve_tuned(12345)


# -- the closed loop, with injected measurements ---------------------------

def fake_measure(devices, programs, rounds=7, **_):
    m = Measurements(crossing_s=2e-4, wake_s=1e-4,
                     copy_s={1 << 10: 2e-6, 1 << 20: 1e-3})
    for kernel in programs:
        m.kernels[kernel] = {
            d.name: {64: 1e-3 + 64 / (1e5 / d.throttle),
                     256: 1e-3 + 256 / (1e5 / d.throttle)}
            for d in devices}
        m.n_timed_runs += 2 * len(devices) * rounds
    return m


def test_autotune_cache_flow(tmp_path):
    path = tmp_path / "cache.json"
    progs = {"k": SimpleNamespace(total_work=4096, lws=8)}
    rep1 = autotune(FLEET, progs, "k", cache=TuneCache(path),
                    measure_fn=fake_measure)
    assert rep1.microbenches_run > 0 and not rep1.cache_hit_winner
    assert rep1.config.predicted_s <= rep1.config.predicted_default_s

    rep2 = autotune(FLEET, progs, "k", cache=TuneCache(path),
                    measure_fn=fake_measure)
    assert rep2.cache_hit_winner and rep2.microbenches_run == 0
    assert rep2.config == rep1.config

    # a second kernel on the warm cache reuses the HOST terms but must
    # measure its own compute fit — and must not evict kernel 1's
    progs2 = {"k2": SimpleNamespace(total_work=8192, lws=8)}
    autotune(FLEET, progs2, "k2", cache=TuneCache(path),
             measure_fn=fake_measure)
    warm = TuneCache(path)
    fp = device_fingerprint(FLEET)
    assert set(warm.get_calibration(fp).kernels) == {"k", "k2"}
    assert warm.get_winner(fp, "k") == rep1.config

    # corrupting the file forces a clean re-measure, not a crash
    path.write_text("garbage{")
    rep3 = autotune(FLEET, progs, "k", cache=TuneCache(path),
                    measure_fn=fake_measure)
    assert rep3.microbenches_run > 0 and not rep3.cache_hit_winner
    assert rep3.config == rep1.config       # same measurements, same answer


def test_autotune_unknown_kernel_raises(tmp_path):
    with pytest.raises(KeyError):
        autotune(FLEET, {"k": SimpleNamespace(total_work=64, lws=1)},
                 "other", cache=TuneCache(tmp_path / "c.json"),
                 measure_fn=fake_measure)
