"""Heterogeneity-aware data-parallel training (core/hetero_dp.py):
convergence, straggler-proportional row assignment, failure absorption,
elastic membership, compression path."""
import jax

from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.core.device import DeviceGroup
from repro.core.hetero_dp import HeteroDPTrainer
from repro.data.pipeline import SyntheticPipeline
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.adamw import OptConfig

SHAPE = ShapeConfig("tiny", seq_len=32, global_batch=16, kind="train")


def make_trainer(devices, **kw):
    cfg = get_smoke("llama3.2-1b")
    pipeline = SyntheticPipeline(cfg, SHAPE)
    opt = OptConfig(lr=2e-3, warmup_steps=1, total_steps=100)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    state = adamw.init_state(params, opt)
    trainer = HeteroDPTrainer(cfg, opt, SHAPE, devices, pipeline, **kw)
    return trainer, state


def test_training_loss_decreases():
    devs = [DeviceGroup("a", throttle=1.0), DeviceGroup("b", throttle=2.0)]
    trainer, state = make_trainer(devs)
    losses = []
    for i in range(6):
        state, rep = trainer.step(state, i)
        losses.append(rep.loss)
        assert rep.tokens == SHAPE.global_batch * SHAPE.seq_len
    assert losses[-1] < losses[0]


def test_rows_proportional_to_speed():
    devs = [DeviceGroup("fast", throttle=1.0),
            DeviceGroup("slow", throttle=4.0)]
    trainer, state = make_trainer(devs)
    total = {"fast": 0, "slow": 0}
    for i in range(4):
        state, rep = trainer.step(state, i)
        for k, v in rep.device_rows.items():
            total[k] += v
    # the fast group must do more rows (straggler mitigation)
    assert total["fast"] > total["slow"]


def test_failure_mid_training_absorbed():
    devs = [DeviceGroup("a", throttle=1.0),
            DeviceGroup("b", throttle=1.0, fail_after=1)]
    trainer, state = make_trainer(devs)
    state, rep = trainer.step(state, 0)      # b dies after 1 packet
    assert rep.failures == 1
    assert rep.tokens == SHAPE.global_batch * SHAPE.seq_len   # full batch
    # next step continues on the survivor only
    state, rep2 = trainer.step(state, 1)
    assert rep2.tokens == SHAPE.global_batch * SHAPE.seq_len


def test_elastic_add_remove():
    devs = [DeviceGroup("a", throttle=1.0)]
    trainer, state = make_trainer(devs)
    state, rep1 = trainer.step(state, 0)
    trainer.add_device(DeviceGroup("b", throttle=1.0))
    state, rep2 = trainer.step(state, 1)
    assert set(rep2.device_rows) == {"a", "b"}
    trainer.remove_device("b")
    state, rep3 = trainer.step(state, 2)
    assert set(rep3.device_rows) == {"a"}


def test_compressed_gradients_still_learn():
    devs = [DeviceGroup("a", throttle=1.0)]
    trainer, state = make_trainer(devs, compress=True)
    losses = []
    for i in range(6):
        state, rep = trainer.step(state, i)
        losses.append(rep.loss)
    assert losses[-1] < losses[0]
