"""Graph-invariant property suite for DAG-structured submits.

The session's ready-set dispatcher must preserve, for ANY graph shape
(chains, diamonds, fan-in/fan-out), any registered scheduler and any
inflight width — with or without injected device deaths:

  (a) topological execution order: a node's ``feed`` runs only after
      every predecessor succeeded;
  (b) exact cover: each node's committed packets tile its region with
      no gap and no overlap (the PR-2/PR-5 invariant, per graph node);
  (c) bit-identical outputs vs a sequential numpy oracle.

The journal/resume half locks down crash recovery: killing a journaled
run at any packet boundary and resuming must re-execute ZERO committed
packets and stitch a bit-identical output.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (CancelledError, DependencyError, EngineSession,
                       GraphProgress, RunJournal, resume_run)
from repro.ckpt.checkpoint import merge_spans
from repro.core.device import DeviceGroup
from repro.core.runtime import Program
from repro.core.scheduler import available_schedulers
from repro.core.simulate import (SimConfig, SimDevice, SimNode, dag_depths,
                                 simulate_dag)

WIDTH = 16


def devices(n=3, fail_after=None):
    devs = [DeviceGroup(f"d{i}", throttle=1.0 + 0.5 * i) for i in range(n)]
    if fail_after is not None:
        devs[-1].fail_after = fail_after
    return devs


def node_program(name, G, lws, holder, seed):
    """One graph node: row r of the output is ``base[r] * (1 + bias)``
    where ``bias`` is fed from the predecessors' outputs — so any
    out-of-order dispatch corrupts the result detectably."""
    base = np.random.default_rng(seed).random(
        (G, WIDTH), dtype=np.float32)

    def build(dev):
        def run(offset, size):
            scale = np.float32(1.0) + holder.get("bias", np.float32(0.0))
            return base[offset:offset + size] * scale
        return run

    prog = Program(name=name, total_work=G, lws=lws, build=build,
                   out_rows_per_wg=1, out_cols=WIDTH,
                   out_dtype=np.float32)
    return prog, base


def bias_of(outputs):
    """Order-stable checksum mix of the predecessors' outputs."""
    acc = np.float32(0.0)
    for out in outputs:
        acc = acc + np.float32(np.asarray(out, np.float32)[0].sum())
    return np.float32(0.25) * acc


def feed_into(holder, node_name, order, lock):
    def feed(dep_results):
        with lock:
            order.append(node_name)
        holder["bias"] = bias_of([r.output for r in dep_results])
    return feed


def assert_exact_cover(packets, G):
    spans = sorted((p.offset, p.offset + p.size) for p in packets)
    cursor = 0
    for a, b in spans:
        assert a == cursor, f"gap/overlap at {a} (expected {cursor})"
        cursor = b
    assert cursor == G


def run_random_graph(shape, scheduler, max_inflight, fail_after=None):
    """Execute a random DAG through the session and check the three
    graph invariants.  ``shape`` is a list of dep-index-lists: node i
    depends on shape[i] (all < i)."""
    lws = 4
    sizes = [lws * (2 + (3 * i) % 4) for i in range(len(shape))]
    order: list = []
    lock = threading.Lock()
    nodes = []
    for i, deps_idx in enumerate(shape):
        holder: dict = {}
        prog, base = node_program(f"n{i}", sizes[i], lws, holder, seed=i)
        nodes.append({"prog": prog, "base": base, "holder": holder,
                      "deps_idx": deps_idx})
    skw = {"n_packets": 4} if scheduler == "dynamic" else {}
    with EngineSession(devices(3, fail_after=fail_after),
                       scheduler=scheduler, scheduler_kwargs=skw,
                       max_inflight=max_inflight,
                       name=f"dag-{scheduler}") as session:
        handles = []
        for i, node in enumerate(nodes):
            deps = [handles[j] for j in node["deps_idx"]]
            feed = (feed_into(node["holder"], f"n{i}", order, lock)
                    if deps else None)
            handles.append(session.submit(node["prog"], deps=deps,
                                          feed=feed, cache=False))
        results = [h.result(timeout=120) for h in handles]

    # (a) topological order: every fed node's feed ran after each of its
    # predecessors' feeds (prefix property of the recorded feed order)
    pos = {name: k for k, name in enumerate(order)}
    for i, node in enumerate(nodes):
        for j in node["deps_idx"]:
            if f"n{i}" in pos and f"n{j}" in pos:
                assert pos[f"n{j}"] < pos[f"n{i}"]
    # (b) exact cover per node
    for node, res in zip(nodes, results):
        assert_exact_cover(res.packets, node["prog"].total_work)
    # (c) bit-identical vs the sequential oracle
    oracle_out: list = []
    for i, node in enumerate(nodes):
        bias = (bias_of([oracle_out[j] for j in node["deps_idx"]])
                if node["deps_idx"] else np.float32(0.0))
        oracle_out.append(node["base"] * (np.float32(1.0) + bias))
    for i, res in enumerate(results):
        assert np.array_equal(np.asarray(res.output), oracle_out[i]), \
            f"node n{i} output differs from oracle"


def dag_shapes(max_nodes=6):
    """Random DAG shape strategy: node i deps on a subset of 0..i-1.
    Chains, diamonds and fan-in/fan-out all occur."""
    def build(picks):
        shape = [[]]
        for i, pick in enumerate(picks, start=1):
            shape.append(sorted({p % i for p in pick}))
        return shape
    return st.builds(
        build,
        st.lists(st.lists(st.integers(0, max_nodes - 1),
                          min_size=0, max_size=3),
                 min_size=1, max_size=max_nodes - 1))


@settings(max_examples=12, deadline=None)
@given(shape=dag_shapes(),
       scheduler=st.sampled_from(available_schedulers()),
       max_inflight=st.sampled_from([1, 2, 3]))
def test_random_dag_invariants(shape, scheduler, max_inflight):
    run_random_graph(shape, scheduler, max_inflight)


@settings(max_examples=6, deadline=None)
@given(shape=dag_shapes(max_nodes=5),
       scheduler=st.sampled_from(["hguided_opt", "hguided_steal",
                                  "dynamic"]),
       fail_after=st.integers(1, 3))
def test_random_dag_survives_device_death(shape, scheduler, fail_after):
    """A device dying mid-run (requeue + mark_dead + steal rebalance)
    must not break cover, order, or exactness — per graph node.
    FIFO inflight keeps the injected death deterministic per run."""
    run_random_graph(shape, scheduler, 1, fail_after=fail_after)


# -- cascading terminal states ---------------------------------------------

def _gate_program(name, G, lws, gate):
    def build(dev):
        def run(offset, size):
            gate.wait(timeout=60)
            return np.full((size, WIDTH), np.float32(offset))
        return run
    return Program(name=name, total_work=G, lws=lws, build=build,
                   out_rows_per_wg=1, out_cols=WIDTH,
                   out_dtype=np.float32)


def test_cancel_cascades_transitively():
    gate = threading.Event()
    blocker = _gate_program("blocker", 8, 4, gate)
    holder: dict = {}
    prog, _ = node_program("mid", 8, 4, holder, seed=1)
    with EngineSession(devices(2), name="cascade") as session:
        h0 = session.submit(blocker, cache=False)   # occupies the fleet
        h1 = session.submit(prog, cache=False)      # pending behind it
        h2 = session.submit(prog, deps=[h1], cache=False)
        h3 = session.submit(prog, deps=[h2], cache=False)
        assert h1.cancel()
        # dependents cascade without any of them ever dispatching
        for h in (h2, h3):
            with pytest.raises(CancelledError):
                h.result(timeout=30)
        assert h2.cancelled() and h3.cancelled()
        gate.set()
        assert h0.result(timeout=60) is not None


def test_cancel_of_running_predecessor_does_not_cascade():
    gate = threading.Event()
    blocker = _gate_program("blocker2", 8, 4, gate)
    holder: dict = {}
    prog, _ = node_program("dep2", 8, 4, holder, seed=2)
    with EngineSession(devices(2), name="norun-cancel") as session:
        h0 = session.submit(blocker, cache=False)
        h1 = session.submit(prog, deps=[h0], cache=False)
        time.sleep(0.1)                   # let h0 start
        assert not h0.cancel()            # already running
        gate.set()
        assert h1.result(timeout=60) is not None


def test_failed_predecessor_raises_dependency_error():
    def boom(dev):
        raise RuntimeError("injected build failure")
    bad = Program(name="bad", total_work=8, lws=4, build=boom,
                  out_rows_per_wg=1, out_cols=WIDTH,
                  out_dtype=np.float32)
    holder: dict = {}
    prog, _ = node_program("after-bad", 8, 4, holder, seed=3)
    with EngineSession(devices(2), name="depfail") as session:
        hb = session.submit(bad, cache=False)
        h1 = session.submit(prog, deps=[hb], cache=False)
        h2 = session.submit(prog, deps=[h1], cache=False)
        # the engine surfaces the build failure as an all-devices-failed
        # terminal error, chained from the injected exception
        with pytest.raises(RuntimeError, match="all devices failed"):
            hb.result(timeout=30)
        with pytest.raises(DependencyError) as e1:
            h1.result(timeout=30)
        assert e1.value.dep_name == "bad"
        assert isinstance(e1.value.cause, RuntimeError)
        assert e1.value.__cause__ is e1.value.cause
        # the DependencyError itself counts as failure for dependents
        with pytest.raises(DependencyError) as e2:
            h2.result(timeout=30)
        assert e2.value.dep_name == "after-bad"
        assert isinstance(e2.value.cause, DependencyError)


def test_dep_validation():
    holder: dict = {}
    prog, _ = node_program("v", 8, 4, holder, seed=4)
    with EngineSession(devices(2), name="v1") as s1, \
            EngineSession(devices(2), name="v2") as s2:
        h = s1.submit(prog, cache=False)
        with pytest.raises(TypeError):
            s1.submit(prog, deps=["not-a-handle"], cache=False)
        with pytest.raises(ValueError, match="not issued by this session"):
            s2.submit(prog, deps=[h], cache=False)
        with pytest.raises(TypeError, match="feed must be callable"):
            s1.submit(prog, feed="nope", cache=False)
        h.result(timeout=60)


def test_feed_failure_fails_run_and_cascades():
    holder: dict = {}
    prog, _ = node_program("feedfail", 8, 4, holder, seed=5)
    with EngineSession(devices(2), name="feedfail") as session:
        h0 = session.submit(prog, cache=False)

        def bad_feed(results):
            raise ValueError("feed exploded")
        h1 = session.submit(prog, deps=[h0], feed=bad_feed, cache=False)
        h2 = session.submit(prog, deps=[h1], cache=False)
        with pytest.raises(ValueError, match="feed exploded"):
            h1.result(timeout=30)
        with pytest.raises(DependencyError):
            h2.result(timeout=30)


def test_close_drains_pending_graph_topologically():
    """close() with a whole graph still pending must drain it in
    dependency order — every handle reaches a terminal state and the
    pending set is empty (no leaked _Submissions)."""
    order: list = []
    lock = threading.Lock()
    nodes = []
    for i in range(4):
        holder: dict = {}
        prog, base = node_program(f"c{i}", 8, 4, holder, seed=10 + i)
        nodes.append((prog, base, holder))
    session = EngineSession(devices(2), max_inflight=2, name="close-graph")
    handles = [session.submit(nodes[0][0], cache=False)]
    for i in range(1, 4):
        handles.append(session.submit(
            nodes[i][0], deps=[handles[i - 1]],
            feed=feed_into(nodes[i][2], f"c{i}", order, lock),
            cache=False))
    session.close()                        # must block until drained
    assert all(h.done() for h in handles)
    assert order == ["c1", "c2", "c3"]
    assert len(session._pending) == 0 and session._inflight == 0
    for h in handles:
        assert h.result(timeout=0) is not None


def test_remaining_work_drains_to_zero():
    holder: dict = {}
    prog, _ = node_program("rw", 16, 4, holder, seed=6)
    with EngineSession(devices(2), name="rw") as session:
        h0 = session.submit(prog, cache=False)
        h1 = session.submit(prog, deps=[h0], cache=False)
        # registered totals are visible while pending/in flight
        assert session.remaining_work() >= 0
        h1.result(timeout=60)
    assert session.remaining_work() == 0


def test_graph_progress_accounting():
    gp = GraphProgress()
    gp.register("a", 32)
    gp.register("b", 16)
    assert gp.remaining() == 48 and len(gp) == 2
    assert gp.nodes() == {"a": 32, "b": 16}
    gp.complete("a")
    assert gp.remaining() == 16
    gp.complete("b")
    gp.complete("b")                      # idempotent
    assert gp.remaining() == 0 and len(gp) == 0


# -- journal / resume -------------------------------------------------------

def test_journal_roundtrip_and_torn_tail(tmp_path):
    path = os.path.join(tmp_path, "j.journal")
    with RunJournal(path) as j:
        j.append_packet("k", 0, 2, np.arange(8, dtype=np.float32))
        j.append_packet("k", 2, 2, np.arange(8, 16, dtype=np.float32))
        j.append_packet("other", 0, 1, np.zeros(4, dtype=np.float32))
    recs = RunJournal.read(path)
    assert sorted(recs) == ["k", "other"]
    assert [(r.offset, r.size) for r in recs["k"]] == [(0, 2), (2, 2)]
    assert np.array_equal(recs["k"][1].data,
                          np.arange(8, 16, dtype=np.float32))
    # torn tail: chop bytes off the last record — it must be dropped,
    # the committed prefix preserved
    size = os.path.getsize(path)
    with open(path, "r+b") as fh:
        fh.truncate(size - 3)
    recs = RunJournal.read(path)
    assert [(r.offset, r.size) for r in recs["k"]] == [(0, 2), (2, 2)]
    assert "other" not in recs
    # missing file reads empty; wrong magic raises
    assert RunJournal.read(os.path.join(tmp_path, "nope")) == {}
    bad = os.path.join(tmp_path, "bad")
    with open(bad, "wb") as fh:
        fh.write(b"NOPE")
    with pytest.raises(ValueError, match="not a run journal"):
        RunJournal.read(bad)


def test_truncate_packets(tmp_path):
    path = os.path.join(tmp_path, "j.journal")
    with RunJournal(path) as j:
        for i in range(4):
            j.append_packet("k", 2 * i, 2,
                            np.full(4, i, dtype=np.float32))
    out = RunJournal.truncate_packets(path, 2)
    recs = RunJournal.read(out)["k"]
    assert [(r.offset, r.size) for r in recs] == [(0, 2), (2, 2)]


@settings(max_examples=10, deadline=None)
@given(kill_frac=st.floats(0.0, 1.0), seed=st.integers(0, 999))
def test_resume_reexecutes_zero_committed_packets(kill_frac, seed):
    """Kill a journaled run at any packet boundary; the resume must
    (1) never re-execute a committed packet — its gap sub-regions are
    disjoint from the committed spans — and (2) stitch a bit-identical
    output."""
    holder: dict = {}
    prog, _ = node_program(f"rj{seed}", 24, 4, holder, seed=seed)
    tmp = tempfile.mkdtemp(prefix="dagtest-")
    path = os.path.join(tmp, "run.journal")
    with EngineSession(devices(3), name="resume") as session:
        with RunJournal(path) as j:
            full = np.asarray(
                session.submit(prog, journal=j, cache=False)
                .result(timeout=120).output).copy()
        records = RunJournal.read(path)[prog.name]
        keep = int(round(kill_frac * len(records)))
        trunc = RunJournal.truncate_packets(path, keep)
        with RunJournal(trunc) as j2:
            rep = resume_run(session, prog, j2, prog.name, cache=False)
    committed = merge_spans(records[:keep])
    # gap sub-regions never touch a committed span
    for ga, gb in rep.gaps:
        for ca, cb in committed:
            assert gb <= ca or ga >= cb, \
                f"gap [{ga},{gb}) overlaps committed [{ca},{cb})"
    assert rep.replayed_wg + rep.executed_wg == prog.total_work
    assert rep.replayed_wg == sum(b - a for a, b in committed)
    if keep == len(records):
        assert rep.fully_replayed
    assert np.array_equal(rep.output, full)


def test_resumed_run_extends_journal(tmp_path):
    """The resume submits with the same journal attached: after the
    resume, the journal covers the whole region — a SECOND resume
    replays everything and executes nothing."""
    holder: dict = {}
    prog, _ = node_program("rj2", 16, 4, holder, seed=42)
    path = os.path.join(tmp_path, "run.journal")
    with EngineSession(devices(2), name="resume2") as session:
        with RunJournal(path) as j:
            session.submit(prog, journal=j, cache=False).result(timeout=60)
        records = RunJournal.read(path)[prog.name]
        trunc = RunJournal.truncate_packets(path, len(records) // 2)
        with RunJournal(trunc) as j2:
            rep1 = resume_run(session, prog, j2, prog.name, cache=False)
        with RunJournal(trunc) as j3:
            rep2 = resume_run(session, prog, j3, prog.name, cache=False)
    assert rep1.executed_wg > 0
    assert rep2.fully_replayed and rep2.executed_wg == 0
    assert np.array_equal(rep1.output, rep2.output)


# -- the simulator twin -----------------------------------------------------

def sim_fleet():
    return [SimDevice("a", 1000.0), SimDevice("b", 2000.0),
            SimDevice("c", 4000.0)]


def test_simulate_dag_depths_and_validation():
    nodes = [SimNode("a", 8), SimNode("b", 8, deps=("a",)),
             SimNode("c", 8, deps=("a",)),
             SimNode("d", 8, deps=("b", "c"))]
    assert dag_depths(nodes) == {"a": 0, "b": 1, "c": 1, "d": 2}
    with pytest.raises(ValueError, match="cycle"):
        dag_depths([SimNode("x", 4, deps=("y",)),
                    SimNode("y", 4, deps=("x",))])
    with pytest.raises(ValueError, match="unknown dep"):
        dag_depths([SimNode("x", 4, deps=("ghost",))])
    with pytest.raises(ValueError, match="dispatch_mode"):
        simulate_dag(nodes, sim_fleet(), SimConfig(), dispatch_mode="bsp")


def test_simulate_dag_respects_dependencies():
    nodes = [SimNode("a", 64, 8), SimNode("b", 64, 8, deps=("a",)),
             SimNode("c", 64, 8, deps=("b",))]
    for mode in ("deps", "levels"):
        r = simulate_dag(nodes, sim_fleet(), SimConfig(), dispatch_mode=mode)
        assert r.node_start["b"] >= r.node_finish["a"]
        assert r.node_start["c"] >= r.node_finish["b"]
        assert r.makespan == max(r.node_finish.values())


def test_simulate_dag_deps_never_slower_than_levels():
    """Ready-set dispatch relaxes the levels constraint, so on a
    deterministic fleet it can only start nodes earlier."""
    nodes = []
    for i in range(4):
        h = 128 * (6 if i == 0 else 1)
        nodes.append(SimNode(f"s{i}", h, h // 2))
        nodes.append(SimNode(f"t{i}", h, h // 2, deps=(f"s{i}",)))
    cfg = SimConfig(scheduler="hguided")
    r_d = simulate_dag(nodes, sim_fleet(), cfg, dispatch_mode="deps")
    r_l = simulate_dag(nodes, sim_fleet(), cfg, dispatch_mode="levels")
    assert r_d.makespan <= r_l.makespan * (1 + 1e-9)
