"""Fleet tier: placement registry + policies, elastic autoscaler state
machine, FleetRouter decisions, and the epoch co-simulation's invariants
(exact request cover, determinism, trace replay, resume bit-identity,
co-sim vs one-shot cross-check)."""
import math

import numpy as np
import pytest

from repro.core.simulate import SimConfig, SimDevice, simulate_serving
from repro.fleet import (AutoscaleConfig, ElasticAutoscaler, FleetRouter,
                         PLACEMENTS, PlacementPolicy, ReplicaState,
                         RouterConfig, SimReplica, available_placements,
                         crosscheck_fleet, make_placement,
                         placement_accepts, placement_spec,
                         register_placement, simulate_fleet,
                         unregister_placement)
from repro.serve import (TraceWorkload, make_requests, poisson_arrivals,
                         record_trace)
from repro.serve.workload import Request


def _req(rid, arrival, deadline, size=1):
    return Request(rid=rid, arrival=arrival, deadline=deadline, size=size)


def _states(*powers, now=0.0):
    return [ReplicaState(name=f"rep{i}", power0=p, last_t=now)
            for i, p in enumerate(powers)]


# ---------------------------------------------------------------- registry

def test_builtin_placements_registered():
    assert set(available_placements()) >= {
        "round_robin", "static", "power_prop", "least_residual", "deadline"}
    assert set(PLACEMENTS) == set(available_placements())


def test_registry_contract_mirrors_schedulers():
    class MyPlacement(PlacementPolicy):
        def __init__(self, pin=0):
            self.pin = pin

        def place(self, req, now, states):
            return self.pin

    register_placement("pin", MyPlacement, defaults={"pin": 1})
    try:
        assert placement_spec("pin").cls is MyPlacement
        assert placement_accepts("pin", "pin")
        assert not placement_accepts("pin", "nope")
        p = make_placement("pin")
        assert p.pin == 1                    # defaults applied
        assert make_placement("pin", pin=2).pin == 2
        with pytest.raises(ValueError, match="already registered"):
            register_placement("pin", MyPlacement)
        register_placement("pin", MyPlacement, overwrite=True)
    finally:
        unregister_placement("pin")
    assert "pin" not in available_placements()
    with pytest.raises(KeyError, match="unknown placement"):
        make_placement("pin")


def test_register_rejects_non_policy():
    with pytest.raises(TypeError):
        register_placement("bad", dict)


# ------------------------------------------------------------ ReplicaState

def test_replica_state_drains_at_service_rate():
    s = ReplicaState("a", power0=10.0)
    s.resid = 5.0
    s.drain(0.3)                             # 3 wg served
    assert s.resid == pytest.approx(2.0)
    s.drain(10.0)
    assert s.resid == 0.0                    # floors at zero
    assert s.pred_finish(10.0, 20.0) == pytest.approx(12.0)


def test_replica_state_warmup_gates_ready():
    s = ReplicaState("a", power0=1.0, warm_at=1.0)
    assert not s.ready(0.5) and s.ready(1.0)
    s.active = False
    assert not s.ready(2.0)


# --------------------------------------------------------------- placements

def test_round_robin_cycles_ready_only():
    pol = make_placement("round_robin")
    states = _states(1.0, 1.0, 1.0)
    states[1].active = False
    picks = [pol.place(_req(i, 0, 1), 0.0, states) for i in range(4)]
    assert picks == [0, 2, 0, 2]


def test_static_shares_follow_declared_powers():
    pol = make_placement("static")
    states = _states(3.0, 1.0)
    states[0].power = 0.01                   # live estimate must be ignored
    picks = [pol.place(_req(i, 0, 1), 0.0, states) for i in range(400)]
    assert picks.count(0) == 300 and picks.count(1) == 100


def test_power_prop_follows_live_powers():
    pol = make_placement("power_prop")
    states = _states(3.0, 1.0)
    states[0].power = 1.0                    # measured: actually equal
    states[1].power = 1.0
    picks = [pol.place(_req(i, 0, 1), 0.0, states) for i in range(400)]
    assert picks.count(0) == 200 and picks.count(1) == 200


def test_least_residual_joins_shortest_queue():
    pol = make_placement("least_residual")
    states = _states(1.0, 1.0)
    states[0].resid = 5.0
    assert pol.place(_req(0, 0, 99), 0.0, states) == 1
    states[1].resid = 9.0
    assert pol.place(_req(1, 0, 99), 0.0, states) == 0


def test_deadline_places_earliest_finish_and_sheds_infeasible():
    pol = make_placement("deadline")
    states = _states(2.0, 1.0)
    states[0].resid = 10.0                   # finish at 5+size/2
    # rep1 empty: finish at size/1 = 4 < rep0's 7 => rep1 wins despite
    # lower power
    assert pol.place(_req(0, 0.0, 100.0, size=4), 0.0, states) == 1
    # no replica makes a 1s deadline => shed at the router
    r = _req(1, 0.0, 1.0, size=4)
    assert pol.place(r, 0.0, states) is None
    assert states[1].shed_for == 1
    # shed=False places anyway (degrade-style fleets)
    keep = make_placement("deadline", shed=False)
    assert keep.place(_req(2, 0.0, 1.0, size=4), 0.0, states) == 1


def test_warming_fleet_falls_back_to_active_set():
    pol = make_placement("least_residual")
    states = _states(1.0, 1.0)
    states[0].active = False
    states[1].warm_at = 5.0                  # active but still warming
    assert pol.place(_req(0, 0, 99), 0.0, states) == 1


# --------------------------------------------------------------- autoscaler

def _asc(**kw):
    base = dict(target_delay_s=1.0, breach_s=0.5, idle_delay_s=0.1,
                idle_s=0.5, warmup_s=0.2, cooldown_s=0.3, payback=2.0,
                min_replicas=1)
    base.update(kw)
    return ElasticAutoscaler(AutoscaleConfig(**base))


def test_scale_up_needs_sustained_breach():
    asc = _asc()
    states = _states(1.0, 1.0)
    states[1].active = False
    states[0].resid = 10.0                   # delay 10 >> target 1
    assert asc.step(0.0, states) is None     # dwell starts
    assert asc.step(0.4, states) is None     # 0.4 < breach_s
    ev = asc.step(0.6, states)
    assert ev is not None and ev.action == "up" and ev.replica == "rep1"
    assert states[1].active and states[1].warm_at == pytest.approx(0.8)
    assert asc.warmup_cost_s == pytest.approx(0.2)


def test_scale_up_picks_most_powerful_standby_and_respects_max():
    asc = _asc(max_replicas=2)
    states = _states(1.0, 2.0, 5.0)
    states[1].active = False
    states[2].active = False
    states[0].resid = 50.0
    ev = None
    t = 0.0
    while ev is None:
        ev = asc.step(t, states)
        t += 0.3
    assert ev.replica == "rep2"              # strongest spare joins first
    # fleet now at max_replicas: further breach never scales
    states[0].resid = 500.0
    for _ in range(10):
        assert asc.step(t, states) is None
        t += 0.3


def test_transient_blip_resets_dwell():
    asc = _asc()
    states = _states(1.0, 1.0)
    states[1].active = False
    states[0].resid = 10.0
    asc.step(0.0, states)                    # breach dwell starts
    states[0].resid = 0.5                    # back in band
    asc.step(0.3, states)                    # resets both dwells
    states[0].resid = 10.0
    assert asc.step(0.6, states) is None     # dwell restarted at 0.6
    assert asc.step(1.2, states) is not None


def test_scale_down_requires_idle_and_payback_residency():
    asc = _asc()
    states = _states(1.0, 1.0)
    states[1].active = False
    states[0].resid = 10.0
    asc.step(0.0, states)
    ev = asc.step(0.6, states)               # up at 0.6
    assert ev and ev.action == "up"
    states[0].resid = 0.0                    # instantly idle
    # min residency = payback*warmup + cooldown = 0.7s after the join:
    # idle dwell alone (0.5s) must NOT shrink the fleet yet
    assert asc.step(0.7, states) is None
    assert asc.step(1.25, states) is None    # 1.25 - 0.6 < 0.7? no: guard
    ev = None
    t = 1.4                                  # 0.8s resident: amortized
    while ev is None and t < 3.0:
        ev = asc.step(t, states)
        t += 0.2
    assert ev is not None and ev.action == "down"
    assert asc.flaps() == 0                  # guards held: no flap
    s = asc.summary()
    assert s["ups"] == 1 and s["downs"] == 1


def test_scale_down_respects_min_replicas():
    asc = _asc(min_replicas=2)
    states = _states(1.0, 1.0)
    for t in (0.0, 0.6, 1.2, 5.0, 9.0):      # long, genuine idle
        assert asc.step(t, states) is None   # 2 active == min: hold


def test_queue_delay_inf_when_nothing_ready():
    states = _states(1.0)
    states[0].warm_at = 99.0
    assert ElasticAutoscaler.queue_delay(0.0, states) == math.inf


# ------------------------------------------------------------------ router

def test_router_validates_construction():
    with pytest.raises(ValueError, match="duplicate"):
        FleetRouter([("a", 1.0), ("a", 2.0)])
    with pytest.raises(ValueError, match="standby"):
        FleetRouter([("a", 1.0)], standby=["ghost"])
    with pytest.raises(ValueError, match="admit"):
        FleetRouter([("a", 1.0)], RouterConfig(admit="degrade"))
    with pytest.raises(KeyError):
        FleetRouter([("a", 1.0)], RouterConfig(placement="nope"))


def test_router_places_commits_and_predicts():
    router = FleetRouter([("a", 2.0), ("b", 1.0)],
                         RouterConfig(placement="least_residual"))
    placed, leftover = router.route([_req(0, 0.0, 100.0, size=4)], 0.0)
    assert leftover == [] and len(placed) == 1
    idx = placed[0].replica
    assert idx == 0                          # ties break to higher power
    assert router.states[idx].resid == 4.0
    assert router.states[idx].placed == 1
    assert router.predicted[0] == pytest.approx(2.0)
    assert placed[0].pred_finish == pytest.approx(2.0)


def test_router_sheds_fleet_infeasible_at_admission():
    router = FleetRouter([("a", 1.0)], RouterConfig(placement="static"))
    doomed = _req(0, 0.0, 0.5, size=100)
    placed, _ = router.route([doomed], 0.0)
    assert placed[0].replica is None
    assert doomed.shed and doomed.finish is None
    assert router.shed == [doomed]
    assert router.states[0].resid == 0.0     # shed work never commits


def test_router_deadline_placement_sheds_per_replica_infeasible():
    # fleet-aggregate prediction passes (2 wg/s total) but neither
    # 1 wg/s replica alone can finish 4 wg by t=3 => placement sheds
    router = FleetRouter([("a", 1.0), ("b", 1.0)],
                         RouterConfig(placement="deadline"))
    r = _req(0, 0.0, 3.0, size=4)
    placed, _ = router.route([r], 0.0)
    assert placed[0].replica is None and r.shed
    assert len(router.shed) == 1


def test_router_feedback_ewma_blend():
    router = FleetRouter([("a", 4.0)], RouterConfig(ewma=0.5))
    router.feedback(0, 0.0, measured_power=2.0)
    assert router.states[0].power == pytest.approx(3.0)
    router.states[0].resid = 2.0
    router.states[0].last_t = 1.0
    router.feedback(0, 1.0, measured_resid=6.0)
    assert router.states[0].resid == pytest.approx(4.0)


def test_router_standby_excluded_until_scaled_up():
    router = FleetRouter([("a", 1.0), ("spare", 50.0)],
                         RouterConfig(placement="least_residual"),
                         standby=["spare"])
    placed, _ = router.route([_req(0, 0.0, 1e9, size=1)], 0.0)
    assert placed[0].replica == 0            # spare not placeable
    assert router.fleet_power(0.0) == pytest.approx(1.0)


# -------------------------------------------------------- fleet co-sim

def _sim_cfg(seed=0):
    return SimConfig(scheduler="hguided_opt", opt_init=True,
                     opt_buffers=True, host_cost_per_packet=1e-4, seed=seed)


def _fleet(n=3, jitter=0.05):
    reps = []
    for k in range(n):
        devs = [SimDevice(f"rep{k}.d0", 40.0 + 10 * k, jitter=jitter,
                          launch_overhead=1e-3),
                SimDevice(f"rep{k}.d1", 20.0, jitter=jitter,
                          launch_overhead=1e-3)]
        reps.append(SimReplica(f"rep{k}", devs))
    return reps


def _workload(n=300, rate=120.0, slo=0.5, size=2, seed=0):
    rng = np.random.default_rng(seed)
    return make_requests(poisson_arrivals(n, rate, rng), slo, size=size)


def test_simulate_fleet_exact_request_cover():
    reqs = _workload()
    res = simulate_fleet(reqs, _fleet(), _sim_cfg(),
                         RouterConfig(placement="deadline"), epoch_s=0.2)
    # every offered request resolves exactly one way
    for r in res.requests:
        assert r.shed != (r.finish is not None)
    routed_rids = sorted(r.rid for chunk in res.replica_requests.values()
                         for r in chunk)
    served_rids = sorted(r.rid for r in res.requests if not r.shed)
    assert routed_rids == served_rids        # disjoint exact partition
    assert len(res.router.shed) == sum(1 for r in res.requests if r.shed)
    assert res.stats.n_requests == len(reqs)


def test_simulate_fleet_deterministic():
    a = simulate_fleet(_workload(seed=5), _fleet(), _sim_cfg(),
                       RouterConfig(placement="least_residual"),
                       epoch_s=0.15)
    b = simulate_fleet(_workload(seed=5), _fleet(), _sim_cfg(),
                       RouterConfig(placement="least_residual"),
                       epoch_s=0.15)
    for ra, rb in zip(a.requests, b.requests):
        assert (ra.rid, ra.shed, ra.finish, ra.replica) \
            == (rb.rid, rb.shed, rb.finish, rb.replica)


def test_simulate_fleet_trace_replay_bit_identical(tmp_path):
    """Record a fleet run, replay the trace through the same router and
    fleet: bit-identical outcomes (the trace harness's core claim)."""
    res = simulate_fleet(_workload(seed=2), _fleet(), _sim_cfg(),
                         RouterConfig(placement="deadline"), epoch_s=0.2)
    path = str(tmp_path / "fleet.jsonl")
    n = record_trace(res, path)
    assert n == len(res.requests)
    replayed = TraceWorkload.load(path).requests()
    res2 = simulate_fleet(replayed, _fleet(), _sim_cfg(),
                          RouterConfig(placement="deadline"), epoch_s=0.2)
    for a, b in zip(res.requests, res2.requests):
        assert (a.rid, a.shed, a.finish, a.replica) \
            == (b.rid, b.shed, b.finish, b.replica)


def test_serve_resume_chunked_matches_one_shot():
    """ServeSimState carry-over: splitting a request stream at a drain
    point and resuming must reproduce the one-shot run bit-identically
    (device clocks, EWMA powers, pipeline fill, jitter stream)."""
    devs = [SimDevice("d0", 50.0, jitter=0.1, launch_overhead=1e-3),
            SimDevice("d1", 25.0, jitter=0.1, launch_overhead=1e-3)]
    rng = np.random.default_rng(4)
    first = make_requests(poisson_arrivals(80, 60.0, rng), 0.6, size=1)
    gap = first[-1].arrival + 5.0            # fleet fully drains
    second = [Request(rid=100 + i, arrival=gap + a, deadline=gap + a + 0.6)
              for i, a in enumerate(poisson_arrivals(80, 60.0, rng))]

    def clone(rs):
        return [Request(rid=r.rid, arrival=r.arrival, deadline=r.deadline,
                        size=r.size) for r in rs]

    one = clone(first) + clone(second)
    res_one = simulate_serving(one, 1, devs, _sim_cfg(9), policy="shed")

    devs2 = [SimDevice("d0", 50.0, jitter=0.1, launch_overhead=1e-3),
             SimDevice("d1", 25.0, jitter=0.1, launch_overhead=1e-3)]
    c1 = clone(first)
    r1 = simulate_serving(c1, 1, devs2, _sim_cfg(9), policy="shed")
    c2 = clone(second)
    r2 = simulate_serving(c2, 1, devs2, _sim_cfg(9), policy="shed",
                          resume=r1.state)
    assert r2.rounds == res_one.rounds       # cumulative across the resume
    chunked = {r.rid: r for r in c1 + c2}
    for r in one:
        c = chunked[r.rid]
        assert (r.shed, r.finish, r.replica) == (c.shed, c.finish, c.replica)


def test_serve_resume_rejects_device_mismatch():
    devs = [SimDevice("d0", 50.0)]
    reqs = _workload(n=10, rate=50.0)
    res = simulate_serving(reqs, 1, devs, _sim_cfg(), policy="none")
    with pytest.raises(ValueError, match="resume state"):
        simulate_serving(_workload(n=10, rate=50.0), 1,
                         [SimDevice("a", 1.0), SimDevice("b", 1.0)],
                         _sim_cfg(), resume=res.state)


def test_crosscheck_fleet_within_tolerance():
    fleet = _fleet()
    res = simulate_fleet(_workload(n=250, rate=100.0, seed=1), fleet,
                         _sim_cfg(), RouterConfig(placement="deadline"),
                         epoch_s=0.2)
    cc = crosscheck_fleet(res, fleet, _sim_cfg())
    assert 0.0 <= cc["cosim_attainment"] <= 1.0
    assert cc["abs_diff"] <= 0.08


def test_simulate_fleet_autoscales_on_burst():
    rng = np.random.default_rng(0)
    storm = poisson_arrivals(250, 260.0, rng)          # ~2x core capacity
    tail0 = storm[-1] + 3.0
    tail = [tail0 + 0.5 * k for k in range(8)]
    reqs = make_requests(list(storm) + tail, 0.8, size=2)
    fleet = _fleet(5)
    standby = [rep.name for rep in fleet[3:]]
    asc = ElasticAutoscaler(AutoscaleConfig(
        target_delay_s=0.4, breach_s=0.1, idle_delay_s=0.05, idle_s=0.5,
        warmup_s=0.1, cooldown_s=0.2, min_replicas=3))
    res = simulate_fleet(reqs, fleet, _sim_cfg(),
                         RouterConfig(placement="deadline"),
                         autoscaler=asc, standby=standby, epoch_s=0.1)
    s = asc.summary()
    assert s["ups"] >= 1                     # breach grew the fleet
    assert s["downs"] >= 1                   # idle tail shrank it
    assert s["flaps"] == 0                   # and never thrashed
    assert s["warmup_cost_s"] == pytest.approx(0.1 * s["ups"])
    # scale events landed on the states: spares served real traffic
    spare_traffic = sum(len(res.replica_requests[name])
                        for name in standby)
    assert spare_traffic > 0


def test_simulate_fleet_rejects_bad_args():
    with pytest.raises(ValueError, match="epoch_s"):
        simulate_fleet([], _fleet(), _sim_cfg(), epoch_s=0.0)
    reps = _fleet(2)
    reps[1].name = reps[0].name
    with pytest.raises(ValueError, match="duplicate"):
        simulate_fleet([], reps, _sim_cfg())
