"""Metric definitions (paper §IV)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import metrics as M
from repro.core.metrics import RunResult


def rr(finish):
    return RunResult(total_time=max(finish), device_busy=list(finish),
                     device_finish=list(finish), packets=[])


def test_balance_perfect():
    assert M.balance(rr([2.0, 2.0, 2.0])) == 1.0


def test_balance_imbalanced():
    assert M.balance(rr([1.0, 4.0])) == pytest.approx(0.25)


def test_smax_example():
    # T = (10, 5, 2): powers (0.1, 0.2, 0.5) -> smax = 0.8/0.5 = 1.6
    assert M.s_max_from_times([10, 5, 2]) == pytest.approx(1.6)


def test_efficiency_perfect_coexec():
    singles = [10.0, 5.0, 2.0]
    ideal = 1.0 / sum(1.0 / t for t in singles)
    eff = M.efficiency(2.0, ideal, singles)
    assert eff == pytest.approx(1.0)


@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=8))
@settings(max_examples=50, deadline=None)
def test_efficiency_bounded(singles):
    ideal = 1.0 / sum(1.0 / t for t in singles)
    eff = M.efficiency(min(singles), ideal, singles)
    assert eff == pytest.approx(1.0, rel=1e-6)
    # any slower co-exec time gives eff < 1
    assert M.efficiency(min(singles), ideal * 1.5, singles) < 1.0


def test_inflection_interpolation():
    sizes = [10, 20, 30]
    co = [5.0, 3.0, 1.0]
    single = [2.0, 2.5, 3.0]
    x = M.inflection_point(sizes, co, single)
    assert 20 < x < 30


def test_inflection_none_when_never_crossing():
    assert M.inflection_point([1, 2], [5, 5], [1, 1]) is None


def test_geomean():
    assert M.geomean([1.0, 4.0]) == pytest.approx(2.0)
    assert M.geomean([]) == 0.0
