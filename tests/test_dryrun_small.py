"""Dry-run machinery on a small forced-device-count mesh, in a SUBPROCESS
(the 512-device production dry-run must not leak into this test process —
the isolation requirement itself is under test here)."""
import json
import os
import subprocess
import sys

import jax

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.launch import specs as SP, hlo_cost
from repro.optim.adamw import OptConfig
from repro.parallel.sharding import ShardingResolver
from repro.training import step as STEP

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_smoke("llama3.2-1b")
shape = ShapeConfig("t", 64, 8, "train", accum_steps=2)
resolver = ShardingResolver(mesh, fsdp=True)
opt = OptConfig()
state_abs, state_axes = SP.abstract_train_state(cfg, opt)
batch_abs = SP.input_specs(cfg, shape)
batch_axes = SP.batch_logical_axes(cfg, shape)


def is_ax(x):
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


st_sh = jax.tree.map(lambda ax, l: resolver.sharding(ax, l.shape, param=True),
                     state_axes, state_abs, is_leaf=is_ax)
b_sh = jax.tree.map(lambda ax, l: resolver.sharding(ax, l.shape),
                    batch_axes, batch_abs, is_leaf=is_ax)
fn = STEP.make_train_step(cfg, opt, res=resolver, accum_steps=2)
jfn = jax.jit(fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
              donate_argnums=(0,))
with mesh:
    lowered = jfn.lower(state_abs, batch_abs)
    compiled = lowered.compile()
mem = compiled.memory_analysis()
corrected = hlo_cost.analyze(compiled.as_text())
print(json.dumps({
    "ok": True,
    "n_devices": len(jax.devices()),
    "flops": corrected["flops"],
    "wire": corrected["collective_wire_bytes"],
    "temp": getattr(mem, "temp_size_in_bytes", -1),
}))
"""


def test_small_mesh_dryrun_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["n_devices"] == 8
    assert rec["flops"] > 0
    assert rec["wire"] > 0            # FSDP all-gathers must appear


def test_this_process_kept_single_device():
    # the isolation contract: tests see the real single CPU device
    assert len(jax.devices()) == 1
