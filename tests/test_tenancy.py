"""Property suite for multi-tenant sessions on a shared device fleet.

For ANY mix of tenants (weights, priorities, exclusive flags), any
registered scheduler, and with or without injected device death, the
``FleetArbiter`` + N ``EngineSession`` stack must preserve:

  (a) per-tenant exact cover: each tenant's committed packets tile its
      own region with no gap and no overlap — arbitration never leaks,
      drops, or duplicates work across tenants;
  (b) bit-identical outputs vs a solo oracle (the same program run in a
      plain, pre-tenancy session);
  (c) exclusive isolation: an ``exclusive=True`` tenant's packet
      windows overlap zero co-tenant windows on every device;
  (d) fair-share convergence: saturated 2:1:1 tenants end near their
      quotas (loose threaded bound; the tight bound is checked on the
      deterministic ``simulate_multitenant`` twin);
  (e) close/submit serialization: racing ``close()`` against in-flight
      ``submit()`` calls never corrupts the dispatcher — every accepted
      handle reaches a terminal state, every rejected submit raises the
      session-closed error.
"""
from __future__ import annotations

import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (EngineSession, FleetArbiter, TenantConfig,
                       exclusive_overlaps, fair_share_index)
from repro.core.device import DeviceGroup
from repro.core.runtime import Program
from repro.core.scheduler import available_schedulers
from repro.core.simulate import (SimConfig, SimDevice, SimTenant,
                                 simulate_multitenant)
from repro.tenancy import PacketWindow

WIDTH = 8
LWS = 4


def devices(n=2, fail_after=None):
    devs = [DeviceGroup(f"d{i}", throttle=1.0 + 0.7 * i) for i in range(n)]
    if fail_after is not None:
        devs[-1].fail_after = fail_after
    return devs


def tenant_program(name, total, seed):
    """Uniquely named per tenant/run: session executable caches key by
    (program.name, device.name), so shared names would alias builds."""
    base = np.random.default_rng(seed).random((total, WIDTH),
                                              dtype=np.float32)

    def build(dev):
        def run(offset, size):
            return base[offset:offset + size] * np.float32(3.0)
        return run

    prog = Program(name=name, total_work=total, lws=LWS, build=build,
                   out_rows_per_wg=1, out_cols=WIDTH,
                   out_dtype=np.float32)
    return prog, base * np.float32(3.0)


def assert_exact_cover(packets, total):
    spans = sorted((p.offset, p.offset + p.size) for p in packets)
    cursor = 0
    for a, b in spans:
        assert a == cursor, f"gap/overlap at {a} (expected {cursor})"
        cursor = b
    assert cursor == total


def run_tenant_mix(scheduler, mix, total, fail_after=None):
    """Run each tenant's submits concurrently through one arbiter;
    return {tenant: [(result, expected), ...]} plus the windows."""
    arb = FleetArbiter(devices(2, fail_after=fail_after),
                      name=f"mix-{scheduler}")
    results = {}
    errors = []

    def tenant_main(cfg, n_runs, seed0):
        try:
            with EngineSession(arbiter=arb, tenant=cfg,
                               scheduler=scheduler,
                               name=f"s-{cfg.name}") as s:
                handles = []
                expected = []
                for k in range(n_runs):
                    prog, exp = tenant_program(f"{cfg.name}-{k}", total,
                                               seed0 + k)
                    handles.append(s.submit(prog))
                    expected.append(exp)
                results[cfg.name] = [(h.result(), e)
                                     for h, e in zip(handles, expected)]
        except Exception as exc:
            errors.append(f"{cfg.name}: {exc!r}")

    threads = [threading.Thread(target=tenant_main,
                                args=(cfg, n_runs, 100 * i))
               for i, (cfg, n_runs) in enumerate(mix)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    windows = arb.windows()
    arb.close()
    assert not errors, errors
    return results, windows


@settings(max_examples=6, deadline=None)
@given(scheduler=st.sampled_from(available_schedulers()),
       n_tenants=st.integers(2, 3),
       weights=st.lists(st.sampled_from([0.5, 1.0, 2.0]), min_size=3,
                        max_size=3),
       priorities=st.lists(st.integers(0, 1), min_size=3, max_size=3),
       fail_after=st.sampled_from([None, None, 2]))
def test_random_mix_exact_cover_and_identity(scheduler, n_tenants,
                                             weights, priorities,
                                             fail_after):
    """(a) + (b) for random tenant mixes, with and without device death
    (the arbiter must compose with the fault-tolerant requeue path)."""
    mix = [(TenantConfig(f"t{i}", weight=weights[i],
                         priority=priorities[i]), 2)
           for i in range(n_tenants)]
    total = 6 * LWS
    results, _ = run_tenant_mix(scheduler, mix, total,
                                fail_after=fail_after)
    assert set(results) == {cfg.name for cfg, _ in mix}
    for name, runs in results.items():
        assert len(runs) == 2
        for res, expected in runs:
            assert_exact_cover(res.packets, total)
            assert np.array_equal(np.asarray(res.output), expected), \
                f"tenant {name} output diverged from solo oracle"


@pytest.mark.parametrize("scheduler", available_schedulers())
def test_solo_tenant_bit_identical_to_plain_session(scheduler):
    """A single-tenant arbiter session is the pre-tenancy fast path:
    output must be bit-identical to a plain session's."""
    total = 8 * LWS
    prog, expected = tenant_program("solo", total, seed=5)
    with EngineSession(devices(2), scheduler=scheduler, name="plain") as s:
        plain = np.asarray(s.submit(prog).result().output)
    arb = FleetArbiter(devices(2), name="solo")
    with EngineSession(arbiter=arb, scheduler=scheduler,
                       name="tenant") as s:
        tenant = np.asarray(s.submit(prog).result().output)
    arb.close()
    assert np.array_equal(plain, expected)
    assert np.array_equal(plain, tenant)


def test_exclusive_windows_never_overlap():
    """(c): across every device, the exclusive tenant's packet windows
    are disjoint from all co-tenant windows."""
    mix = [(TenantConfig("s1"), 3),
           (TenantConfig("s2"), 3),
           (TenantConfig("ex", exclusive=True), 2)]
    results, windows = run_tenant_mix("hguided_opt", mix, 8 * LWS)
    assert any(w.tenant == "ex" for w in windows)
    assert exclusive_overlaps(windows, "ex") == 0
    for res, expected in results["ex"]:
        assert np.array_equal(np.asarray(res.output), expected)


def test_priority_tenant_finishes_first():
    """Strict priority: with equal backlogs, the high-priority tenant's
    work is granted ahead of the low-priority tenant's."""
    arb = FleetArbiter(devices(2), name="prio")
    finish = {}

    def tenant_main(cfg):
        with EngineSession(arbiter=arb, tenant=cfg,
                           scheduler="hguided_opt",
                           name=f"s-{cfg.name}") as s:
            handles = []
            for k in range(4):
                prog, _ = tenant_program(f"{cfg.name}-{k}", 8 * LWS,
                                         seed=k)
                handles.append(s.submit(prog))
            for h in handles:
                h.result()
            finish[cfg.name] = time.perf_counter()

    threads = [threading.Thread(target=tenant_main, args=(cfg,))
               for cfg in (TenantConfig("hi", priority=1),
                           TenantConfig("lo", priority=0))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stats = arb.tenant_stats(include_departed=True)
    arb.close()
    assert finish["hi"] <= finish["lo"]
    assert stats["hi"]["usage_wg"] == stats["lo"]["usage_wg"] == 4 * 8 * LWS


def test_fair_share_threaded_loose():
    """(d), loose: saturated 2:1:1 tenants; the weight-2 tenant must
    hold a strictly larger share than either weight-1 tenant while all
    three are live (checked at its own completion snapshot)."""
    arb = FleetArbiter(devices(2), name="fair")
    finish = {}

    def tenant_main(cfg):
        with EngineSession(arbiter=arb, tenant=cfg,
                           scheduler="dynamic", name=f"s-{cfg.name}") as s:
            handles = []
            for k in range(6):
                prog, _ = tenant_program(f"{cfg.name}-{k}", 8 * LWS,
                                         seed=k)
                handles.append(s.submit(prog))
            for h in handles:
                h.result()
            finish[cfg.name] = time.perf_counter()

    cfgs = [TenantConfig("a", weight=2.0), TenantConfig("b"),
            TenantConfig("c")]
    threads = [threading.Thread(target=tenant_main, args=(cfg,))
               for cfg in cfgs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    windows = arb.windows()
    arb.close()
    snap = finish["a"]
    wg = {"a": 0.0, "b": 0.0, "c": 0.0}
    for w in windows:
        if w.t1 <= snap:
            wg[w.tenant] += w.wg
    assert wg["a"] > wg["b"] and wg["a"] > wg["c"], wg


@pytest.mark.parametrize("scheduler", available_schedulers())
def test_fair_share_simulated_tight(scheduler):
    """(d), tight: the deterministic discrete-event twin must hold every
    tenant within 25% of quota at the weight-2 tenant's finish."""
    devs = [SimDevice("gpu", throughput=2000.0),
            SimDevice("cpu", throughput=1000.0)]
    r = simulate_multitenant(
        [SimTenant("a", 4096, weight=2.0), SimTenant("b", 4096),
         SimTenant("c", 4096)],
        devs, SimConfig(scheduler=scheduler, seed=11))
    assert r.tenant_wg == {"a": 4096, "b": 4096, "c": 4096}
    snap = r.tenant_finish["a"]
    wg = {"a": 0.0, "b": 0.0, "c": 0.0}
    for name, _dev, t0, t1, w in r.windows:
        if t1 <= snap:
            wg[name] += w
        elif t0 < snap:
            wg[name] += w * (snap - t0) / (t1 - t0)
    total = sum(wg.values())
    # Coarse-packet schedulers (static: one packet per device per run)
    # quantize the b/c split, so the equal-weight pair is checked as an
    # aggregate; the weight-2 tenant's share is tight for all of them.
    assert abs(wg["a"] / total / 0.5 - 1.0) < 0.25, (scheduler, wg)
    bc = (wg["b"] + wg["c"]) / total
    assert abs(bc / 0.5 - 1.0) < 0.25, (scheduler, wg)
    for name in ("b", "c"):
        assert wg[name] / total > 0.10, (scheduler, name, wg)


def test_simulated_exclusive_and_death():
    """Sim twin: exclusive non-overlap holds even while a device dies
    mid-stream and its packets are requeued onto the survivor."""
    devs = [SimDevice("gpu", throughput=2000.0, fail_at=1.5),
            SimDevice("cpu", throughput=800.0)]
    r = simulate_multitenant(
        [SimTenant("s1", 4096), SimTenant("s2", 4096),
         SimTenant("ex", 512, exclusive=True, arrival=0.5)],
        devs, SimConfig(scheduler="dynamic", seed=2))
    assert r.tenant_wg == {"s1": 4096, "s2": 4096, "ex": 512}
    wins = [PacketWindow(*w) for w in r.windows]
    assert exclusive_overlaps(wins, "ex") == 0
    assert r.takeover_latency["ex"] >= 0.0


def test_arbiter_rejects_bad_tenants():
    arb = FleetArbiter(devices(1), name="cfg")
    try:
        with pytest.raises(ValueError):
            TenantConfig("")
        with pytest.raises(ValueError):
            TenantConfig("a::b")
        with pytest.raises(ValueError):
            TenantConfig("a", weight=0.0)
        arb.register(TenantConfig("dup"))
        with pytest.raises(ValueError):
            arb.register(TenantConfig("dup"))
        with pytest.raises(ValueError):
            EngineSession(tenant=TenantConfig("t"))  # tenant w/o arbiter
    finally:
        arb.close()


def test_arena_partition_isolation():
    """Tenant close evicts only its own prefix from the shared arena."""
    arb = FleetArbiter(devices(1), name="arena")
    h1 = arb.register(TenantConfig("p"))
    h2 = arb.register(TenantConfig("q"))
    a = h1.arena.acquire("prog", "d0", (4, 4), np.float32)
    h1.arena.release(a)
    b = h2.arena.acquire("prog", "d0", (4, 4), np.float32)
    h2.arena.release(b)
    arb.unregister(h1)
    assert arb.arena.stats_for_prefix("p::").entries == 0
    assert arb.arena.stats_for_prefix("q::").entries == 1
    with pytest.raises(RuntimeError):
        h1.arena.acquire("prog", "d0", (4, 4), np.float32)
    arb.close()


def test_close_racing_submits_regression():
    """(e): hammer submit() from many threads while close() lands.  The
    only acceptable rejection is the session-closed RuntimeError, and
    every accepted handle must reach a terminal state (the pre-fix race
    could strand a queued handle forever when close() won the discard
    hook interleaving)."""
    for trial in range(4):
        session = EngineSession(devices(2), scheduler="hguided_opt",
                                name=f"race-{trial}")
        start = threading.Barrier(5)
        handles, bad = [], []
        lock = threading.Lock()

        def submitter(tid):
            try:
                start.wait()
                for k in range(8):
                    prog, _ = tenant_program(f"r{tid}-{k}", 4 * LWS,
                                             seed=k)
                    h = session.submit(prog)
                    with lock:
                        handles.append(h)
            except RuntimeError as exc:
                if "closed" not in str(exc):
                    bad.append(exc)
            except Exception as exc:       # anything else is the bug
                bad.append(exc)

        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        start.wait()
        time.sleep(0.002 * trial)
        session.close()
        for t in threads:
            t.join()
        assert not bad, bad
        for h in handles:
            assert h.done(), f"stranded handle {h!r}"
            if not h.cancelled():
                h.result()                 # accepted => must have run


def test_handle_terminal_state_is_final():
    """A settled handle ignores late _set_result/_set_exception (the
    cancel/settle race could flip a CANCELLED handle to DONE)."""
    from repro.api.handles import RunHandle
    h = RunHandle("p", 0)
    assert h.cancel()
    h._set_result("late")
    assert h.cancelled()
    with pytest.raises(Exception):
        h.result(timeout=0.1)
    h2 = RunHandle("q", 1)
    assert h2._start()
    h2._set_result("ok")
    h2._set_exception(RuntimeError("late loser"))
    assert h2.result() == "ok" and h2.exception() is None


def test_fair_share_index_helper():
    stats = {"a": {"share": 0.5, "quota": 0.5},
             "b": {"share": 0.2, "quota": 0.25},
             "z": {"share": 0.3, "quota": 0.0}}
    idx = fair_share_index(stats)
    assert abs(idx - 0.8) < 1e-9           # worst tenant: b at 80%
    assert fair_share_index({}) == 1.0
