"""Region (NDRange) types + region carving invariants.

Satellite property suite: 1-D and 2-D carves from EVERY scheduler tile the
full region exactly once, lws-aligned per dimension — including under
requeue and mark_dead faults (the engine's fault-tolerance semantics).
"""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.region import Dim, Region, as_region
from repro.core.scheduler import (DeviceProfile, available_schedulers,
                                  make_scheduler)

ALL_SCHEDULERS = ["static", "static_rev", "dynamic", "hguided",
                  "hguided_opt", "hguided_deadline", "hguided_steal"]


# ------------------------------------------------------------- value types

def test_dim_validation():
    with pytest.raises(ValueError, match="offset"):
        Dim(-1, 4)
    with pytest.raises(ValueError, match="size"):
        Dim(0, 0)
    with pytest.raises(ValueError, match="lws"):
        Dim(0, 4, 0)
    assert Dim(2, 6).end == 8


def test_region_constructors_and_geometry():
    line = Region.line(100, lws=8, offset=16)
    assert line.ndim == 1 and line.work == 100 and line.shape == (100,)
    rect = Region.rect(64, 32, lws=(8, 4), offset=(8, 4))
    assert rect.ndim == 2 and rect.work == 64 * 32
    assert rect.offsets == (8, 4)
    with pytest.raises(ValueError, match="1-D and 2-D"):
        Region((Dim(0, 4), Dim(0, 4), Dim(0, 4)))
    assert as_region(50, lws=4) == Region.line(50, lws=4)
    assert as_region(rect) is rect


def test_region_containment_and_alignment():
    full = Region.rect(64, 32, lws=(8, 4))
    roi = Region.rect(16, 8, lws=(8, 4), offset=(8, 4))
    assert full.contains(roi)
    assert roi.aligned_within(full)
    # misaligned offset in dim 1
    skew = Region.rect(16, 8, lws=(8, 4), offset=(8, 3))
    assert full.contains(skew) and not skew.aligned_within(full)
    # a final remainder may stop exactly at the outer end...
    ragged = Region.rect(60, 32, lws=(8, 4))
    tail = Region.rect(4, 32, lws=(1, 1), offset=(56, 0))
    assert tail.aligned_within(ragged)
    # ...but not short of it
    short = Region.rect(4, 32, lws=(1, 1), offset=(48, 0))
    assert not short.aligned_within(ragged)
    assert not full.contains(Region.rect(64, 33, lws=(1, 1)))
    assert not full.contains(Region.line(64))          # ndim mismatch


def test_row_panel():
    r = Region.rect(64, 32, lws=(8, 4), offset=(16, 4))
    p = r.row_panel(8, 16)
    assert p.dims[0] == Dim(24, 16, 8)
    assert p.dims[1] == r.dims[1]
    with pytest.raises(ValueError, match="outside"):
        r.row_panel(60, 8)


# ----------------------------------------------------------- carve harness

def _drain_with_faults(sched, n_dev, die_after, requeue_budget, seed):
    """Round-robin drain with injected mid-run faults (same semantics as
    the engine: deaths happen while HOLDING a pulled packet, which is
    requeued; device 0 is immortal so work cannot strand)."""
    rng = random.Random(seed)
    executed = []
    pulled = {i: 0 for i in range(n_dev)}
    alive = set(range(n_dev))
    budget = requeue_budget
    while True:
        progress = False
        for i in sorted(alive):
            pkt = sched.next_packet(i)
            if pkt is None:
                continue
            progress = True
            pulled[i] += 1
            if i != 0 and die_after[i] is not None \
                    and pulled[i] > die_after[i]:
                sched.requeue(pkt)
                sched.mark_dead(i)
                alive.discard(i)
                continue
            if budget > 0 and not pkt.retried and rng.random() < 0.3:
                budget -= 1
                sched.requeue(pkt)
                continue
            executed.append(pkt)
        if not progress:
            return executed


def assert_exact_region_tiling(packets, region):
    """Every packet is an lws-aligned row panel of ``region``; together the
    panels tile its dim-0 extent exactly once (no gaps, no overlaps) and
    each spans the full trailing dims."""
    assert packets, "no packets carved"
    d0 = region.dims[0]
    for p in packets:
        assert p.region is not None
        assert p.region.ndim == region.ndim
        assert region.contains(p.region)
        assert p.region.aligned_within(region)
        assert p.region.dims[1:] == region.dims[1:]       # full row panels
        # relative carve coordinates match the absolute panel
        assert p.region.dims[0].offset == d0.offset + p.offset
        assert p.region.dims[0].size == p.size
    spans = sorted((p.region.dims[0].offset, p.region.dims[0].end)
                   for p in packets)
    pos = d0.offset
    for a, b in spans:
        assert a == pos, f"gap/overlap at {pos}: got {a}"
        pos = b
    assert pos == d0.end


REGIONS_1D = st.builds(
    lambda size, lws, off: Region.line(size, lws=lws, offset=off),
    st.integers(1, 3000), st.integers(1, 32), st.integers(0, 64))

REGIONS_2D = st.builds(
    lambda r, c, lr, lc, orow, ocol: Region.rect(
        r, c, lws=(lr, lc), offset=(orow, ocol)),
    st.integers(1, 1500), st.integers(1, 128), st.integers(1, 16),
    st.integers(1, 8), st.integers(0, 64), st.integers(0, 64))


@given(region=st.one_of(REGIONS_1D, REGIONS_2D),
       powers=st.lists(st.floats(0.05, 10.0), min_size=1, max_size=6),
       name=st.sampled_from(ALL_SCHEDULERS))
@settings(max_examples=120, deadline=None)
def test_property_region_carving_exact_cover(region, powers, name):
    """Fault-free: every scheduler tiles 1-D and 2-D regions exactly."""
    devs = [DeviceProfile(f"d{i}", p) for i, p in enumerate(powers)]
    sched = make_scheduler(name, region, 1, devs)
    assert sched.region == region
    out = []
    active = set(range(len(devs)))
    while active:
        for i in list(active):
            pkt = sched.next_packet(i)
            if pkt is None:
                active.discard(i)
            else:
                out.append(pkt)
    assert_exact_region_tiling(out, region)
    assert sched.remaining() == 0


@given(region=st.one_of(REGIONS_1D, REGIONS_2D),
       powers=st.lists(st.floats(0.05, 10.0), min_size=2, max_size=6),
       name=st.sampled_from(ALL_SCHEDULERS),
       deaths=st.lists(st.integers(0, 6), min_size=6, max_size=6),
       requeue_budget=st.integers(0, 3),
       seed=st.integers(0, 2**16))
@settings(max_examples=120, deadline=None)
def test_property_region_carving_fault_tolerant(region, powers, name,
                                                deaths, requeue_budget,
                                                seed):
    """Under random requeues and device deaths (mark_dead), the executed
    packets still tile the region exactly once, per-dimension aligned."""
    devs = [DeviceProfile(f"d{i}", p) for i, p in enumerate(powers)]
    sched = make_scheduler(name, region, 1, devs)
    die_after = [None] + [d if d < 4 else None
                          for d in deaths[1:len(devs)]]
    executed = _drain_with_faults(sched, len(devs), die_after,
                                  requeue_budget, seed)
    assert_exact_region_tiling(executed, region)
    seqs = [p.seq for p in executed]
    assert len(seqs) == len(set(seqs))
    assert sched.remaining() == 0


def test_every_registered_scheduler_covered_by_property_suite():
    """Guard: a newly registered built-in must be added to ALL_SCHEDULERS
    (plugins registered by other tests may come and go)."""
    assert set(ALL_SCHEDULERS) <= set(available_schedulers())


def test_legacy_int_work_still_carves_offset_zero():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 3.0)]
    sched = make_scheduler("dynamic", 256, 8, devs)
    pkt = sched.next_packet(0)
    assert pkt.region == Region.line(256, lws=8).row_panel(0, pkt.size)
    assert sched.region == Region.line(256, lws=8)
