"""Lease-amortized dispatch through the threaded engine + simulator.

Satellite coverage for the lock-amortized hand-off PR: both dispatch
modes stay bit-exact on every scheduler (including the new
``hguided_steal``), the per-device ``sched_wait_s`` metric is stamped
with the phase identity intact, fault tolerance survives leased
dispatch, and the simulator's lease model reproduces the crossover.
"""
import numpy as np
import pytest

from repro.api import (BufferPolicy, EngineSession, OffloadMode, coexec)
from repro.core import programs as P
from repro.core.device import DeviceGroup
from repro.core.simulate import SimConfig, SimDevice, simulate

MANDEL_KW = dict(px=64, max_iter=16, lws=(4, 4))
GAUSS_KW = dict(h=64, w=64, lws=(4, 4))


def devices3():
    return [DeviceGroup("cpu", throttle=3.0),
            DeviceGroup("igpu", throttle=2.0),
            DeviceGroup("gpu", throttle=1.0)]


# ------------------------------------------------------------- exactness

@pytest.mark.parametrize("dispatch", ["leased", "per_packet"])
@pytest.mark.parametrize("scheduler", ["dynamic", "hguided_opt",
                                       "hguided_steal"])
def test_dispatch_modes_bit_identical(scheduler, dispatch):
    ref = P.reference_output("mandelbrot2d", **MANDEL_KW)
    res = coexec(P.PROGRAMS["mandelbrot2d"](**MANDEL_KW), devices3(),
                 scheduler=scheduler, dispatch=dispatch)
    np.testing.assert_array_equal(res.output, ref)


def test_steal_scheduler_pooled_output_exact():
    ref = P.reference_output("gaussian2d", **GAUSS_KW)
    res = coexec(P.PROGRAMS["gaussian2d"](**GAUSS_KW), devices3(),
                 scheduler="hguided_steal",
                 buffer_policy=BufferPolicy.POOLED)
    np.testing.assert_allclose(res.output, ref, rtol=1e-5, atol=1e-5)


# ------------------------------------------------- sched_wait_s + phases

@pytest.mark.parametrize("dispatch", ["leased", "per_packet"])
def test_sched_wait_stamped_and_phase_identity(dispatch):
    res = coexec(P.PROGRAMS["gaussian2d"](**GAUSS_KW), devices3(),
                 scheduler="hguided_steal", dispatch=dispatch)
    assert len(res.sched_wait_s) == 3
    assert all(w >= 0.0 for w in res.sched_wait_s)
    ph = res.phases
    # the five disjoint windows still cover the wall exactly
    total = (ph.init_s + ph.h2d_s + ph.roi_s + ph.d2h_s + ph.teardown_s)
    assert total == pytest.approx(res.binary_time, abs=1e-9)
    assert ph.offload_s == pytest.approx(ph.h2d_s + ph.roi_s + ph.d2h_s,
                                         abs=1e-9)


def test_session_dispatch_override_and_validation():
    prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
    ref = P.reference_output("gaussian2d", **GAUSS_KW)
    with pytest.raises(ValueError, match="dispatch"):
        EngineSession(devices3(), dispatch="bogus")
    with EngineSession(devices3(), dispatch="leased") as session:
        with pytest.raises(ValueError, match="dispatch"):
            session.submit(prog, dispatch="nope")
        # per-submit override of the session default
        r = session.submit(prog, dispatch="per_packet").result()
        np.testing.assert_allclose(r.output, ref, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- fault tolerance

def test_leased_dispatch_fault_tolerance_with_steal():
    """A device dying mid-run under leased dispatch: its lease is
    reclaimed, survivors absorb the work, output stays exact."""
    ref = P.reference_output("mandelbrot2d", **MANDEL_KW)
    devs = devices3()
    devs[1].fail_after = 0            # dies holding its first packet
    res = coexec(P.PROGRAMS["mandelbrot2d"](**MANDEL_KW), devs,
                 scheduler="hguided_steal")
    np.testing.assert_array_equal(res.output, ref)
    assert res.aborted_devices == 1
    assert res.retries >= 1


def test_roi_submits_leased_dispatch_exact_with_faults():
    prog = P.PROGRAMS["gaussian2d"](**GAUSS_KW)
    ref = P.reference_output("gaussian2d", **GAUSS_KW)
    devs = devices3()
    devs[2].fail_after = 0
    with EngineSession(devs, scheduler="hguided_steal") as session:
        session.register_workload(prog)
        r = session.submit(prog, mode=OffloadMode.ROI).result()
        np.testing.assert_allclose(r.output, ref, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- simulator

def test_sim_lease_model_crossover():
    """The sim's leased hand-off must (a) match per-packet results when
    every pop crosses the lock anyway, and (b) beat it at high packet
    counts where per-packet serialization dominates."""
    def devs():
        return [SimDevice("gpu", 40000.0), SimDevice("gpu2", 15000.0),
                SimDevice("cpu", 10000.0)]
    gains = []
    for n_pkt in (64, 512):
        kw = {"n_packets": n_pkt}
        lock = simulate(16384, 8, devs(),
                        SimConfig(scheduler="dynamic", scheduler_kwargs=kw,
                                  sched_overhead_s=1e-3))
        lease = simulate(16384, 8, devs(),
                         SimConfig(scheduler="dynamic", scheduler_kwargs=kw,
                                   sched_overhead_s=1e-3,
                                   dispatch="leased"))
        assert len(lock.sched_wait_s) == 3
        assert all(w >= 0 for w in lock.sched_wait_s)
        assert sum(lease.sched_wait_s) <= sum(lock.sched_wait_s) + 1e-9
        gains.append(1 - lease.total_time / lock.total_time)
    assert gains[-1] > gains[0]               # crossover widens
    assert gains[-1] > 0.05                   # and is material at 512


def test_sim_per_packet_unchanged_by_lease_plumbing():
    """Default SimConfig (per-packet) must stay bit-identical to the
    calibrated behavior: same packets, same times, seeded jitter."""
    devs = [SimDevice("a", 1000.0, jitter=0.1),
            SimDevice("b", 400.0, jitter=0.1)]
    r1 = simulate(4096, 8, devs, SimConfig(scheduler="hguided_opt", seed=3))
    devs2 = [SimDevice("a", 1000.0, jitter=0.1),
             SimDevice("b", 400.0, jitter=0.1)]
    r2 = simulate(4096, 8, devs2, SimConfig(scheduler="hguided_opt", seed=3))
    assert r1.total_time == r2.total_time
    assert [p.seq for p in r1.packets] == [p.seq for p in r2.packets]


def test_sim_steal_scheduler_serving_and_fault():
    """hguided_steal under leased dispatch survives a mid-run device
    death in the sim (lease reclaim + exact drain)."""
    devs = [SimDevice("a", 1000.0), SimDevice("b", 800.0, fail_at=0.4),
            SimDevice("c", 600.0)]
    r = simulate(8192, 8, devs,
                 SimConfig(scheduler="hguided_steal", dispatch="leased"))
    assert r.aborted_devices == 1
    covered = sorted((p.offset, p.offset + p.size) for p in r.packets)
    pos = 0
    for a, b in covered:
        assert a == pos
        pos = b
    assert pos == 8192
