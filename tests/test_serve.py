"""Deadline-aware serving subsystem: scheduler variant, workloads,
open-loop simulator, and the threaded CoexecServer."""
import math

import numpy as np
import pytest

from repro.core.scheduler import (SCHEDULERS, DeviceProfile,
                                  HGuidedDeadlineScheduler,
                                  HGuidedOptScheduler, make_scheduler)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.simulate import SimConfig, SimDevice, simulate_serving
from repro.serve import (
    RequestQueue,
    TraceWorkload,
    bursty_arrivals,
    make_requests,
    poisson_arrivals,
    record_trace,
    summarize,
    trace_arrivals,
)
from repro.serve.stats import percentile
from repro.serve.workload import Request


# ---------------------------------------------------------- HGuidedDeadline

def test_hguided_deadline_registered():
    assert "hguided_deadline" in SCHEDULERS
    sched = make_scheduler("hguided_deadline", 100, 4,
                           [DeviceProfile("a", 1.0)])
    assert isinstance(sched, HGuidedDeadlineScheduler)
    assert isinstance(sched, HGuidedOptScheduler)   # inherits EWMA observe


def test_hguided_deadline_shrinks_with_slack():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 3.0)]
    sched = make_scheduler("hguided_deadline", 10000, 8, devs)
    wide = sched.next_packet(1)
    sched.update_slack(1e-3)        # ~3 wg of budget at power 3
    tight = sched.next_packet(1)
    assert tight.size == 8          # shrunk to the lws floor
    assert tight.size < wide.size
    sched.update_slack(None)        # lifting the cap restores HGuidedOpt
    lifted = sched.next_packet(1)
    assert lifted.size > tight.size


def test_hguided_deadline_no_slack_matches_hguided_opt():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 3.0),
            DeviceProfile("c", 7.0)]
    a = make_scheduler("hguided_deadline", 5000, 8, devs)
    b = make_scheduler("hguided_opt", 5000, 8,
                       [DeviceProfile(d.name, d.power) for d in devs])
    for dev in (2, 1, 0, 2, 1):
        pa, pb = a.next_packet(dev), b.next_packet(dev)
        assert (pa.offset, pa.size) == (pb.offset, pb.size)


def test_hguided_deadline_coverage():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 2.0)]
    sched = make_scheduler("hguided_deadline", 1000, 8, devs)
    sched.update_slack(0.5)
    got = []
    active = {0, 1}
    while active:
        for i in list(active):
            p = sched.next_packet(i)
            if p is None:
                active.discard(i)
            else:
                got.append(p)
    ivs = sorted((p.offset, p.offset + p.size) for p in got)
    pos = 0
    for a, b in ivs:
        assert a == pos
        pos = b
    assert pos == 1000


# ---------------------------------------------------------------- workloads

def test_poisson_arrivals_rate_and_order():
    rng = np.random.default_rng(0)
    arr = poisson_arrivals(4000, 50.0, rng)
    assert len(arr) == 4000
    assert all(b >= a for a, b in zip(arr, arr[1:]))
    mean_gap = arr[-1] / len(arr)
    assert mean_gap == pytest.approx(1 / 50.0, rel=0.1)


def test_bursty_arrivals_sorted_and_bursty():
    rng = np.random.default_rng(0)
    arr = bursty_arrivals(2000, 50.0, rng, burst=5.0)
    assert len(arr) == 2000
    assert all(b >= a for a, b in zip(arr, arr[1:]))
    # burstiness: inter-arrival CV well above the exponential's 1.0
    gaps = np.diff(arr)
    assert gaps.std() / gaps.mean() > 1.2


def test_trace_arrivals_validation():
    assert trace_arrivals([0.0, 1.0, 1.0, 2.5]) == [0.0, 1.0, 1.0, 2.5]
    with pytest.raises(ValueError):
        trace_arrivals([0.0, 2.0, 1.0])


def test_request_queue_open_loop_release():
    reqs = make_requests([0.0, 0.5, 1.0, 1.5], slo=1.0)
    q = RequestQueue(reqs)
    assert q.preview().rid == 0
    assert [r.rid for r in q.poll(0.6)] == [0, 1]
    assert q.next_arrival() == 1.0
    assert q.poll(0.6) == []            # no re-release
    assert [r.rid for r in q.poll(10.0)] == [2, 3]
    assert q.next_arrival() is None


# ------------------------------------------------------ trace record/replay

def _traced(n=20, seed=0):
    """A small 'measured' workload: mixed sizes, some outcomes filled."""
    rng = np.random.default_rng(seed)
    reqs = make_requests(poisson_arrivals(n, 40.0, rng), slo=0.5,
                         size=2)
    for i, r in enumerate(reqs):
        r.size = 1 + i % 3
        if i % 4 == 0:
            r.shed = True
        else:
            r.finish = r.arrival + 0.1
            r.replica = f"rep{i % 2}"
            r.degraded = i % 5 == 0
    return reqs


def test_trace_round_trip_file(tmp_path):
    reqs = _traced()
    path = str(tmp_path / "trace.jsonl")
    assert record_trace(reqs, path) == len(reqs)
    tw = TraceWorkload.load(path)
    assert len(tw) == len(reqs)
    replay = tw.requests()
    for orig, rep in zip(reqs, replay):
        # the schedule half replays exactly...
        assert (rep.rid, rep.arrival, rep.deadline, rep.size) \
            == (orig.rid, orig.arrival, orig.deadline, orig.size)
        # ...with the accounting cleared for a fresh run
        assert rep.finish is None and not rep.shed and not rep.degraded
        assert rep.replica is None and rep.prompt is None
    # the measured outcome half survives on the records for analysis
    for orig, d in zip(reqs, tw.records):
        assert (d["finish"], d["shed"], d["replica"]) \
            == (orig.finish, orig.shed, orig.replica)


def test_trace_round_trip_is_fixed_point(tmp_path):
    """record -> load -> record must be byte-identical: the trace file is
    canonical (sorted, versioned), not an accident of insertion order."""
    reqs = _traced(seed=3)
    p1, p2 = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    record_trace(list(reversed(reqs)), p1)     # scrambled input order
    record_trace(TraceWorkload.load(p1).requests(), p2)
    with open(p1) as f1, open(p2) as f2:
        lines1, lines2 = f1.readlines(), f2.readlines()
    # outcome fields differ (cleared by replay); schedule lines must not
    import json as _json
    for l1, l2 in zip(lines1, lines2):
        d1, d2 = _json.loads(l1), _json.loads(l2)
        for k in ("rid", "arrival", "deadline", "size", "trace_version",
                  "n_requests"):
            assert d1.get(k) == d2.get(k)


def test_trace_from_requests_and_queue():
    reqs = _traced(seed=1)
    tw = TraceWorkload.from_requests(reqs)
    assert tw.arrivals() == sorted(r.arrival for r in reqs)
    q = tw.queue()
    assert len(q) == len(reqs)
    released = q.poll(math.inf)
    assert [r.rid for r in released] \
        == [r.rid for r in sorted(reqs, key=lambda r: (r.arrival, r.rid))]
    prompts = {r.rid: np.full(4, r.rid, dtype=np.int32) for r in reqs}
    with_prompts = tw.requests(prompt_fn=lambda rid: prompts[rid])
    assert all(r.prompt[0] == r.rid for r in with_prompts)


def test_trace_rejects_unknown_version(tmp_path):
    path = str(tmp_path / "vers.jsonl")
    reqs = _traced(n=3)
    record_trace(reqs, path)
    with open(path) as f:
        lines = f.readlines()
    import json as _json
    hdr = _json.loads(lines[0])
    hdr["trace_version"] = 999
    with open(path, "w") as f:
        f.write(_json.dumps(hdr) + "\n")
        f.writelines(lines[1:])
    with pytest.raises(ValueError, match="unsupported trace version"):
        TraceWorkload.load(path)


@given(st.lists(st.tuples(st.floats(0.0, 100.0),
                          st.floats(0.001, 10.0),
                          st.integers(1, 8)),
                min_size=1, max_size=40))
@settings(max_examples=30, deadline=None)
def test_trace_round_trip_property(items):
    """Any schedule (ties, duplicates, unsorted) survives a round trip:
    replay order is the canonical (arrival, rid) sort and every field is
    bit-identical (floats via JSON repr round-tripping exactly)."""
    reqs = [Request(rid=i, arrival=a, deadline=a + slo, size=sz)
            for i, (a, slo, sz) in enumerate(items)]
    tw = TraceWorkload.from_requests(reqs)
    replay = tw.requests()
    expect = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    assert [(r.rid, r.arrival, r.deadline, r.size) for r in replay] \
        == [(r.rid, r.arrival, r.deadline, r.size) for r in expect]
    # and a second trip is stable
    again = TraceWorkload.from_requests(replay).requests()
    assert [(r.rid, r.arrival) for r in again] \
        == [(r.rid, r.arrival) for r in replay]


# ------------------------------------------------------------------- stats

def test_percentile_interpolation():
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile([5.0], 99) == 5.0
    assert math.isnan(percentile([], 50))


def test_summarize_accounting():
    reqs = make_requests([0.0, 0.0, 0.0, 0.0], slo=1.0)
    reqs[0].finish = 0.5                 # on time
    reqs[1].finish = 2.0                 # late
    reqs[2].shed = True                  # shed
    reqs[3].finish = 0.9                 # on time
    st = summarize(reqs, duration=2.0)
    assert (st.n_requests, st.served, st.shed, st.missed) == (4, 3, 1, 1)
    assert st.slo_attainment == pytest.approx(0.5)
    assert st.goodput_wg_s == pytest.approx(2 / 2.0)
    assert st.throughput_wg_s == pytest.approx(3 / 2.0)


# -------------------------------------------------------- open-loop simulator

def _fleet(n=4, thr=25.0):
    return [SimDevice(f"r{i}", thr) for i in range(n)]


def _reqs(n, rate, slo, seed=0):
    rng = np.random.default_rng(seed)
    return make_requests(poisson_arrivals(n, rate, rng), slo=slo)


@pytest.mark.parametrize("sched", ["static", "dynamic", "hguided",
                                   "hguided_opt", "hguided_deadline"])
def test_sim_open_loop_conservation_and_causality(sched):
    reqs = _reqs(300, 60.0, slo=0.5)
    cfg = SimConfig(scheduler=sched, opt_init=True, opt_buffers=True,
                    host_cost_per_packet=1e-4)
    res = simulate_serving(reqs, 1, _fleet(), cfg, policy="shed")
    assert not res.all_dead
    for r in reqs:                        # every request accounted for once
        assert r.shed or r.finish is not None
        if r.finish is not None and not r.shed:
            assert r.finish > r.arrival   # open loop: service after arrival
    assert res.rounds > 1                 # genuinely incremental dispatch
    assert res.duration >= max(r.arrival for r in reqs if not r.shed)


def test_sim_underload_meets_slo():
    reqs = _reqs(200, 30.0, slo=1.0)      # 30% of fleet capacity
    cfg = SimConfig(scheduler="hguided_opt", opt_init=True, opt_buffers=True,
                    host_cost_per_packet=1e-4)
    simulate_serving(reqs, 1, _fleet(), cfg)
    st = summarize(reqs)
    assert st.shed == 0
    assert st.slo_attainment > 0.99


def test_sim_overload_sheds_and_protects_survivors():
    mk = lambda: _reqs(400, 300.0, slo=0.3)      # 3x fleet capacity
    cfg = SimConfig(scheduler="hguided_deadline", opt_init=True,
                    opt_buffers=True, host_cost_per_packet=1e-4)
    shed_reqs = mk()
    simulate_serving(shed_reqs, 1, _fleet(), cfg, policy="shed")
    st_shed = summarize(shed_reqs)
    none_reqs = mk()
    simulate_serving(none_reqs, 1, _fleet(), cfg, policy="none")
    st_none = summarize(none_reqs)
    assert st_shed.shed > 0
    # shedding doomed work must not cost on-time completions, and the
    # survivors' tail must be tighter than the unprotected queue's
    assert st_shed.slo_attainment >= st_none.slo_attainment
    assert st_shed.p99_latency < st_none.p99_latency


def test_sim_guided_beats_static_under_heterogeneity():
    devs_spec = [50.0, 25.0, 12.5]       # 2x steps, biased profile below

    def fleet():
        devs = [SimDevice(f"r{i}", t, jitter=0.1) for i, t in
                enumerate(devs_spec)]
        devs[0].profile_bias = 0.6       # profile badly underrates the GPU
        devs[2].straggle_at = 0.5
        devs[2].straggle_factor = 0.3
        return devs

    atts = {}
    for sched in ("static", "hguided_opt", "hguided_deadline"):
        att = []
        for seed in range(3):
            reqs = _reqs(300, 70.0, slo=0.4, seed=seed)
            cfg = SimConfig(scheduler=sched, opt_init=True, opt_buffers=True,
                            host_cost_per_packet=1e-4, seed=seed)
            simulate_serving(reqs, 1, fleet(), cfg, policy="shed",
                             batch_window_s=0.05, round_quantum_s=0.05)
            att.append(summarize(reqs).slo_attainment)
        atts[sched] = sum(att) / len(att)
    assert atts["hguided_opt"] > atts["static"]
    assert atts["hguided_deadline"] > atts["static"]


def test_sim_device_failure_work_survives():
    devs = _fleet(3)
    devs[1].fail_at = 0.5                # dies mid-stream
    reqs = _reqs(200, 50.0, slo=2.0)
    cfg = SimConfig(scheduler="hguided_opt", opt_init=True, opt_buffers=True,
                    host_cost_per_packet=1e-4)
    res = simulate_serving(reqs, 1, devs, cfg, policy="none")
    assert not res.all_dead
    for r in reqs:                       # survivors absorbed everything
        assert r.finish is not None and not r.shed
        assert r.replica != "r1" or r.finish <= 0.5 + 1.0


def test_sim_all_dead_sheds_remaining():
    devs = _fleet(2)
    for d in devs:
        d.fail_at = 0.2
    reqs = _reqs(100, 100.0, slo=5.0)
    cfg = SimConfig(scheduler="dynamic", opt_init=True, opt_buffers=True)
    res = simulate_serving(reqs, 1, devs, cfg, policy="none")
    assert res.all_dead
    assert all(r.shed or r.finish is not None for r in reqs)
    assert any(r.shed for r in reqs)


# ------------------------------------------------------- threaded CoexecServer

@pytest.fixture(scope="module")
def smoke_serving():
    import jax
    from repro.configs import get_smoke
    from repro.models import transformer as T
    from repro.serve import Replica
    cfg = get_smoke("llama3.2-1b")
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (8, 16)).astype(np.int32)
    return cfg, params, prompts, Replica


def test_server_replica_invariant_outputs(smoke_serving):
    from repro.serve import CoexecServer, ServerConfig
    cfg, params, prompts, Replica = smoke_serving
    scfg = ServerConfig(scheduler="hguided_deadline", lws=2, gen=2,
                        policy="none")

    def run(replicas):
        reqs = make_requests([0.0] * len(prompts), slo=300.0,
                             prompt_fn=lambda i: prompts[i])
        server = CoexecServer(replicas, scfg)
        try:
            out = server.run(RequestQueue(reqs))
        finally:
            server.close()
        assert out.stats.served == len(prompts)
        return out

    two = run([Replica("a", cfg, params), Replica("b", cfg, params,
                                                  throttle=2.0)])
    one = run([Replica("solo", cfg, params)])
    assert set(two.results) == set(one.results)
    for rid in one.results:
        np.testing.assert_array_equal(two.results[rid], one.results[rid])
    assert sum(two.stats.dispatch.values()) == len(prompts)


def test_server_sheds_on_predicted_miss(smoke_serving):
    from repro.serve import CoexecServer, ServerConfig
    cfg, params, prompts, Replica = smoke_serving
    reqs = make_requests([0.0] * len(prompts), slo=1e-3,
                         prompt_fn=lambda i: prompts[i])
    server = CoexecServer(
        [Replica("a", cfg, params)],
        ServerConfig(scheduler="hguided_deadline", lws=2, gen=2,
                     policy="shed"),
        initial_power={"a": 1.0})        # calibrated: 1 req/s, SLO 1 ms
    try:
        out = server.run(RequestQueue(reqs))
    finally:
        server.close()
    assert out.stats.shed > 0
    assert out.stats.shed + out.stats.served == len(prompts)
    for r in out.requests:
        if r.shed:
            assert r.finish is None and r.rid not in out.results


def test_server_degrade_policy_reduces_generation(smoke_serving):
    from repro.serve import CoexecServer, ServerConfig
    cfg, params, prompts, Replica = smoke_serving
    reqs = make_requests([0.0] * len(prompts), slo=2.0,
                         prompt_fn=lambda i: prompts[i])
    server = CoexecServer(
        [Replica("a", cfg, params)],
        ServerConfig(scheduler="hguided_deadline", lws=2, gen=4,
                     policy="degrade", min_gen=1),
        initial_power={"a": 2.0})        # too slow for 8 reqs x 4 tokens
    try:
        out = server.run(RequestQueue(reqs))
    finally:
        server.close()
    assert out.stats.shed == 0           # degrade never drops
    assert out.stats.degraded > 0
    degraded = [r for r in out.requests if r.degraded]
    assert all(len(out.results[r.rid]) < 4 for r in degraded)
