"""Serving-path structural variants: unrolled vs scanned decode, stacked vs
unstacked weights — all must produce identical logits (the §Perf cell C
optimizations may not change semantics)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.launch import specs as SP
from repro.models import transformer as T


def _setup(arch, **cfg_over):
    cfg = get_smoke(arch)
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    return cfg, params, toks


def _decode_logits(cfg, params, toks):
    cache, _ = T.init_cache(cfg, 2, 24)
    lg, cache = T.prefill(cfg, params, toks, cache)
    out = [np.asarray(lg)]
    tok = jnp.argmax(lg[:, -1], -1)[:, None]
    for i in range(4):
        lg, cache = T.decode_step(cfg, params, tok, cache, jnp.int32(16 + i))
        out.append(np.asarray(lg))
        tok = jnp.argmax(lg[:, -1], -1)[:, None]
    return np.concatenate(out, axis=1)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "jamba-v0.1-52b",
                                  "deepseek-v2-lite-16b"])
def test_unrolled_matches_scanned_decode(arch):
    cfg_u, params, toks = _setup(arch, decode_unroll=True)
    cfg_s = dataclasses.replace(cfg_u, decode_unroll=False)
    lu = _decode_logits(cfg_u, params, toks)
    ls = _decode_logits(cfg_s, params, toks)
    np.testing.assert_allclose(lu, ls, rtol=2e-4, atol=2e-4)


def test_unstacked_weights_match_stacked():
    cfg, params, toks = _setup("llama3.2-1b", decode_unroll=True)
    # build the unstacked weight view and run decode with it
    n = jax.tree.leaves(params["blocks"])[0].shape[0]
    params_u = dict(params)
    params_u["blocks"] = [jax.tree.map(lambda t: t[i], params["blocks"])
                          for i in range(n)]
    ls = _decode_logits(cfg, params, toks)
    lu = _decode_logits(cfg, params_u, toks)
    np.testing.assert_allclose(lu, ls, rtol=1e-5, atol=1e-5)


def test_abstract_params_unstacked_structure():
    cfg = get_smoke("qwen3-32b")
    p, a = SP.abstract_params_unstacked(cfg)
    assert isinstance(p["blocks"], list) and isinstance(a["blocks"], list)
    n = len(p["blocks"])
    assert n == cfg.n_layers // cfg.block_period
    stacked, _ = SP.abstract_params(cfg)
    lead = jax.tree.leaves(stacked["blocks"])[0]
    leaf = jax.tree.leaves(p["blocks"][0])[0]
    assert lead.shape[1:] == leaf.shape


def test_sqrt_remat_matches_flat_forward():
    """Grouped (sqrt-L) remat must not change the forward values."""
    cfg, params, toks = _setup("llama3.2-1b")
    cfg_flat = dataclasses.replace(cfg, remat_groups=1)
    cfg_grp = dataclasses.replace(cfg, remat_groups=2)
    lf, _ = T.forward(cfg_flat, params, toks)
    lg, _ = T.forward(cfg_grp, params, toks)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lg),
                               rtol=1e-5, atol=1e-5)


def test_sqrt_remat_matches_flat_gradients():
    from repro.training.step import make_loss_fn
    cfg, params, toks = _setup("llama3.2-1b")
    batch = {"tokens": toks}
    grads = {}
    for name, g in (("flat", 1), ("grouped", 2)):
        c = dataclasses.replace(cfg, remat_groups=g)
        loss_fn = make_loss_fn(c)
        (_, _), gr = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        grads[name] = gr
    for a, b in zip(jax.tree.leaves(grads["flat"]),
                    jax.tree.leaves(grads["grouped"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)
