"""Loop-corrected HLO cost model: the scan-vs-unroll equivalence that
justifies using it instead of raw cost_analysis (see launch/hlo_cost.py),
plus collective accounting inside loops."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_equals_unroll_flops():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(ws.shape[0]):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    r_scan = hlo_cost.analyze(_compile(scanned, x, ws).as_text())
    r_unroll = hlo_cost.analyze(_compile(unrolled, x, ws).as_text())
    expect = 8 * 2 * 64 * 128 * 128
    assert r_scan["dot_flops"] == expect
    assert r_unroll["dot_flops"] == expect
    # raw XLA undercounts the scan by ~8x (the reason this module exists)
    ca = _compile(scanned, x, ws).cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < expect / 4


def test_nested_scan_multiplies():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def step(x, _):
            y, _ = jax.lax.scan(inner, x, ws)
            return y, None
        return jax.lax.scan(step, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    r = hlo_cost.analyze(_compile(outer, x, ws).as_text())
    assert r["dot_flops"] == 3 * 4 * 2 * 32 * 32 * 32


def test_transcendentals_counted():
    def f(x):
        return jnp.exp(x).sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = hlo_cost.analyze(_compile(f, x).as_text())
    assert r["transcendentals"] >= 128 * 128


def test_dus_inplace_traffic():
    """decode-style cache update WITH DONATION (the serve path donates its
    cache): traffic must scale with the update, not the cache."""
    def f(cache, upd):
        return jax.lax.dynamic_update_slice(cache, upd, (0, 0, 0))

    cache = jax.ShapeDtypeStruct((64, 1024, 64), jnp.float32)  # 16 MiB
    upd = jax.ShapeDtypeStruct((64, 1, 64), jnp.float32)       # 16 KiB
    c = jax.jit(f, donate_argnums=(0,)).lower(cache, upd).compile()
    r = hlo_cost.analyze(c.as_text())
    cache_bytes = 64 * 1024 * 64 * 4
    assert r["traffic_bytes"] < cache_bytes  # far below 2x cache


def test_parse_robust_to_tuple_comments():
    text = """HloModule m, entry_computation_layout={()->f32[2]{0}}

%body (p: (s32[], f32[2])) -> (s32[], f32[2]) {
  %p = (s32[], f32[2]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[2]{0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %nx = f32[2]{0} add(%x, %x)
  ROOT %t = (s32[], f32[2]{0}) tuple(%ni, %nx)
}

%cond (p: (s32[], f32[2])) -> pred[] {
  %p = (s32[], f32[2]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main () -> f32[2] {
  %z = f32[2]{0} constant({1, 2})
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[2]{0}) tuple(%c0, %z)
  %w = (s32[], /*index=1*/f32[2]{0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[2]{0} get-tuple-element(%w), index=1
}
"""
    r = hlo_cost.analyze(text)
    # 5 iterations x (1 add of 2 elems + 1 iv add) >= 10 flops
    assert r["flops"] >= 10
    assert r["unknown_trip_loops"] == 0


def test_collective_wire_estimates():
    from repro.launch.hlo_cost import _wire_bytes
    assert _wire_bytes("all-reduce", 100, 4) == pytest.approx(150.0)
    assert _wire_bytes("all-gather", 100, 4) == pytest.approx(75.0)
    assert _wire_bytes("reduce-scatter", 100, 4) == pytest.approx(300.0)
    assert _wire_bytes("collective-permute", 100, 4) == 100.0
