"""Serving-path correctness: prefill + token-by-token decode must match the
full forward logits for every architecture family (KV caches, MLA
compressed cache + absorbed decode, Mamba conv/ssm state, hybrid stacks,
multi-codebook audio)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke(arch)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S, P = 2, 32, 16
    if cfg.frontend == "encodec_stub":
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (B, S, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size)
    logits_full, _ = T.forward(cfg, params, toks, remat=False)
    cache, _ = T.init_cache(cfg, B, S)
    lg, cache = T.prefill(cfg, params, toks[:, :P], cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(logits_full[:, P - 1]),
                               rtol=2e-4, atol=2e-4)
    dec = jax.jit(lambda p, t, c, pos: T.decode_step(cfg, p, t, c, pos))
    for i in range(P, S):
        lg, cache = dec(params, toks[:, i:i + 1], cache, jnp.int32(i))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_full[:, i]),
                                   rtol=5e-4, atol=5e-4)


def test_greedy_generation_deterministic():
    cfg = get_smoke("llama3.2-1b")
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    B, P, N = 1, 8, 8
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, P), 0,
                                cfg.vocab_size)

    def generate():
        cache, _ = T.init_cache(cfg, B, P + N)
        lg, cache = T.prefill(cfg, params, prompt, cache)
        toks = []
        tok = jnp.argmax(lg[:, -1], -1)[:, None]
        for i in range(N):
            toks.append(int(tok[0, 0]))
            lg, cache = T.decode_step(cfg, params, tok, cache,
                                      jnp.int32(P + i))
            tok = jnp.argmax(lg[:, -1], -1)[:, None]
        return toks

    assert generate() == generate()
