"""Co-execution integration: the threaded dispatch engine (via the tiered
API) on real kernels and the discrete-event simulator (paper-system
behaviour)."""
import numpy as np
import pytest

from repro.api import EngineSession, coexec
from repro.core import metrics as M
from repro.core import programs as P
from repro.core.device import DeviceGroup
from repro.core.simulate import SimConfig, SimDevice, simulate, \
    single_device_time


def devices3():
    return [DeviceGroup("cpu", throttle=3.0), DeviceGroup("igpu", throttle=1.5),
            DeviceGroup("gpu", throttle=1.0)]


@pytest.mark.parametrize("sched", ["static", "static_rev", "dynamic",
                                   "hguided", "hguided_opt"])
def test_engine_output_exact(sched):
    kw = {"n_packets": 8} if sched == "dynamic" else {}
    prog = P.PROGRAMS["binomial"](n_options=4096)
    ref = P.reference_output("binomial", n_options=4096)
    res = coexec(prog, devices3(), scheduler=sched, scheduler_kwargs=kw)
    np.testing.assert_allclose(res.output, ref, rtol=1e-5, atol=1e-5)
    assert res.total_time > 0
    assert res.binary_time >= res.total_time


def test_engine_device_failure_absorbed():
    # h=1024 -> 8 work-groups: every device's static chunk is non-empty
    prog = P.PROGRAMS["gaussian"](h=1024, w=128)
    ref = P.reference_output("gaussian", h=1024, w=128)
    devs = devices3()
    devs[2].fail_after = 0          # gpu dies on its first packet
    # static: the gpu's chunk is pre-assigned, so the failure (and its
    # requeue) is deterministic regardless of thread scheduling
    res = coexec(prog, devs, scheduler="static")
    assert res.aborted_devices == 1
    assert res.retries >= 1
    np.testing.assert_allclose(res.output, ref, rtol=1e-5, atol=1e-5)


def test_engine_all_fail_raises():
    prog = P.PROGRAMS["gaussian"](h=256, w=128)
    devs = devices3()
    for d in devs:
        d.fail_after = 0
    with pytest.raises(RuntimeError):
        coexec(prog, devs, scheduler="dynamic",
               scheduler_kwargs={"n_packets": 8})


def test_engine_elastic_membership():
    prog = P.PROGRAMS["binomial"](n_options=2048)
    ref = P.reference_output("binomial", n_options=2048)
    with EngineSession(devices3()[:2]) as session:
        session.run(prog)
        session.add_device(DeviceGroup("late", throttle=1.0))
        r2 = session.run(prog)
        np.testing.assert_allclose(r2.output, ref, rtol=1e-5, atol=1e-5)
        assert len(r2.device_busy) == 3
        session.remove_device("late")
        r3 = session.run(prog)
        assert len(r3.device_busy) == 2
        np.testing.assert_allclose(r3.output, ref, rtol=1e-5, atol=1e-5)


def test_engine_executable_cache_reused():
    prog = P.PROGRAMS["binomial"](n_options=2048)
    with EngineSession(devices3(), init_cost_s=0.05) as session:
        session.run(prog)
        t0 = __import__("time").perf_counter()
        session.run(prog)
        warm = __import__("time").perf_counter() - t0
        # the 3 x 50 ms init costs must not be paid again
        assert warm < 10.0
        assert session.init_payments == 3
        assert len(session.executables) == 3


# ----------------------------------------------------------- simulator
def sim_devs():
    return [SimDevice("cpu", 100.0, jitter=0.05, zero_copy=True),
            SimDevice("igpu", 300.0, jitter=0.05, zero_copy=True),
            SimDevice("gpu", 700.0, jitter=0.05)]


def test_sim_hguided_beats_static_under_bias():
    devs = sim_devs()
    for d, b in zip(devs, (1.5, 0.8, 1.0)):
        d.profile_bias = b
    t = {}
    for sched in ("static", "hguided"):
        cfg = SimConfig(scheduler=sched, opt_init=True, opt_buffers=True)
        t[sched] = simulate(4096, 8, devs, cfg).total_time
    assert t["hguided"] < t["static"]


def test_sim_balance_near_one_for_hguided():
    cfg = SimConfig(scheduler="hguided", opt_init=True, opt_buffers=True)
    r = simulate(8192, 8, sim_devs(), cfg)
    assert M.balance(r) > 0.9


def test_sim_failure_requeues():
    devs = sim_devs()
    devs[2].fail_at = 0.5
    cfg = SimConfig(scheduler="hguided", opt_init=True, opt_buffers=True)
    r = simulate(8192, 8, devs, cfg)
    assert r.aborted_devices == 1
    covered = sum(p.size for p in r.packets)
    assert covered == 8192


def test_sim_straggler_absorbed():
    devs = sim_devs()
    devs[2].straggle_at = 0.2
    devs[2].straggle_factor = 0.3
    cfg_h = SimConfig(scheduler="hguided", opt_init=True, opt_buffers=True)
    cfg_s = SimConfig(scheduler="static", opt_init=True, opt_buffers=True)
    th = simulate(8192, 8, devs, cfg_h).total_time
    ts = simulate(8192, 8, devs, cfg_s).total_time
    assert th < ts        # guided tail reroutes around the straggler


def test_sim_efficiency_metrics_consistent():
    devs = sim_devs()
    cfg = SimConfig(scheduler="hguided_opt", opt_init=True, opt_buffers=True)
    singles = [single_device_time(8192, 8, d, cfg) for d in devs]
    r = simulate(8192, 8, devs, cfg)
    eff = M.efficiency(min(singles), r.total_time, singles)
    assert 0 < eff <= 1.05
