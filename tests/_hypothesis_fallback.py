"""Deterministic fallback for the `hypothesis` property-testing API.

The test suite uses a small slice of hypothesis (`given`, `settings`,
`strategies.integers/floats/lists/sampled_from/one_of/builds/tuples`).  When the real library
is installed (see requirements-dev.txt) it is used untouched; when it is
absent — hermetic CI images, the pinned repro container — importing this
module registers a seeded random-sampling stand-in under
``sys.modules["hypothesis"]`` so property tests still *run* (as seeded
randomized tests) instead of failing at collection.

Limitations vs the real thing (acceptable for a fallback): no shrinking,
no example database, no coverage-guided generation.
"""
from __future__ import annotations

import random
import sys
import types

_SEED = 0xC0E0EC            # fixed seed: runs are reproducible
_DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value=None, max_value=None):
    lo = -(2**15) if min_value is None else min_value
    hi = 2**15 if max_value is None else max_value
    return _Strategy(lambda rng: rng.randint(lo, hi))


def floats(min_value=None, max_value=None, **_kw):
    lo = -1e6 if min_value is None else min_value
    hi = 1e6 if max_value is None else max_value
    return _Strategy(lambda rng: rng.uniform(lo, hi))


def lists(elements, min_size=0, max_size=None, **_kw):
    cap = min_size + 10 if max_size is None else max_size

    def draw(rng):
        n = rng.randint(min_size, cap)
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw)


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: rng.choice(seq))


def one_of(*strategies):
    return _Strategy(lambda rng: rng.choice(strategies).draw(rng))


def builds(target, *arg_strategies, **kw_strategies):
    def draw(rng):
        args = [s.draw(rng) for s in arg_strategies]
        kwargs = {k: s.draw(rng) for k, s in kw_strategies.items()}
        return target(*args, **kwargs)

    return _Strategy(draw)


def tuples(*strategies):
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        def runner():
            # resolved at call time so @settings works written above OR
            # below @given (both orders are legal in real hypothesis)
            cfg = (getattr(runner, "_fallback_settings", None)
                   or getattr(fn, "_fallback_settings", {}))
            n_examples = cfg.get("max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(_SEED)
            for _ in range(n_examples):
                args = [s.draw(rng) for s in arg_strategies]
                kwargs = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, **kwargs)

        # NOTE: deliberately no functools.wraps — pytest must see a
        # zero-argument signature, not the strategy parameters (which it
        # would otherwise try to resolve as fixtures).
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner

    return deco


def install() -> None:
    """Register the fallback as ``hypothesis`` / ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "sampled_from",
                 "one_of", "builds", "tuples"):
        setattr(st, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    hyp.assume = lambda cond: None
    hyp.__version__ = "0.0-fallback"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
