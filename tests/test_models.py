"""Per-arch smoke tests: reduced same-family config, one forward and one
real train step on CPU; asserts shapes, finiteness, and that the update
changed the parameters."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke, shapes_for
from repro.models import transformer as T
from repro.optim.adamw import OptConfig
from repro.optim import adamw
from repro.training.step import make_train_step


def _tokens(cfg, B, S, key):
    if cfg.frontend == "encodec_stub":
        return jax.random.randint(key, (B, S, cfg.n_codebooks), 0,
                                  cfg.vocab_size)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    params, axes = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = _tokens(cfg, B, S, jax.random.PRNGKey(1))
    patches = jnp.ones((B, cfg.n_patches, cfg.d_model)) \
        if cfg.frontend == "vit_stub" else None
    logits, aux = jax.jit(lambda p, t: T.forward(cfg, p, t, patches=patches))(
        params, toks)
    want = (B, S, cfg.n_codebooks, cfg.vocab_size) \
        if cfg.frontend == "encodec_stub" else (B, S, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = OptConfig(lr=1e-3, warmup_steps=1)
    state = adamw.init_state(params, opt)
    step_fn = jax.jit(make_train_step(cfg, opt, accum_steps=2))
    B, S = 4, 32
    batch = {"tokens": _tokens(cfg, B, S, jax.random.PRNGKey(1))}
    if cfg.frontend == "vit_stub":
        batch["patches"] = jnp.ones((B, cfg.n_patches, cfg.d_model))
    new_state, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert int(new_state.step) == 1
    # parameters actually moved
    diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                        state.params, new_state.params)
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_validates_and_counts(arch):
    cfg = get_config(arch)
    cfg.validate()
    total, active = T.param_count(cfg)
    assert total > 0 and 0 < active <= total
    if cfg.moe.n_routed:
        assert active < total        # routed experts discounted
    cells = shapes_for(cfg)
    names = [c.name for c in cells]
    assert "train_4k" in names and "decode_32k" in names
    assert ("long_500k" in names) == cfg.is_recurrent


def test_param_count_scaling_sanity():
    """Full qwen3-32b should count ~32-33B params."""
    total, active = T.param_count(get_config("qwen3-32b"))
    assert 28e9 < total < 38e9
    total, _ = T.param_count(get_config("llama3.2-1b"))
    assert 1.0e9 < total < 1.5e9
    total, active = T.param_count(get_config("dbrx-132b"))
    assert 120e9 < total < 145e9
    assert 30e9 < active < 45e9      # top-4 of 16 experts


def test_loss_decreases_dense():
    """A few steps on a fixed batch should reduce the loss (learnability)."""
    cfg = get_smoke("llama3.2-1b")
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = OptConfig(lr=3e-3, warmup_steps=1, total_steps=50)
    state = adamw.init_state(params, opt)
    step_fn = jax.jit(make_train_step(cfg, opt))
    batch = {"tokens": _tokens(cfg, 4, 64, jax.random.PRNGKey(7))}
    losses = []
    for _ in range(8):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
