"""End-to-end behaviour of the paper's system: co-execution runs the same
problem faster/equal and EXACT vs single device, the optimized HGuided is
the best scheduler under the calibrated testbed, and the two runtime
optimizations improve binary/ROI modes — the paper's headline claims as
executable assertions."""
import numpy as np

from repro.api import coexec
from repro.configs.paper_suite import BENCHES, SCHED_CONFIGS, sim_devices
from repro.core import metrics as M
from repro.core import programs as P
from repro.core.device import DeviceGroup
from repro.core.simulate import SimConfig, simulate, single_device_time


def test_claim_hguided_opt_is_best_scheduler():
    """Paper: 'the new load balancing algorithm is always the most
    efficient scheduling configuration'."""
    geo = {}
    for label, sched, kw in SCHED_CONFIGS:
        effs = []
        for bname, spec in BENCHES.items():
            devs = sim_devices(spec)
            base = SimConfig(opt_init=True, opt_buffers=True)
            singles = [single_device_time(spec.total_work, spec.lws, d, base)
                       for d in devs]
            ts = []
            for seed in range(5):
                cfg = SimConfig(scheduler=sched, scheduler_kwargs=kw,
                                opt_init=True, opt_buffers=True, seed=seed)
                ts.append(simulate(spec.total_work, spec.lws, devs,
                                   cfg).total_time)
            effs.append(M.efficiency(min(singles), sum(ts) / len(ts),
                                     singles))
        geo[label] = M.geomean(effs)
    # the paper's claim is about ITS seven configurations; the
    # beyond-paper HGuided steal (same carve law + leases/steals) may
    # tie or beat it, so compare among the paper configs only
    paper = {k: v for k, v in geo.items() if k != "HGuided steal"}
    assert max(paper, key=paper.get) == "HGuided opt"
    assert geo["HGuided steal"] + 1e-9 >= geo["HGuided opt"]
    assert geo["HGuided opt"] > geo["HGuided"]          # +~3% in the paper
    assert geo["HGuided opt"] > 0.8                     # paper: 0.84


def test_claim_coexecution_beats_fastest_device():
    """Paper: HGuided is 'always better than using the fastest device'."""
    for bname, spec in BENCHES.items():
        devs = sim_devices(spec)
        base = SimConfig(opt_init=True, opt_buffers=True)
        gpu_time = single_device_time(spec.total_work, spec.lws, devs[-1],
                                      base)
        cfg = SimConfig(scheduler="hguided_opt", opt_init=True,
                        opt_buffers=True, seed=0)
        co = simulate(spec.total_work, spec.lws, devs, cfg).total_time
        assert co < gpu_time, bname


def test_claim_optimizations_improve_both_modes():
    spec = BENCHES["gaussian"]
    devs = sim_devices(spec)
    t = {}
    for tag, oi, ob in (("unopt", False, False), ("opt", True, True)):
        cfg = SimConfig(scheduler="hguided_opt", opt_init=oi,
                        opt_buffers=ob, seed=0)
        r = simulate(spec.total_work, spec.lws, devs, cfg)
        t[tag] = (r.total_time, r.binary_time)
    assert t["opt"][0] < t["unopt"][0]     # ROI improves (buffers)
    assert t["opt"][1] < t["unopt"][1]     # binary improves (init)


def test_real_engine_end_to_end_exact():
    """Full co-execution on real devices, every program, vs oracle."""
    cases = {"gaussian": dict(h=256, w=128), "binomial": dict(n_options=2048),
             "nbody": dict(n_bodies=1024)}
    for name, kw in cases.items():
        ref = P.reference_output(name, **kw)
        prog = P.PROGRAMS[name](**kw)
        res = coexec(prog, [DeviceGroup("a", throttle=2.0),
                            DeviceGroup("b", throttle=1.0)])
        np.testing.assert_allclose(res.output, ref, rtol=1e-5, atol=1e-5)
        assert M.balance(res) > 0     # both devices participated
