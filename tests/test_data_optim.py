"""Data pipeline determinism/sliceability + optimizer + compression tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticPipeline
from repro.optim import adamw, compress as C
from repro.optim.adamw import OptConfig

SHAPE = ShapeConfig("t", seq_len=64, global_batch=8, kind="train")


def pipe():
    return SyntheticPipeline(get_smoke("llama3.2-1b"), SHAPE)


def test_batch_determinism():
    p1, p2 = pipe(), pipe()
    b1 = p1.batch_at(5)
    b2 = p2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_slice_rows_matches_full_batch():
    p = pipe()
    full = p.batch_at(3)["tokens"]
    a = p.slice_rows(3, 0, 3)["tokens"]
    b = p.slice_rows(3, 3, 5)["tokens"]
    got = np.concatenate([a, b], axis=0)
    assert got.shape == full.shape
    # row-range slicing must be consistent regardless of partitioning
    np.testing.assert_array_equal(got, np.concatenate(
        [p.slice_rows(3, 0, 3)["tokens"], p.slice_rows(3, 3, 5)["tokens"]]))


def test_markov_structure_learnable():
    p = pipe()
    toks = p.batch_at(0)["tokens"]
    succ = p._succ
    follows = (toks[:, 1:] == succ[toks[:, :-1]]).mean()
    assert follows > 0.5      # alpha=0.7 minus collisions


def test_iterator_prefetch():
    p = pipe()
    it = p.iterator(start_step=0, depth=2)
    b0 = next(it)
    np.testing.assert_array_equal(b0["tokens"], p.batch_at(0)["tokens"])
    b1 = next(it)
    np.testing.assert_array_equal(b1["tokens"], p.batch_at(1)["tokens"])


# ------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    opt = OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                    weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(params, opt)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(150):
        g = {"w": 2 * (state.params["w"] - target)}
        state, _ = adamw.apply_updates(state, g, opt)
    np.testing.assert_allclose(state.params["w"], target, atol=0.05)


def test_grad_clipping():
    opt = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1)
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(params, opt)
    g = {"w": jnp.full((4,), 1e6)}
    state, m = adamw.apply_updates(state, g, opt)
    assert float(m["grad_norm"]) > 1e5           # reported pre-clip
    assert bool(jnp.isfinite(state.params["w"]).all())
    assert float(jnp.abs(state.params["w"]).max()) < 1.0


def test_lr_schedule_shape():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    lrs = [float(adamw.lr_at(opt, s)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, rel=0.01)
    assert lrs[-1] == pytest.approx(0.1, rel=0.05)


# ----------------------------------------------------------- compression
@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_compression_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((64,)) * rng.uniform(0.1, 10))}
    deq, err = C.compress_decompress(g, None)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.abs(deq["w"] - g["w"]).max()) <= scale * 0.51 + 1e-6


def test_error_feedback_accumulates():
    """With error feedback, the quantization bias averages out: the sum of
    dequantized grads tracks the sum of true grads."""
    rng = np.random.default_rng(0)
    true = jnp.asarray(rng.standard_normal((256,)) * 1e-3)
    err = C.init_error({"w": true})["w"]
    total_deq = jnp.zeros_like(true)
    for _ in range(50):
        deq, new_err = C.compress_decompress({"w": true}, {"w": err})
        err = new_err["w"]
        total_deq = total_deq + deq["w"]
    np.testing.assert_allclose(total_deq / 50, true, atol=2e-5)
