"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — tests must see the
real single CPU device; only launch/dryrun.py forces 512 host devices."""
import pathlib
import sys

import numpy as np
import pytest

try:                                    # real hypothesis when installed...
    import hypothesis                   # noqa: F401
except ModuleNotFoundError:             # ...seeded fallback otherwise
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _hypothesis_fallback
    _hypothesis_fallback.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
