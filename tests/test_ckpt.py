"""Checkpoint: roundtrip, atomic commit, GC, async, restart semantics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as CK
from repro.configs import get_smoke
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.adamw import OptConfig


def make_state():
    cfg = get_smoke("llama3.2-1b")
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    return adamw.init_state(params, OptConfig())


def test_roundtrip(tmp_path):
    state = make_state()
    CK.save(state, str(tmp_path), 7)
    assert CK.latest_step(str(tmp_path)) == 7
    restored, step = CK.restore(state, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incomplete_checkpoint_ignored(tmp_path):
    state = make_state()
    CK.save(state, str(tmp_path), 1)
    # fake a torn write: directory without COMMIT
    os.makedirs(tmp_path / "step_00000009")
    assert CK.latest_step(str(tmp_path)) == 1


def test_gc_keeps_latest(tmp_path):
    state = make_state()
    for s in range(5):
        CK.save(state, str(tmp_path), s, keep=2)
    steps = CK.all_steps(str(tmp_path))
    assert sorted(steps) == [3, 4]


def test_async_checkpointer(tmp_path):
    state = make_state()
    ck = CK.AsyncCheckpointer(str(tmp_path), keep=2)
    ck.save(state, 11)
    ck.wait()
    assert CK.latest_step(str(tmp_path)) == 11


def test_restart_resumes_training(tmp_path):
    """Save mid-run, restore into a fresh state, verify training continues
    from the same point (deterministic data => identical next step)."""
    from repro.training.step import make_train_step
    cfg = get_smoke("llama3.2-1b")
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = OptConfig(lr=1e-3, warmup_steps=1)
    state = adamw.init_state(params, opt)
    step_fn = jax.jit(make_train_step(cfg, opt))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0,
                                          cfg.vocab_size)}
    state, _ = step_fn(state, batch)
    CK.save(state, str(tmp_path), int(state.step))
    restored, _ = CK.restore(state, str(tmp_path))
    restored = jax.tree.map(jnp.asarray, restored)
    s1, m1 = step_fn(state, batch)
    s2, m2 = step_fn(restored, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-6)
