"""Offload modes through the session: BINARY vs ROI contracts, workload
registration, sub-region submits, and the per-phase breakdown."""
import numpy as np
import pytest

from repro.api import (EngineSession, OffloadMode, PhaseBreakdown, Region,
                       coexec)
from repro.core import programs as P
from repro.core.device import DeviceGroup

GAUSS2D_KW = dict(h=128, w=96, lws=(16, 8))


def devices3():
    return [DeviceGroup("cpu", throttle=3.0),
            DeviceGroup("igpu", throttle=1.5),
            DeviceGroup("gpu", throttle=1.0)]


@pytest.fixture(scope="module")
def gauss2d_ref():
    return P.reference_output("gaussian2d", **GAUSS2D_KW)


# ----------------------------------------------------------- 2-D programs

def test_2d_program_full_region_exact(gauss2d_ref):
    res = coexec(P.PROGRAMS["gaussian2d"](**GAUSS2D_KW), devices3())
    assert res.output.shape == (128, 96)
    np.testing.assert_allclose(res.output, gauss2d_ref,
                               rtol=1e-5, atol=1e-5)
    for p in res.packets:
        assert p.region is not None and p.region.ndim == 2


def test_2d_ray_program_exact():
    ref = P.reference_output("ray1_2d", px=64)
    res = coexec(P.PROGRAMS["ray1_2d"](px=64), devices3())
    assert res.output.shape == (64, 64 * 3)
    np.testing.assert_allclose(res.output, ref, rtol=1e-5, atol=1e-5)


def test_2d_mandelbrot_roi_matches_full_slice():
    px = 64
    ref = P.reference_output("mandelbrot2d", px=px)
    prog = P.PROGRAMS["mandelbrot2d"](px=px)
    roi = Region.rect(16, 24, lws=(8, 8), offset=(8, 16))
    res = coexec(prog, devices3(), region=roi)
    assert res.output.shape == (16, 24)
    np.testing.assert_array_equal(res.output, ref[8:24, 16:40])


# ------------------------------------------------------------- ROI mode

def test_roi_submits_reuse_registered_workload(gauss2d_ref):
    prog = P.PROGRAMS["gaussian2d"](**GAUSS2D_KW)
    roi = Region.rect(32, 48, lws=(16, 8), offset=(16, 8))
    with EngineSession(devices3(), init_cost_s=0.05) as session:
        session.register_workload(prog)
        assert session.init_payments == 3       # init paid at registration
        assert "gaussian2d" in session.workloads
        for _ in range(3):                      # warm back-to-back submits
            r = session.submit(prog, region=roi,
                               mode=OffloadMode.ROI).result()
            np.testing.assert_allclose(r.output, gauss2d_ref[16:48, 8:56],
                                       rtol=1e-5, atol=1e-5)
        assert session.init_payments == 3       # nothing rebuilt
        assert all(v == 1 for v in session.buffer_registry.values())
        session.unregister_workload("gaussian2d")
        assert "gaussian2d" not in session.workloads
        assert session.executables == {}


def test_roi_requires_registration():
    prog = P.PROGRAMS["gaussian2d"](**GAUSS2D_KW)
    with EngineSession(devices3()) as session:
        with pytest.raises(RuntimeError, match="register_workload"):
            session.submit(prog, mode=OffloadMode.ROI)


def test_region_validation_errors():
    prog = P.PROGRAMS["gaussian2d"](**GAUSS2D_KW)
    with EngineSession(devices3()) as session:
        with pytest.raises(ValueError, match="not contained"):
            session.submit(prog, region=Region.rect(256, 96, lws=(16, 8)))
        with pytest.raises(ValueError, match="lws-aligned"):
            session.submit(prog, region=Region.rect(16, 8, lws=(16, 8),
                                                    offset=(8, 8)))
        with pytest.raises(ValueError, match="dims"):
            session.submit(prog, region=Region.line(16))


def test_roi_1d_subregion(gauss2d_ref):
    """1-D programs accept line sub-regions too (offset in work-groups)."""
    kw = dict(h=256, w=64)
    prog = P.PROGRAMS["gaussian"](**kw)
    ref = P.reference_output("gaussian", **kw)
    lws_rows = P.gaussian_ops.LWS               # rows per work-group
    res = coexec(prog, devices3(), region=Region.line(1, offset=1))
    np.testing.assert_allclose(res.output, ref[lws_rows:2 * lws_rows],
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- BINARY mode

def test_binary_mode_pays_init_every_submit_and_evicts():
    prog = P.PROGRAMS["gaussian2d"](**GAUSS2D_KW)
    with EngineSession(devices3(), init_cost_s=0.02) as session:
        for k in (1, 2):
            r = session.submit(prog, mode=OffloadMode.BINARY).result()
            assert session.init_payments == 3 * k   # fresh build per submit
            assert session.executables == {}        # torn down after
            assert r.phases is not None
            # init phase charges the emulated driver cost to THIS run
            assert r.phases.init_s >= 0.02


def test_binary_refuses_registered_workload_then_evicts_plain_cache():
    prog = P.PROGRAMS["gaussian2d"](**GAUSS2D_KW)
    with EngineSession(devices3()) as session:
        session.register_workload(prog)
        # refusing protects the ROI contract: a BINARY teardown would
        # silently de-warm subsequent ROI submits
        with pytest.raises(ValueError, match="unregister_workload"):
            session.submit(prog, mode=OffloadMode.BINARY)
        session.unregister_workload("gaussian2d")
        session.run(prog)                           # plain cached submit
        assert len(session.executables) == 3
        session.submit(prog, mode=OffloadMode.BINARY).result()
        assert session.executables == {}            # teardown dropped it


def test_roi_rejects_different_instance_under_same_name():
    prog = P.PROGRAMS["gaussian2d"](**GAUSS2D_KW)
    impostor = P.PROGRAMS["gaussian2d"](**GAUSS2D_KW)   # same name, new data
    with EngineSession(devices3()) as session:
        session.register_workload(prog)
        with pytest.raises(ValueError, match="different program instance"):
            session.submit(impostor, mode=OffloadMode.ROI)


# ------------------------------------------------------ phase breakdown

def test_phase_breakdown_identity(gauss2d_ref):
    res = coexec(P.PROGRAMS["gaussian2d"](**GAUSS2D_KW), devices3(),
                 init_cost_s=0.03)
    ph = res.phases
    assert isinstance(ph, PhaseBreakdown)
    assert ph.roi_s == res.total_time
    assert ph.offload_s >= ph.roi_s
    assert ph.init_s >= 0.03                    # compiles inside init phase
    assert res.binary_time == pytest.approx(ph.binary, rel=1e-6)
    assert ph.management == pytest.approx(ph.binary - ph.roi_s, rel=1e-6)


def test_roi_warm_submits_beat_binary(gauss2d_ref):
    """The paper's asymmetry, as a coarse invariant at test scale: a warm
    ROI submit must not pay the per-run init a BINARY submit pays."""
    prog = P.PROGRAMS["gaussian2d"](**GAUSS2D_KW)
    roi = Region.rect(64, 96, lws=(16, 8), offset=(32, 0))
    with EngineSession(devices3(), init_cost_s=0.1) as session:
        session.register_workload(prog)
        session.submit(prog, region=roi, mode=OffloadMode.ROI).result()
        warm = session.submit(prog, region=roi,
                              mode=OffloadMode.ROI).result()
        session.unregister_workload(prog.name)
        cold = session.submit(prog, region=roi,
                              mode=OffloadMode.BINARY).result()
    assert warm.phases.init_s < 0.1             # no driver cost re-paid
    assert cold.phases.init_s >= 0.1
    assert cold.phases.binary > warm.phases.binary


def test_simulator_fills_phases():
    from repro.core.simulate import SimConfig, simulate, SimDevice
    devs = [SimDevice("gpu", throughput=1000.0),
            SimDevice("cpu", throughput=250.0)]
    r = simulate(4096, 8, devs, SimConfig())
    assert r.phases is not None
    assert r.phases.roi_s == r.total_time
    assert r.binary_time == pytest.approx(r.phases.binary)
