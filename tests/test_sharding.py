"""Sharding-resolver tests: divisibility fallbacks, axis-conflict handling,
FSDP extra shard — the rules that keep all ten archs partitionable on the
fixed 16x16 / 2x16x16 meshes."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import ShardingResolver


def mesh2d(data=2, model=2):
    devs = np.array(jax.devices()[:1] * (data * model)).reshape(data, model)
    return Mesh(devs, ("data", "model"))


@pytest.fixture
def res():
    return ShardingResolver(mesh2d())


def test_basic_tp(res):
    assert res.spec(("d_model", "heads", None), (64, 8, 16)) == \
        P(None, "model", None)


def test_divisibility_fallback_heads(res):
    # 7 heads don't divide model=2 -> replicate (no crash)
    s = res.spec(("d_model", "heads", None), (64, 7, 16))
    assert s == P(None, None, None)


def test_vocab_fallback_to_dmodel():
    r = ShardingResolver(mesh2d(2, 2))
    # odd vocab can't shard on model; d_model picks nothing by default
    s = r.spec(("vocab", "d_model"), (151655, 896))
    assert s == P(None, None)
    # FSDP pass shards the largest eligible dim over data instead
    s = r.spec(("vocab", "d_model"), (151655, 896), param=True)
    assert s == P(None, None)   # fsdp off by default
    r_fsdp = ShardingResolver(mesh2d(2, 2), fsdp=True)
    s = r_fsdp.spec(("vocab", "d_model"), (151655, 896), param=True)
    assert s == P(None, "data")


def test_batch_over_pod_and_data():
    devs = np.array(jax.devices()[:1] * 8).reshape(2, 2, 2)
    mesh = Mesh(devs, ("pod", "data", "model"))
    r = ShardingResolver(mesh)
    s = r.spec(("batch", "seq", None), (8, 16, 4))
    assert s == P(("pod", "data"), None, None)


def test_batch1_falls_to_seq():
    r = ShardingResolver(mesh2d(2, 2))
    s = r.spec(("batch", "seq", None), (1, 16, 4))
    assert s == P(None, "data", None)


def test_no_axis_reuse_within_tensor():
    r = ShardingResolver(mesh2d(2, 2))
    # experts gets model first (higher priority), then d_ff can't reuse it
    s = r.spec(("experts", "d_ff"), (4, 8))
    assert s == P("model", None)


def test_kv_seq_on_model_when_kv_heads_small():
    r = ShardingResolver(mesh2d(2, 4))
    # kv_heads=2 can't fill model=4... 2 % 4 != 0 -> kv_seq takes model
    s = r.spec(("batch", "kv_seq", "kv_heads", None), (8, 64, 2, 16))
    assert s == P("data", "model", None, None)


def test_fsdp_prefers_largest_dim():
    r = ShardingResolver(mesh2d(2, 2), fsdp=True)
    s = r.spec(("d_model", "d_ff"), (64, 256), param=True)
    # d_ff -> model (rule), then fsdp shards d_model over data
    assert s == P("data", "model")


def test_tree_shardings_shape():
    r = ShardingResolver(mesh2d())
    axes = {"w": ("d_model", "d_ff"), "b": ("d_ff",)}
    shapes = {"w": (8, 16), "b": (16,)}
    specs = r.tree_specs(axes, shapes)
    assert specs["w"] == P(None, "model")
    assert specs["b"] == P("model")


def test_all_arch_params_resolve_on_production_shapes():
    """Every param of every arch gets a valid spec on a 16x16-shaped rule
    check (divisibility probed against the real mesh sizes)."""
    from repro.configs import ARCH_IDS, get_config
    from repro.models.transformer import init_abstract

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), dtype=object)

    r = ShardingResolver(FakeMesh(), fsdp=True)
    for arch in ARCH_IDS:
        params, axes = init_abstract(get_config(arch))
        flat_p = jax.tree.leaves(params)
        flat_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple)
                                 and all(isinstance(e, (str, type(None)))
                                         for e in x))
        assert len(flat_p) == len(flat_a), arch
        for p, a in zip(flat_p, flat_a):
            spec = r.spec(a, p.shape, param=True)
            # every sharded dim must divide
            for dim, ax in zip(p.shape, spec):
                if ax is None:
                    continue
                sz = 16 if isinstance(ax, str) else 16 ** len(ax)
                assert dim % sz == 0, (arch, a, p.shape, spec)
