"""Scheduler unit + property tests (the paper's §II-B invariants)."""
import math
import random
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import (DeviceProfile, DynamicScheduler,
                                  HGuidedOptScheduler, HGuidedScheduler,
                                  StaticScheduler, make_scheduler,
                                  tuned_profiles)

ALL_SCHEDULERS = ["static", "static_rev", "dynamic", "hguided",
                  "hguided_opt", "hguided_deadline", "hguided_steal"]


def drain(sched, n_dev):
    """Round-robin drain; returns per-device packet lists."""
    out = {i: [] for i in range(n_dev)}
    active = set(range(n_dev))
    while active:
        for i in list(active):
            pkt = sched.next_packet(i)
            if pkt is None:
                active.discard(i)
            else:
                out[i].append(pkt)
    return out


def coverage_ok(packets, total):
    """Every work-group covered exactly once."""
    ivs = sorted((p.offset, p.offset + p.size) for p in packets)
    pos = 0
    for a, b in ivs:
        if a != pos:
            return False
        pos = b
    return pos == total


DEVICES3 = [DeviceProfile("cpu", 1.0), DeviceProfile("igpu", 3.0),
            DeviceProfile("gpu", 7.0)]


@pytest.mark.parametrize("name", ["static", "static_rev", "dynamic",
                                  "hguided", "hguided_opt"])
def test_exactly_once_coverage(name):
    sched = make_scheduler(name, 1000, 8, [DeviceProfile(d.name, d.power)
                                           for d in DEVICES3])
    out = drain(sched, 3)
    allp = [p for ps in out.values() for p in ps]
    assert coverage_ok(allp, 1000)


@given(total=st.integers(1, 5000), lws=st.integers(1, 64),
       powers=st.lists(st.floats(0.05, 10.0), min_size=1, max_size=9),
       name=st.sampled_from(["static", "static_rev", "dynamic", "hguided",
                             "hguided_opt"]))
@settings(max_examples=120, deadline=None)
def test_property_coverage_and_alignment(total, lws, powers, name):
    devs = [DeviceProfile(f"d{i}", p) for i, p in enumerate(powers)]
    sched = make_scheduler(name, total, lws, devs)
    out = drain(sched, len(devs))
    allp = [p for ps in out.values() for p in ps]
    assert coverage_ok(allp, total)
    # all packets except per-device finals are lws-aligned in size
    for p in allp:
        assert p.size > 0
        if p.offset + p.size != total:
            assert p.size % lws == 0 or p.size == total


def test_hguided_formula_first_packet():
    G, lws = 10000, 10
    devs = [DeviceProfile("a", 2.0, min_mult=1, k=2.0),
            DeviceProfile("b", 6.0, min_mult=1, k=2.0)]
    sched = HGuidedScheduler(G, lws, devs)
    pkt = sched.next_packet(1)
    expect = math.ceil(G * 6.0 / (2.0 * 2 * 8.0))
    expect = lws * math.ceil(expect / lws)
    assert pkt.size == expect


def test_hguided_sizes_decrease():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = HGuidedScheduler(100000, 4, devs)
    sizes = []
    while True:
        p = sched.next_packet(0)
        if p is None:
            break
        sizes.append(p.size)
    assert sizes == sorted(sizes, reverse=True) or \
        all(b <= a for a, b in zip(sizes, sizes[1:]))
    assert sizes[-1] >= 4  # min packet >= lws


def test_hguided_min_packet_respected():
    devs = [DeviceProfile("a", 1.0, min_mult=5, k=4.0)]
    sched = HGuidedScheduler(1000, 8, devs)
    while True:
        p = sched.next_packet(0)
        if p is None:
            break
        if p.offset + p.size != 1000:
            assert p.size >= 5 * 8


def test_static_order_matters():
    devs = [DeviceProfile("cpu", 1.0), DeviceProfile("gpu", 9.0)]
    s1 = StaticScheduler(1000, 10, devs)
    s2 = StaticScheduler(1000, 10, devs, order=[1, 0])
    p1 = s1.next_packet(0)   # cpu first chunk at offset 0
    p2 = s2.next_packet(0)   # reversed: cpu chunk after gpu's
    assert p1.offset == 0
    assert p2.offset > 0


def test_static_proportional():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 3.0)]
    sched = StaticScheduler(4000, 1, devs)
    pa = sched.next_packet(0)
    pb = sched.next_packet(1)
    assert abs(pa.size - 1000) <= 2
    assert abs(pb.size - 3000) <= 2


def test_dynamic_packet_count():
    devs = [DeviceProfile("a", 1.0)]
    sched = DynamicScheduler(1024, 1, devs, n_packets=64)
    out = drain(sched, 1)
    assert len(out[0]) == 64


def test_requeue_fault_tolerance():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = DynamicScheduler(100, 1, devs, n_packets=10)
    p = sched.next_packet(0)
    sched.requeue(p)
    out = drain(sched, 2)
    allp = [q for ps in out.values() for q in ps]
    assert coverage_ok(allp, 100)


def test_requeue_preserves_seq_and_sets_retried():
    """Provenance: a requeued packet is re-issued with its ORIGINAL seq and
    retried=True — RunResult.packets never reports more sequence numbers
    than packets actually carved."""
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = DynamicScheduler(100, 1, devs, n_packets=10)
    p = sched.next_packet(0)
    assert not p.retried
    sched.requeue(p)
    again = sched.next_packet(1)
    assert (again.offset, again.size, again.seq) == (p.offset, p.size, p.seq)
    assert again.retried
    assert again.device == 1            # re-issued to the surviving device
    # the next carve continues the seq stream without a gap
    fresh = sched.next_packet(0)
    assert fresh.seq == p.seq + 1 and not fresh.retried


def _drain_with_faults(sched, n_dev, die_after, requeue_budget, seed):
    """Round-robin drain with injected mid-run faults, mirroring the
    engine's semantics: a death happens while HOLDING a pulled packet
    (run_packet raises), which is then requeued; a transient requeue
    returns the packet and the device keeps pulling.  Device 0 is
    immortal so the work cannot strand.  Returns executed packets."""
    rng = random.Random(seed)
    executed = []
    pulled = {i: 0 for i in range(n_dev)}
    alive = set(range(n_dev))
    budget = requeue_budget
    while True:
        # a device that sees None stays alive: a later death may requeue
        # work it must absorb (the engine's drained/alive_others loop)
        progress = False
        for i in sorted(alive):
            pkt = sched.next_packet(i)
            if pkt is None:
                continue
            progress = True
            pulled[i] += 1
            if i != 0 and die_after[i] is not None \
                    and pulled[i] > die_after[i]:
                sched.requeue(pkt)          # device dies holding the packet
                sched.mark_dead(i)          # releases pre-assigned work
                alive.discard(i)
                continue
            if budget > 0 and not pkt.retried and rng.random() < 0.3:
                budget -= 1                  # transient failure: retry later
                sched.requeue(pkt)
                continue
            executed.append(pkt)
        if not progress:
            return executed


@given(total=st.integers(1, 4000), lws=st.integers(1, 32),
       powers=st.lists(st.floats(0.05, 10.0), min_size=2, max_size=6),
       name=st.sampled_from(ALL_SCHEDULERS),
       deaths=st.lists(st.integers(0, 6), min_size=6, max_size=6),
       requeue_budget=st.integers(0, 3),
       seed=st.integers(0, 2**16))
@settings(max_examples=120, deadline=None)
def test_property_fault_tolerant_coverage(total, lws, powers, name, deaths,
                                          requeue_budget, seed):
    """Every scheduler covers [0, G) exactly once — no gaps, no overlaps —
    under random mid-run requeues and device deaths (satellite invariant
    behind the API's fault-tolerance guarantee)."""
    devs = [DeviceProfile(f"d{i}", p) for i, p in enumerate(powers)]
    sched = make_scheduler(name, total, lws, devs)
    # die_after[i] >= 4 means immortal; device 0 always survives
    die_after = [None] + [d if d < 4 else None
                          for d in deaths[1:len(devs)]]
    executed = _drain_with_faults(sched, len(devs), die_after,
                                  requeue_budget, seed)
    assert coverage_ok(executed, total)
    # provenance: every committed packet has a unique seq
    seqs = [p.seq for p in executed]
    assert len(seqs) == len(set(seqs))
    assert sched.remaining() == 0


def test_tuned_profiles_paper_laws():
    devs = [DeviceProfile("cpu", 1.0), DeviceProfile("igpu", 3.0),
            DeviceProfile("gpu", 7.0)]
    out = tuned_profiles(devs)
    # (a)/(b): more power => larger m, smaller k; exact triple for n=3
    assert [d.min_mult for d in out] == [1, 15, 30]
    assert [d.k for d in out] == [3.5, 1.5, 1.0]


def test_hguided_opt_fleet_scale_adaptation():
    devs = [DeviceProfile(f"g{i}", 1.0) for i in range(64)]
    sched = HGuidedOptScheduler(64 * 64, 1, devs)
    assert all(d.k >= 2.0 for d in sched.devices)
    assert all(d.min_mult == 1 for d in sched.devices)


# ---------------------------------------------------------------- leases

def test_retry_reissue_is_fifo():
    """Regression: requeued packets must re-issue OLDEST FIRST — LIFO
    draining re-issued a straggler's early packet last, extending the
    tail."""
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = DynamicScheduler(100, 1, devs, n_packets=10)
    p1 = sched.next_packet(0)
    p2 = sched.next_packet(0)
    p3 = sched.next_packet(0)
    sched.requeue(p1)
    sched.requeue(p2)
    sched.requeue(p3)
    out = [sched.next_packet(1) for _ in range(3)]
    assert [p.offset for p in out] == [p1.offset, p2.offset, p3.offset]
    assert all(p.retried for p in out)
    # the lease path drains retries in the same FIFO order
    for p in out:
        sched.requeue(p)
    sched.lease(1, k=3)
    leased = [sched.acquire(1) for _ in range(3)]
    assert [p.offset for p in leased] == [p1.offset, p2.offset, p3.offset]
    for _ in leased:
        sched.release(1)


@pytest.mark.parametrize("name", ALL_SCHEDULERS)
def test_lease_counts_as_remaining(name):
    """Leased-but-unexecuted packets are outstanding work: admission and
    slack caps must see them (satellite invariant)."""
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 3.0)]
    sched = make_scheduler(name, 1000, 8, devs)
    before = sched.remaining()
    assert before == 1000
    got = sched.lease(0, k=4)
    assert got >= 1
    assert sched.remaining() == before          # leases still count
    pkt = sched.acquire(0)
    assert pkt is not None
    assert sched.remaining() == before - pkt.size  # popped -> in flight
    sched.release(0)


@pytest.mark.parametrize("name", ALL_SCHEDULERS)
def test_mark_dead_reclaims_leased_packets(name):
    """A dead device's leased packets re-enter the retry pool; survivors
    drain to exact cover."""
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0),
            DeviceProfile("c", 1.0)]
    sched = make_scheduler(name, 1200, 8, devs)
    sched.lease(1, k=6)
    sched.lease(2, k=6)
    sched.mark_dead(1)
    sched.mark_dead(2)
    executed = []
    while True:
        pkt = sched.acquire(0)
        if pkt is None:
            break
        executed.append(pkt)
        sched.release(0)
    assert coverage_ok(executed, 1200)
    assert sched.remaining() == 0
    assert sched.drained()


def test_lease_respects_explicit_k_and_adaptive_growth():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = DynamicScheduler(10000, 1, devs, n_packets=1000)
    assert sched.lease(0, k=5) == 5
    drained = [sched.acquire(0) for _ in range(5)]
    assert all(p is not None for p in drained)
    for _ in drained:
        sched.release(0)
    # adaptive: with fast packets the granted lease size must grow
    # geometrically from 1 (one lock crossing buys a growing plan)
    sizes = []
    for _ in range(6):
        sched.note_packet_latency(1, 1e-5)
        got = sched.lease(1)
        sizes.append(got)
        for _ in range(got):
            assert sched.acquire(1) is not None
            sched.release(1)
    assert sizes[0] <= 2            # first grant: k doubled at most once
    assert sizes[-1] > sizes[0]
    assert any(b > a for a, b in zip(sizes, sizes[1:]))


def test_lease_tail_budget_shrinks():
    """Near the tail a lease may not hoard: granted work is capped at
    half the device's power-proportional share of what remains."""
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = DynamicScheduler(64, 1, devs, n_packets=64)
    sched.note_packet_latency(0, 1e-6)        # fast: k wants to explode
    for _ in range(5):
        sched.lease(0)
        while sched.acquire(0) is not None:
            sched.release(0)
    # all work executed by device 0; each lease was budget-capped
    assert sched.remaining() == 0


def test_steal_takes_back_half_of_largest_victim():
    # steal() is a SchedulerBase method (the property harness drives it
    # on every scheduler); equal dynamic chunks make it deterministic
    devs = [DeviceProfile(f"d{i}", 1.0) for i in range(3)]
    sched = make_scheduler("dynamic", 4096, 1, devs, n_packets=64)
    sched.lease(1, k=2)
    sched.lease(2, k=8)                        # the largest victim
    stolen = sched.steal(0)
    assert stolen == 4                         # back half of 8
    assert sched.stats.steals == 1
    # stolen packets are re-stamped to the thief, keep their seq, and
    # arrive in FIFO offset order
    a = sched.acquire(0)
    b = sched.acquire(0)
    assert a.device == 0 and b.device == 0
    assert a.offset < b.offset
    sched.release(0)
    sched.release(0)
    assert sched.remaining() == 4096 - a.size - b.size


def test_steal_never_empties_a_single_packet_lease():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = make_scheduler("hguided_steal", 1000, 8, devs)
    assert sched.lease(1, k=1) == 1
    assert sched.steal(0) == 0                 # owner keeps at least one


def test_acquire_release_drained_protocol():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = DynamicScheduler(16, 1, devs, n_packets=2)
    a = sched.acquire(0)
    b = sched.acquire(1)
    assert a is not None and b is not None
    assert sched.remaining() == 0
    assert not sched.drained()                 # both still in flight
    sched.release(0)
    assert not sched.drained()
    sched.requeue(b)                           # device 1 fails its packet
    sched.release(1)
    assert not sched.drained()                 # retry re-entered the pool
    c = sched.acquire(0)
    assert c is not None and c.retried and c.seq == b.seq
    sched.release(0)
    assert sched.drained()
    assert all(w >= 0 for w in sched.sched_wait_s())


def _lease_fault_harness(sched, n_dev, ops):
    """Drive random lease/steal/requeue/death ops, then drain; mirrors
    the engine's acquire/release contract (device 0 is immortal)."""
    executed = []
    alive = set(range(n_dev))
    for dev, action, k in ops:
        i = dev % n_dev
        if i not in alive:
            continue
        if action == 0:                        # leased pull + execute
            pkt = sched.acquire(i)
            if pkt is not None:
                executed.append(pkt)
                sched.note_packet_latency(i, 1e-5)
                sched.release(i)
        elif action == 1:                      # per-packet pull + execute
            pkt = sched.next_packet(i)
            if pkt is not None:
                executed.append(pkt)
                sched.release(i)
        elif action == 2:                      # explicit lease plan
            sched.lease(i, k)
        elif action == 3:                      # steal from the largest
            sched.steal(i)
        elif action == 4:                      # transient failure
            pkt = sched.acquire(i)
            if pkt is not None:
                sched.requeue(pkt)
                sched.release(i)
        elif action == 5 and i != 0:           # death holding a packet
            pkt = sched.acquire(i)
            if pkt is not None:
                sched.requeue(pkt)
                sched.release(i)
            sched.mark_dead(i)
            alive.discard(i)
    while True:
        progress = False
        for i in sorted(alive):
            pkt = sched.acquire(i)
            if pkt is not None:
                executed.append(pkt)
                sched.release(i)
                progress = True
        if not progress:
            return executed


@given(total=st.integers(1, 4000), lws=st.integers(1, 32),
       powers=st.lists(st.floats(0.05, 10.0), min_size=2, max_size=6),
       name=st.sampled_from(ALL_SCHEDULERS),
       ops=st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                              st.integers(1, 8)),
                    min_size=0, max_size=40))
@settings(max_examples=120, deadline=None)
def test_property_lease_steal_fault_coverage(total, lws, powers, name, ops):
    """Satellite property suite: random lease sizes, steals, requeues and
    device deaths on EVERY registered scheduler still yield exact cover,
    unique seqs, non-negative sched-wait accounting, and a drained
    scheduler."""
    devs = [DeviceProfile(f"d{i}", p) for i, p in enumerate(powers)]
    sched = make_scheduler(name, total, lws, devs)
    executed = _lease_fault_harness(sched, len(devs), ops)
    assert coverage_ok(executed, total)
    seqs = [p.seq for p in executed]
    assert len(seqs) == len(set(seqs))
    assert sched.remaining() == 0
    assert sched.drained()
    assert all(w >= 0 for w in sched.sched_wait_s())


def test_thread_safety():
    devs = [DeviceProfile(f"d{i}", 1.0 + i) for i in range(4)]
    sched = HGuidedScheduler(20000, 4, devs)
    got = []
    lock = threading.Lock()

    def worker(i):
        while True:
            p = sched.next_packet(i)
            if p is None:
                return
            with lock:
                got.append(p)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert coverage_ok(got, 20000)


def test_thread_safety_leased_acquire():
    """Concurrent acquire/release (the leased hot path, with steals) on
    the steal scheduler still covers exactly once."""
    devs = [DeviceProfile(f"d{i}", 1.0 + i) for i in range(4)]
    sched = make_scheduler("hguided_steal", 20000, 4, devs)
    got = []
    lock = threading.Lock()

    def worker(i):
        while True:
            p = sched.acquire(i)
            if p is None:
                return
            sched.note_packet_latency(i, 1e-5)
            with lock:
                got.append(p)
            sched.release(i)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert coverage_ok(got, 20000)
    assert sched.drained()
