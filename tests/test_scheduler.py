"""Scheduler unit + property tests (the paper's §II-B invariants)."""
import math
import random
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import (DeviceProfile, DynamicScheduler,
                                  HGuidedOptScheduler, HGuidedScheduler,
                                  StaticScheduler, make_scheduler,
                                  tuned_profiles)

ALL_SCHEDULERS = ["static", "static_rev", "dynamic", "hguided",
                  "hguided_opt", "hguided_deadline"]


def drain(sched, n_dev):
    """Round-robin drain; returns per-device packet lists."""
    out = {i: [] for i in range(n_dev)}
    active = set(range(n_dev))
    while active:
        for i in list(active):
            pkt = sched.next_packet(i)
            if pkt is None:
                active.discard(i)
            else:
                out[i].append(pkt)
    return out


def coverage_ok(packets, total):
    """Every work-group covered exactly once."""
    ivs = sorted((p.offset, p.offset + p.size) for p in packets)
    pos = 0
    for a, b in ivs:
        if a != pos:
            return False
        pos = b
    return pos == total


DEVICES3 = [DeviceProfile("cpu", 1.0), DeviceProfile("igpu", 3.0),
            DeviceProfile("gpu", 7.0)]


@pytest.mark.parametrize("name", ["static", "static_rev", "dynamic",
                                  "hguided", "hguided_opt"])
def test_exactly_once_coverage(name):
    sched = make_scheduler(name, 1000, 8, [DeviceProfile(d.name, d.power)
                                           for d in DEVICES3])
    out = drain(sched, 3)
    allp = [p for ps in out.values() for p in ps]
    assert coverage_ok(allp, 1000)


@given(total=st.integers(1, 5000), lws=st.integers(1, 64),
       powers=st.lists(st.floats(0.05, 10.0), min_size=1, max_size=9),
       name=st.sampled_from(["static", "static_rev", "dynamic", "hguided",
                             "hguided_opt"]))
@settings(max_examples=120, deadline=None)
def test_property_coverage_and_alignment(total, lws, powers, name):
    devs = [DeviceProfile(f"d{i}", p) for i, p in enumerate(powers)]
    sched = make_scheduler(name, total, lws, devs)
    out = drain(sched, len(devs))
    allp = [p for ps in out.values() for p in ps]
    assert coverage_ok(allp, total)
    # all packets except per-device finals are lws-aligned in size
    for p in allp:
        assert p.size > 0
        if p.offset + p.size != total:
            assert p.size % lws == 0 or p.size == total


def test_hguided_formula_first_packet():
    G, lws = 10000, 10
    devs = [DeviceProfile("a", 2.0, min_mult=1, k=2.0),
            DeviceProfile("b", 6.0, min_mult=1, k=2.0)]
    sched = HGuidedScheduler(G, lws, devs)
    pkt = sched.next_packet(1)
    expect = math.ceil(G * 6.0 / (2.0 * 2 * 8.0))
    expect = lws * math.ceil(expect / lws)
    assert pkt.size == expect


def test_hguided_sizes_decrease():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = HGuidedScheduler(100000, 4, devs)
    sizes = []
    while True:
        p = sched.next_packet(0)
        if p is None:
            break
        sizes.append(p.size)
    assert sizes == sorted(sizes, reverse=True) or \
        all(b <= a for a, b in zip(sizes, sizes[1:]))
    assert sizes[-1] >= 4  # min packet >= lws


def test_hguided_min_packet_respected():
    devs = [DeviceProfile("a", 1.0, min_mult=5, k=4.0)]
    sched = HGuidedScheduler(1000, 8, devs)
    while True:
        p = sched.next_packet(0)
        if p is None:
            break
        if p.offset + p.size != 1000:
            assert p.size >= 5 * 8


def test_static_order_matters():
    devs = [DeviceProfile("cpu", 1.0), DeviceProfile("gpu", 9.0)]
    s1 = StaticScheduler(1000, 10, devs)
    s2 = StaticScheduler(1000, 10, devs, order=[1, 0])
    p1 = s1.next_packet(0)   # cpu first chunk at offset 0
    p2 = s2.next_packet(0)   # reversed: cpu chunk after gpu's
    assert p1.offset == 0
    assert p2.offset > 0


def test_static_proportional():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 3.0)]
    sched = StaticScheduler(4000, 1, devs)
    pa = sched.next_packet(0)
    pb = sched.next_packet(1)
    assert abs(pa.size - 1000) <= 2
    assert abs(pb.size - 3000) <= 2


def test_dynamic_packet_count():
    devs = [DeviceProfile("a", 1.0)]
    sched = DynamicScheduler(1024, 1, devs, n_packets=64)
    out = drain(sched, 1)
    assert len(out[0]) == 64


def test_requeue_fault_tolerance():
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = DynamicScheduler(100, 1, devs, n_packets=10)
    p = sched.next_packet(0)
    sched.requeue(p)
    out = drain(sched, 2)
    allp = [q for ps in out.values() for q in ps]
    assert coverage_ok(allp, 100)


def test_requeue_preserves_seq_and_sets_retried():
    """Provenance: a requeued packet is re-issued with its ORIGINAL seq and
    retried=True — RunResult.packets never reports more sequence numbers
    than packets actually carved."""
    devs = [DeviceProfile("a", 1.0), DeviceProfile("b", 1.0)]
    sched = DynamicScheduler(100, 1, devs, n_packets=10)
    p = sched.next_packet(0)
    assert not p.retried
    sched.requeue(p)
    again = sched.next_packet(1)
    assert (again.offset, again.size, again.seq) == (p.offset, p.size, p.seq)
    assert again.retried
    assert again.device == 1            # re-issued to the surviving device
    # the next carve continues the seq stream without a gap
    fresh = sched.next_packet(0)
    assert fresh.seq == p.seq + 1 and not fresh.retried


def _drain_with_faults(sched, n_dev, die_after, requeue_budget, seed):
    """Round-robin drain with injected mid-run faults, mirroring the
    engine's semantics: a death happens while HOLDING a pulled packet
    (run_packet raises), which is then requeued; a transient requeue
    returns the packet and the device keeps pulling.  Device 0 is
    immortal so the work cannot strand.  Returns executed packets."""
    rng = random.Random(seed)
    executed = []
    pulled = {i: 0 for i in range(n_dev)}
    alive = set(range(n_dev))
    budget = requeue_budget
    while True:
        # a device that sees None stays alive: a later death may requeue
        # work it must absorb (the engine's drained/alive_others loop)
        progress = False
        for i in sorted(alive):
            pkt = sched.next_packet(i)
            if pkt is None:
                continue
            progress = True
            pulled[i] += 1
            if i != 0 and die_after[i] is not None \
                    and pulled[i] > die_after[i]:
                sched.requeue(pkt)          # device dies holding the packet
                sched.mark_dead(i)          # releases pre-assigned work
                alive.discard(i)
                continue
            if budget > 0 and not pkt.retried and rng.random() < 0.3:
                budget -= 1                  # transient failure: retry later
                sched.requeue(pkt)
                continue
            executed.append(pkt)
        if not progress:
            return executed


@given(total=st.integers(1, 4000), lws=st.integers(1, 32),
       powers=st.lists(st.floats(0.05, 10.0), min_size=2, max_size=6),
       name=st.sampled_from(ALL_SCHEDULERS),
       deaths=st.lists(st.integers(0, 6), min_size=6, max_size=6),
       requeue_budget=st.integers(0, 3),
       seed=st.integers(0, 2**16))
@settings(max_examples=120, deadline=None)
def test_property_fault_tolerant_coverage(total, lws, powers, name, deaths,
                                          requeue_budget, seed):
    """Every scheduler covers [0, G) exactly once — no gaps, no overlaps —
    under random mid-run requeues and device deaths (satellite invariant
    behind the API's fault-tolerance guarantee)."""
    devs = [DeviceProfile(f"d{i}", p) for i, p in enumerate(powers)]
    sched = make_scheduler(name, total, lws, devs)
    # die_after[i] >= 4 means immortal; device 0 always survives
    die_after = [None] + [d if d < 4 else None
                          for d in deaths[1:len(devs)]]
    executed = _drain_with_faults(sched, len(devs), die_after,
                                  requeue_budget, seed)
    assert coverage_ok(executed, total)
    # provenance: every committed packet has a unique seq
    seqs = [p.seq for p in executed]
    assert len(seqs) == len(set(seqs))
    assert sched.remaining() == 0


def test_tuned_profiles_paper_laws():
    devs = [DeviceProfile("cpu", 1.0), DeviceProfile("igpu", 3.0),
            DeviceProfile("gpu", 7.0)]
    out = tuned_profiles(devs)
    # (a)/(b): more power => larger m, smaller k; exact triple for n=3
    assert [d.min_mult for d in out] == [1, 15, 30]
    assert [d.k for d in out] == [3.5, 1.5, 1.0]


def test_hguided_opt_fleet_scale_adaptation():
    devs = [DeviceProfile(f"g{i}", 1.0) for i in range(64)]
    sched = HGuidedOptScheduler(64 * 64, 1, devs)
    assert all(d.k >= 2.0 for d in sched.devices)
    assert all(d.min_mult == 1 for d in sched.devices)


def test_thread_safety():
    devs = [DeviceProfile(f"d{i}", 1.0 + i) for i in range(4)]
    sched = HGuidedScheduler(20000, 4, devs)
    got = []
    lock = threading.Lock()

    def worker(i):
        while True:
            p = sched.next_packet(i)
            if p is None:
                return
            with lock:
                got.append(p)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert coverage_ok(got, 20000)
