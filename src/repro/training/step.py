"""Train / serve step factories.

``make_train_step`` builds the jit-able (state, batch) -> (state, metrics)
with next-token CE loss, MoE aux loss, gradient accumulation (scan over
microbatches — bounds activation memory on the 16 GB v5e), optional int8
error-feedback gradient compression, and AdamW.

``make_prefill_step`` / ``make_decode_step`` wrap the cached model paths for
serving.  All functions are pure; shardings are applied by the launcher via
``jax.jit(in_shardings=...)``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.adamw import OptConfig, TrainState
from repro.optim import compress as C

AUX_WEIGHT = 0.01


def make_loss_fn(cfg: ModelConfig, res=None):
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        patches = batch.get("patches")
        logits, aux = T.forward(cfg, params, tokens, patches=patches, res=res)
        logits = logits.astype(jnp.float32)
        if cfg.frontend == "encodec_stub":
            # (B,S,CB,V): predict each codebook of the next frame
            tgt = tokens[:, 1:]                      # (B,S-1,CB)
            lg = logits[:, :-1]                      # (B,S-1,CB,V)
            logz = jax.nn.logsumexp(lg, axis=-1)
            ll = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
            nll = logz - ll                          # (B,S-1,CB)
            mask = jnp.ones(nll.shape[:2], jnp.float32)
        else:
            tgt = tokens[:, 1:]
            lg = logits[:, :-1]
            logz = jax.nn.logsumexp(lg, axis=-1)
            ll = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
            nll = logz - ll                          # (B,S-1)
            mask = jnp.ones(nll.shape, jnp.float32)
            if cfg.frontend == "vit_stub":
                # image-patch positions don't contribute to the LM loss
                pos = jnp.arange(nll.shape[1])
                mask = mask * (pos >= cfg.n_patches)[None, :]
        if nll.ndim == 3:
            nll = nll.mean(-1)
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return loss + AUX_WEIGHT * aux, {"loss": loss, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: OptConfig, *, res=None,
                    accum_steps: int = 1, compress: bool = False):
    loss_fn = make_loss_fn(cfg, res)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if accum_steps == 1:
            (_, metrics), grads = grad_fn(state.params, batch)
        else:
            def micro(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(state.params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            def split(x):
                A = accum_steps
                return x.reshape((A, x.shape[0] // A) + x.shape[1:])

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32)}
            (grads, msum), _ = jax.lax.scan(micro, (g0, m0), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m / accum_steps, msum)
        if compress:
            grads, _ = C.compress_decompress(grads, None)
        new_state, opt_metrics = adamw.apply_updates(state, grads, opt)
        metrics = dict(metrics, **opt_metrics)
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, res=None):
    def prefill_step(params, batch, cache):
        return T.prefill(cfg, params, batch["tokens"], cache,
                         patches=batch.get("patches"), res=res)
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, res=None):
    def decode_step(params, token, cache, pos):
        return T.decode_step(cfg, params, token, cache, pos, res=res)
    return decode_step
