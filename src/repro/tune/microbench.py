"""Micro-benchmarks feeding the calibration: measure, don't guess.

Three raw quantities drive every constant the autotuner sets:

* **compute rate** per (kernel, device) — a small lws-aligned row-span
  sweep through the device's real compiled executable; the size sweep
  lets :mod:`repro.tune.calibrate` split fixed per-run overhead from the
  per-row slope;
* **lock-crossing / thread-wake cost** — contended condition-variable
  and event ping-pongs between two threads (what one scheduler hand-off
  or one async-commit wakeup costs on this host);
* **host copy cost** vs size — the transfer-crossover economics.

All timing goes through the shared interleaved-median protocol
(``benchmarks.common.interleaved_medians``): this host drifts ~25% over
a benchmark's lifetime, so candidate configurations are interleaved with
alternating visit order and scored by medians, never timed in blocks.

Everything here returns *raw medians*; fitting lives in calibrate.py.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.device import DeviceGroup
from repro.core.runtime import Program

DEFAULT_ROUNDS = 7
DEFAULT_COPY_SIZES = (4 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20)


def _interleaved_medians():
    """The shared drift-cancelling protocol (satellite of benchmarks/).

    Imported lazily: ``benchmarks`` lives at the repo root, next to
    ``src/`` — resolvable whenever the repo root is on ``sys.path`` (the
    benchmark/CI/pytest invocations), without making ``repro.core``
    depend on it.
    """
    try:
        from benchmarks.common import interleaved_medians
    except ImportError as e:                       # pragma: no cover
        raise ImportError(
            "repro.tune.microbench needs benchmarks/common.py (run with "
            "the repo root on sys.path, e.g. PYTHONPATH=src:.)") from e
    return interleaved_medians


@dataclass
class Measurements:
    """Raw interleaved-median samples, pre-fit.

    ``kernels[kernel][device][rows]`` is the median seconds for one
    ``rows``-row run on that device; ``copy_s[nbytes]`` the median
    seconds for one host copy of that size.  ``n_timed_runs`` counts
    every timed micro-run executed — the calibration-cache acceptance
    check asserts this stays ZERO on a warm second tune.
    """
    kernels: Dict[str, Dict[str, Dict[int, float]]] = field(
        default_factory=dict)
    crossing_s: float = 0.0
    wake_s: float = 0.0
    copy_s: Dict[int, float] = field(default_factory=dict)
    n_timed_runs: int = 0


# -- compute rate per (kernel, device) -------------------------------------

def _range_call(prog: Program, fn):
    """Adapt a compiled executable to ``call(offset, rows)`` over the
    program's full width (the microbench sweeps dim-0 panels only,
    matching the schedulers' row-panel carving)."""
    region = prog.work_region
    if region.ndim == 2:
        d0, d1 = region.dims

        def call(offset, rows):
            return fn(d0.offset + offset, rows, d1.offset, d1.size)
    else:
        d0 = region.dims[0]

        def call(offset, rows):
            return fn(d0.offset + offset, rows)
    return call


def span_grid(prog: Program, n_spans: int = 3) -> List[int]:
    """lws-aligned row spans [G/2^(n-1), ..., G/2, G] for the slope fit."""
    g, lws = prog.total_work, prog.lws
    spans = []
    for i in range(n_spans - 1, -1, -1):
        rows = max(lws, (g >> i) // lws * lws)
        if rows not in spans:
            spans.append(rows)
    return spans


def measure_compute(prog: Program, device: DeviceGroup, *,
                    spans: Optional[Sequence[int]] = None,
                    rounds: int = DEFAULT_ROUNDS):
    """``({rows: median_seconds}, n_timed_runs)`` for ``prog`` on
    ``device``.

    Runs the device's real compiled executable through
    ``DeviceGroup.run_packet`` so throttle (the emulated relative speed)
    is part of the measurement, exactly as the engine sees it.  The span
    labels themselves are the interleaved configurations — a drift burst
    biases every span equally instead of poisoning the slope.
    """
    interleaved = _interleaved_medians()
    call = _range_call(prog, prog.build(device))
    spans = list(spans) if spans is not None else span_grid(prog)
    call(0, spans[0])                       # warm-up: compile outside timing
    counter = {"runs": 0}

    def run(rows):
        device.run_packet(call, 0, rows)
        counter["runs"] += 1

    med = interleaved(spans, run, rounds)
    return dict(med), counter["runs"]


# -- host cost primitives --------------------------------------------------

def _pingpong_condition(crossings: int) -> None:
    """``crossings`` contended lock hand-offs between two threads."""
    cond = threading.Condition()
    state = {"turn": 0, "left": crossings}

    def peer():
        with cond:
            while state["left"] > 0:
                cond.wait_for(lambda: state["turn"] == 1
                              or state["left"] <= 0)
                if state["left"] <= 0:
                    break
                state["turn"] = 0
                state["left"] -= 1
                cond.notify_all()

    t = threading.Thread(target=peer, daemon=True)
    t.start()
    with cond:
        while state["left"] > 0:
            cond.wait_for(lambda: state["turn"] == 0 or state["left"] <= 0)
            if state["left"] <= 0:
                break
            state["turn"] = 1
            state["left"] -= 1
            cond.notify_all()
    t.join()


def _pingpong_events(crossings: int) -> None:
    """``crossings`` thread wakes via paired events (the committer
    hand-off shape: one Event.set -> one Event.wait wake)."""
    a, b = threading.Event(), threading.Event()
    n = crossings // 2

    def peer():
        for _ in range(n):
            a.wait()
            a.clear()
            b.set()

    t = threading.Thread(target=peer, daemon=True)
    t.start()
    for _ in range(n):
        a.set()
        b.wait()
        b.clear()
    t.join()


def measure_host_costs(*, rounds: int = DEFAULT_ROUNDS,
                       crossings: int = 400,
                       copy_sizes: Sequence[int] = DEFAULT_COPY_SIZES):
    """One interleaved pass over every host-side primitive.

    Labels are (kind, size) pairs: the lock-crossing ping-pong, the
    event-wake ping-pong, and one copy benchmark per size all rotate
    through the same rounds, so host drift hits them evenly — the
    crossover fit compares copy cost *against* wake cost, which only
    works if both saw the same machine.

    Returns ``(crossing_s, wake_s, copy_s: {nbytes: s}, n_timed_runs)``.
    """
    interleaved = _interleaved_medians()
    bufs = {nb: (np.empty(nb, np.uint8), np.empty(nb, np.uint8))
            for nb in copy_sizes}
    copies_per_run = 8
    labels = [("crossing", 0), ("wake", 0)] + \
             [("copy", nb) for nb in copy_sizes]
    counter = {"runs": 0}

    def run(label):
        kind, nb = label
        counter["runs"] += 1
        if kind == "crossing":
            _pingpong_condition(crossings)
        elif kind == "wake":
            _pingpong_events(crossings)
        else:
            dst, src = bufs[nb]
            for _ in range(copies_per_run):
                np.copyto(dst, src)

    med = interleaved(labels, run, rounds)
    crossing_s = med[("crossing", 0)] / crossings
    wake_s = med[("wake", 0)] / (crossings // 2 * 2)
    copy_s = {nb: med[("copy", nb)] / copies_per_run for nb in copy_sizes}
    return crossing_s, wake_s, copy_s, counter["runs"]


# -- the full measurement pass ---------------------------------------------

def measure(devices: Sequence[DeviceGroup],
            programs: Dict[str, Program], *,
            rounds: int = DEFAULT_ROUNDS,
            spans: Optional[Sequence[int]] = None,
            copy_sizes: Sequence[int] = DEFAULT_COPY_SIZES) -> Measurements:
    """Everything calibrate.py needs, for one fleet and a kernel set."""
    m = Measurements()
    m.crossing_s, m.wake_s, m.copy_s, n = measure_host_costs(
        rounds=rounds, copy_sizes=copy_sizes)
    m.n_timed_runs += n
    for kernel, prog in programs.items():
        per_dev = m.kernels.setdefault(kernel, {})
        for dev in devices:
            per_dev[dev.name], n = measure_compute(
                prog, dev, spans=spans, rounds=rounds)
            m.n_timed_runs += n
    return m
