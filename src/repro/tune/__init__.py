"""Calibrated autotuner: close the hand-tuning loop.

    measure (microbench) -> fit (calibrate) -> search (simulate)
        -> confirm (hardware) -> cache (per device fingerprint)

Every constant the runtime hand-picked for the reference container —
packet granularity, panel lws, the lease growth law, the 256 KiB
transfer crossover — is measured, fitted, swept, and persisted here.
``autotune()`` drives the whole loop; ``EngineSession(tuned=...)`` /
``coexec(tuned=...)`` apply the result.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence

from repro.core.device import DeviceGroup
from repro.core.runtime import Program
from repro.tune.cache import (Calibration, DeviceCalibration, TuneCache,
                              TunedConfig, device_fingerprint, resolve_tuned)
from repro.tune.calibrate import calibrate, crossover_bytes
from repro.tune.microbench import Measurements, measure
from repro.tune.search import SearchResult, confirm_on_hardware, search

__all__ = [
    "Calibration", "DeviceCalibration", "Measurements", "SearchResult",
    "TuneCache", "TunedConfig", "TuneReport", "autotune", "calibrate",
    "confirm_on_hardware", "crossover_bytes", "device_fingerprint",
    "measure", "resolve_tuned", "search",
]


@dataclass
class TuneReport:
    """What one ``autotune()`` call actually did — the cache-reuse
    acceptance check reads ``microbenches_run == 0`` on a warm run."""
    config: TunedConfig
    fingerprint: str
    cache_hit_winner: bool = False
    cache_hit_calibration: bool = False
    microbenches_run: int = 0
    confirmed: bool = False


def autotune(devices: Sequence[DeviceGroup],
             programs: Dict[str, Program],
             kernel: str, *,
             cache: Optional[object] = None,
             rounds: int = 7,
             scheduler: str = "dynamic",
             n_packets_grid: Optional[Sequence[int]] = None,
             lws_grid: Optional[Sequence[int]] = None,
             confirm_run: Optional[
                 Callable[[TunedConfig], object]] = None,
             confirm_top: int = 2,
             confirm_rounds: int = 5,
             measure_fn: Optional[Callable] = None) -> TuneReport:
    """The full loop for one kernel on one fleet, cache-first.

    * winner cached for this fleet fingerprint -> return it untouched
      (zero micro-benchmarks, identical TunedConfig);
    * calibration cached -> skip measuring, go straight to the search;
    * otherwise measure every program in ``programs`` once (the
      calibration is shared by later kernels on this fleet), fit, sweep.

    ``confirm_run(cfg)`` (optional) executes one hardware run under a
    candidate config; the top ``confirm_top`` simulated candidates plus
    the defaults then compete in an interleaved-median shoot-out and the
    *measured* winner is cached.  ``measure_fn`` substitutes the
    measurement pass (tests inject synthetic measurements).

    ``cache`` is a :class:`TuneCache`, a path, or None (default path).
    """
    if kernel not in programs:
        raise KeyError(f"kernel {kernel!r} not in programs "
                       f"({sorted(programs)})")
    if not isinstance(cache, TuneCache):
        cache = TuneCache(cache)
    fp = device_fingerprint(devices)

    cached = cache.get_winner(fp, kernel)
    if cached is not None:
        return TuneReport(config=cached, fingerprint=fp,
                          cache_hit_winner=True)

    cal = cache.get_calibration(fp)
    hit_cal = cal is not None and kernel in cal.kernels
    report = TuneReport(config=None, fingerprint=fp,  # type: ignore
                        cache_hit_calibration=hit_cal)
    if not hit_cal:
        m = (measure_fn or measure)(devices, programs, rounds=rounds)
        report.microbenches_run = m.n_timed_runs
        fresh = calibrate(m)
        if cal is not None:
            # keep other kernels' fits; host terms take the fresh values
            for k, v in cal.kernels.items():
                fresh.kernels.setdefault(k, v)
        cal = fresh
        cache.put_calibration(fp, cal)

    prog = programs[kernel]
    kw = {}
    if n_packets_grid is not None:
        kw["n_packets_grid"] = n_packets_grid
    res = search(cal, kernel, prog.total_work, prog.lws,
                 scheduler=scheduler, lws_grid=lws_grid,
                 fingerprint=fp, **kw)
    winner = res.winner

    if confirm_run is not None:
        # hardware has the last word: defaults + top simulated candidates
        ranked = sorted({id(c): c for c in (winner, res.default)}.values(),
                        key=lambda c: c.predicted_s or 0.0)
        pool = ranked[:max(1, confirm_top)]
        if res.default not in pool:
            pool.append(res.default)
        best, med = confirm_on_hardware(pool, confirm_run,
                                        rounds=confirm_rounds)
        winner = pool[best]
        winner.confirmed_s = med[best]
        report.confirmed = True

    report.config = winner
    cache.put_winner(fp, kernel, winner)
    return report
