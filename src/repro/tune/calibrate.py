"""Fit the simulator's cost terms from raw micro-benchmark medians.

The discrete-event simulator (core/simulate.py) prices a packet as
``launch_overhead + rows / throughput`` plus host hand-off and transfer
terms.  This module turns :class:`repro.tune.microbench.Measurements`
into exactly those terms:

* per (kernel, device): a least-squares line through the row-span sweep
  — slope is ``1/throughput``, intercept the per-packet fixed cost
  (``SimDevice.packet_cost``'s busy components);
* host: the measured lock-crossing cost becomes ``sched_overhead_s``,
  the event-wake cost ``host_cost_per_packet``;
* transfers: a line through the copy-size sweep gives byte-traffic
  terms, and its intersection with the wake cost is the *crossover* —
  the smallest commit worth handing to the async committer
  (``TransferPipeline.async_threshold_bytes``).

``bytes_per_wg_from_hlo`` bridges the static side: for kernels with an
HLO dump, ``launch/hlo_cost.py``'s loop-corrected traffic totals seed
``SimDevice.xfer_bytes_per_wg`` without running anything (the same
bones ``benchmarks/roofline.py`` reads).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from repro.core.simulate import SimConfig, SimDevice
from repro.tune.cache import Calibration, DeviceCalibration
from repro.tune.microbench import Measurements


def fit_line(samples: Dict[int, float]) -> Tuple[float, float]:
    """Least-squares ``(intercept, slope)`` through {x: seconds}.

    With a single point the intercept is 0 (pure rate); degenerate or
    noise-dominated fits are clamped to non-negative intercept and
    positive slope so downstream throughputs stay finite.
    """
    xs = sorted(samples)
    if not xs:
        raise ValueError("fit_line needs at least one sample")
    if len(xs) == 1:
        x = xs[0]
        return 0.0, max(samples[x], 1e-12) / max(x, 1)
    n = float(len(xs))
    mx = sum(xs) / n
    my = sum(samples[x] for x in xs) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    sxy = sum((x - mx) * (samples[x] - my) for x in xs)
    slope = sxy / sxx if sxx > 0 else 0.0
    if slope <= 0:
        # noise ate the slope: fall back to the biggest sample's rate
        x = xs[-1]
        return 0.0, max(samples[x], 1e-12) / max(x, 1)
    intercept = max(0.0, my - slope * mx)
    return intercept, slope


def fit_device(samples: Dict[int, float]) -> DeviceCalibration:
    """One (kernel, device) fit: seconds-per-row line -> rate + overhead."""
    intercept, slope = fit_line(samples)
    return DeviceCalibration(throughput=1.0 / slope, overhead_s=intercept)


def crossover_bytes(transfer_base_s: float, transfer_s_per_byte: float,
                    wake_cost_s: float, *,
                    default: int = 256 << 10) -> int:
    """Smallest commit size where an async hand-off beats an inline copy.

    The committer hand-off costs one thread wake; an inline copy costs
    ``base + nbytes/bw``.  Below the intersection the calling thread
    should just copy (``TransferPipeline`` runs it inline); above it the
    wake is amortized.  Degenerate fits keep the hand-picked default;
    a wake cheaper than even the fixed copy cost means "always async"
    (threshold 0).
    """
    if transfer_s_per_byte <= 0:
        return int(default)
    if wake_cost_s <= transfer_base_s:
        return 0
    x = (wake_cost_s - transfer_base_s) / transfer_s_per_byte
    return max(0, int(x))


def calibrate(m: Measurements) -> Calibration:
    """Fit every cost term from one measurement pass."""
    cal = Calibration(
        sched_overhead_s=max(m.crossing_s, 1e-7),
        wake_cost_s=max(m.wake_s, 1e-7),
    )
    if m.copy_s:
        base, per_byte = fit_line(m.copy_s)
        cal.transfer_base_s = base
        cal.transfer_s_per_byte = per_byte
    for kernel, per_dev in m.kernels.items():
        cal.kernels[kernel] = {name: fit_device(samples)
                               for name, samples in per_dev.items()}
    return cal


# -- simulator construction ------------------------------------------------

def sim_devices(cal: Calibration, kernel: str) -> Sequence[SimDevice]:
    """Calibrated :class:`SimDevice` fleet for one kernel's search."""
    if kernel not in cal.kernels:
        raise KeyError(f"no calibration for kernel {kernel!r} "
                       f"(have {sorted(cal.kernels)})")
    return [SimDevice(name, dc.throughput, launch_overhead=dc.overhead_s)
            for name, dc in sorted(cal.kernels[kernel].items())]


def sim_config(cal: Calibration, *, scheduler: str = "dynamic",
               scheduler_kwargs: Optional[Dict] = None,
               dispatch: str = "leased",
               lease_overhead_frac: Optional[float] = None,
               lease_k_max: Optional[int] = None,
               seed: int = 0) -> SimConfig:
    """A :class:`SimConfig` whose host terms come from the calibration:
    hand-offs cost the *measured* crossing, per-packet host management
    the *measured* wake."""
    return SimConfig(
        scheduler=scheduler,
        scheduler_kwargs=dict(scheduler_kwargs or {}),
        opt_init=True, opt_buffers=True, buffer_policy="pooled",
        dispatch=dispatch,
        sched_overhead_s=cal.sched_overhead_s,
        host_cost_per_packet=cal.wake_cost_s,
        lease_overhead_frac=lease_overhead_frac,
        lease_k_max=lease_k_max,
        seed=seed)


def bytes_per_wg_from_hlo(hlo_text: str, total_work: int) -> float:
    """Per-work-group byte traffic from a compiled module's HLO dump
    (loop-corrected totals via ``repro.launch.hlo_cost``) — seeds
    ``SimDevice.xfer_bytes_per_wg`` for transfer-aware searches."""
    from repro.launch.hlo_cost import analyze
    if total_work <= 0:
        raise ValueError(f"total_work must be > 0, got {total_work}")
    return analyze(hlo_text)["traffic_bytes"] / float(total_work)
