"""Persistent autotuning cache: calibrations + winners per device fleet.

Modeled on XLA's autotuning cache: results are keyed by a *device
fingerprint* (what the fleet looks like), stored as versioned JSON, and
every read path is defensive — a corrupt, torn, or stale file silently
degrades to "no cache" and the next store rewrites it atomically.

Layout on disk::

    {"version": 1,
     "entries": {
        "<fingerprint>": {
            "calibration": {...},              # fitted cost terms
            "winners": {"<kernel>": {...}}     # TunedConfig per kernel
        }}}

Nothing in here runs a micro-benchmark; see :mod:`repro.tune.microbench`
(measure), :mod:`repro.tune.calibrate` (fit) and :mod:`repro.tune.search`
(sweep + confirm) for how entries are produced.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, Optional

CACHE_VERSION = 1

DEFAULT_CACHE_PATH = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "tune_cache.json")


# -- fingerprint -----------------------------------------------------------

def device_fingerprint(devices) -> str:
    """Stable identity of a device fleet for cache keying.

    Covers what the calibration actually depends on: each device's name,
    throttle, and power model, plus the host's core count (lock-crossing
    and wake costs are an oversubscription story).  Order-insensitive —
    the same fleet listed in a different order is the same fingerprint.
    """
    parts = []
    for d in devices:
        parts.append([
            str(getattr(d, "name", d)),
            float(getattr(d, "throttle", 1.0)),
            repr(getattr(d, "power_model", None)),
        ])
    blob = json.dumps({"devices": sorted(parts),
                       "cpus": os.cpu_count(),
                       "version": CACHE_VERSION}, sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


# -- calibration (the fitted cost terms) -----------------------------------

@dataclass
class DeviceCalibration:
    """One device's fitted terms for one kernel: ``t(rows) =
    overhead_s + rows / throughput`` (slope/intercept of the
    interleaved-median size sweep)."""
    throughput: float                    # work-groups (rows) / second
    overhead_s: float = 0.0              # per-run fixed cost (launch+sync)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "DeviceCalibration":
        return cls(throughput=float(d["throughput"]),
                   overhead_s=float(d.get("overhead_s", 0.0)))


@dataclass
class Calibration:
    """Fitted simulator cost terms for one device fleet.

    ``kernels[kernel][device_name]`` holds the per-(kernel, device)
    compute fit; the host-side terms (lock crossing, thread wake, copy
    bandwidth) are kernel-independent.
    """
    kernels: Dict[str, Dict[str, DeviceCalibration]] = field(
        default_factory=dict)
    sched_overhead_s: float = 2e-4       # one contended lock crossing
    wake_cost_s: float = 2e-4            # one thread hand-off wake
    transfer_base_s: float = 0.0         # fixed cost of one host copy
    transfer_s_per_byte: float = 0.0     # copy slope (1 / bandwidth)

    def to_dict(self) -> Dict:
        return {
            "kernels": {k: {d: c.to_dict() for d, c in devs.items()}
                        for k, devs in self.kernels.items()},
            "sched_overhead_s": self.sched_overhead_s,
            "wake_cost_s": self.wake_cost_s,
            "transfer_base_s": self.transfer_base_s,
            "transfer_s_per_byte": self.transfer_s_per_byte,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "Calibration":
        return cls(
            kernels={k: {dn: DeviceCalibration.from_dict(c)
                         for dn, c in devs.items()}
                     for k, devs in d.get("kernels", {}).items()},
            sched_overhead_s=float(d["sched_overhead_s"]),
            wake_cost_s=float(d.get("wake_cost_s", 2e-4)),
            transfer_base_s=float(d.get("transfer_base_s", 0.0)),
            transfer_s_per_byte=float(d.get("transfer_s_per_byte", 0.0)),
        )


# -- the tuned result ------------------------------------------------------

@dataclass
class TunedConfig:
    """The autotuner's output: every constant the session can apply.

    ``None`` fields mean "keep the hand-picked default" — a TunedConfig
    is a sparse overlay, so partial tunes compose with explicit session
    kwargs (which always win; see ``EngineSession(tuned=...)``).
    """
    kernel: Optional[str] = None             # provenance
    fingerprint: Optional[str] = None        # fleet it was tuned for
    scheduler: Optional[str] = None
    scheduler_kwargs: Optional[Dict] = None  # e.g. {"n_packets": 16}
    lws: Optional[int] = None                # dim-0 panel alignment
    lease_overhead_s: Optional[float] = None
    lease_overhead_frac: Optional[float] = None
    lease_k_max: Optional[int] = None
    async_threshold_bytes: Optional[int] = None
    predicted_s: Optional[float] = None      # simulator's winning time
    predicted_default_s: Optional[float] = None  # simulator's default time
    confirmed_s: Optional[float] = None      # hardware-confirmed median

    def lease_params(self) -> Dict:
        """Non-None lease constants, in ``set_lease_params`` form."""
        return {k: v for k, v in (
            ("lease_overhead_s", self.lease_overhead_s),
            ("lease_overhead_frac", self.lease_overhead_frac),
            ("lease_k_max", self.lease_k_max)) if v is not None}

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "TunedConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


# -- the cache file --------------------------------------------------------

class TuneCache:
    """Versioned on-disk store of calibrations and per-kernel winners.

    Every ``put_*`` persists immediately via an atomic temp-file +
    ``os.replace`` write, so a concurrent reader sees either the old or
    the new file, never a torn one.  Loads tolerate missing, corrupt,
    and wrong-version files by starting empty.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = str(path) if path is not None else DEFAULT_CACHE_PATH
        self._data = self._load()

    # -- read paths (all defensive) ----------------------------------------
    def _empty(self) -> Dict:
        return {"version": CACHE_VERSION, "entries": {}}

    def _load(self) -> Dict:
        try:
            with open(self.path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            return self._empty()
        if not isinstance(raw, dict) or raw.get("version") != CACHE_VERSION \
                or not isinstance(raw.get("entries"), dict):
            return self._empty()     # stale schema: recalibrate
        return raw

    def get_calibration(self, fingerprint: str) -> Optional[Calibration]:
        ent = self._data["entries"].get(fingerprint, {})
        try:
            return Calibration.from_dict(ent["calibration"])
        except (KeyError, TypeError, ValueError, AttributeError):
            return None

    def get_winner(self, fingerprint: str,
                   kernel: str) -> Optional[TunedConfig]:
        ent = self._data["entries"].get(fingerprint, {})
        try:
            return TunedConfig.from_dict(ent["winners"][kernel])
        except (KeyError, TypeError, ValueError, AttributeError):
            return None

    def winners(self, fingerprint: str) -> Dict[str, TunedConfig]:
        ent = self._data["entries"].get(fingerprint, {})
        out = {}
        for kernel, d in (ent.get("winners") or {}).items():
            try:
                out[kernel] = TunedConfig.from_dict(d)
            except (TypeError, ValueError, AttributeError):
                continue
        return out

    # -- write paths -------------------------------------------------------
    def put_calibration(self, fingerprint: str, cal: Calibration) -> None:
        ent = self._data["entries"].setdefault(fingerprint, {})
        ent["calibration"] = cal.to_dict()
        self.save()

    def put_winner(self, fingerprint: str, kernel: str,
                   cfg: TunedConfig) -> None:
        ent = self._data["entries"].setdefault(fingerprint, {})
        ent.setdefault("winners", {})[kernel] = cfg.to_dict()
        self.save()

    def save(self) -> None:
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tune_cache.")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._data, f, indent=2, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


# -- session entry point ---------------------------------------------------

def resolve_tuned(tuned, *, devices=None,
                  kernel: Optional[str] = None) -> Optional[TunedConfig]:
    """Turn the session's ``tuned=`` argument into a TunedConfig.

    Accepts a :class:`TunedConfig` (returned as-is), a plain dict, a path
    to a TunedConfig JSON file, a :class:`TuneCache`, or ``True`` (open
    the default cache).  Cache forms look up the fleet's fingerprint:
    the winner for ``kernel`` when given, else the sole stored winner,
    else ``None`` — a miss quietly keeps the hand-picked defaults, so
    ``tuned=True`` is always safe to pass.
    """
    if tuned is None or tuned is False:
        return None
    if isinstance(tuned, TunedConfig):
        return tuned
    if isinstance(tuned, dict):
        return TunedConfig.from_dict(tuned)
    cache: Optional[TuneCache] = None
    if isinstance(tuned, TuneCache):
        cache = tuned
    elif tuned is True:
        cache = TuneCache()
    elif isinstance(tuned, (str, os.PathLike)):
        path = os.fspath(tuned)
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            return None
        if isinstance(raw, dict) and "entries" in raw:
            cache = TuneCache(path)       # a whole cache file
        elif isinstance(raw, dict):
            return TunedConfig.from_dict(raw)
        else:
            return None
    else:
        raise TypeError(f"tuned= accepts TunedConfig, dict, path, "
                        f"TuneCache, or True — got {type(tuned).__name__}")
    if devices is None:
        return None
    fp = device_fingerprint(devices)
    if kernel is not None:
        return cache.get_winner(fp, kernel)
    winners = cache.winners(fp)
    if len(winners) == 1:
        return next(iter(winners.values()))
    return winners.get("default")
