"""Sweep the knobs in the calibrated simulator; confirm winners on metal.

The search space is exactly the set of hand-frozen constants the prior
PRs shipped: packet granularity (``n_packets``), the dim-0 panel ``lws``,
the lease growth law (``lease_overhead_frac`` / ``lease_k_max``), and the
transfer crossover.  A full sweep on hardware would cost minutes per
kernel; in the calibrated discrete-event simulator it costs milliseconds,
so the grid runs there, and only the top candidates (plus the defaults —
the winner must never regress them) graduate to an interleaved-median
shoot-out on the real engine.

The transfer crossover never needs simulating: it falls analytically out
of the calibration (``calibrate.crossover_bytes``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler import SchedulerBase
from repro.core.simulate import simulate
from repro.tune.cache import Calibration, TunedConfig
from repro.tune.calibrate import crossover_bytes, sim_config, sim_devices

# default grids: small enough to sweep in milliseconds, wide enough to
# bracket every hand-picked constant (which are all included — the
# search can therefore never do worse than the defaults it replaces)
N_PACKETS_GRID = (4, 8, 16, 32, 64, 128, 256)
LEASE_FRAC_GRID = (0.01, SchedulerBase.lease_overhead_frac, 0.05, 0.1)
LEASE_K_MAX_GRID = (8, 16, SchedulerBase.lease_k_max, 256)
DEFAULT_N_PACKETS = 128          # DynamicScheduler's hand-picked default
PREDICT_SEEDS = 3


@dataclass
class SearchResult:
    winner: TunedConfig
    default: TunedConfig                      # the hand-picked baseline
    predictions: List[Tuple[Dict, float]] = field(default_factory=list)

    @property
    def predicted_gain_pct(self) -> float:
        if not self.default.predicted_s:
            return 0.0
        return 100.0 * (1.0 - self.winner.predicted_s
                        / self.default.predicted_s)


def predict(cal: Calibration, kernel: str, total_work: int, lws: int, *,
            scheduler: str = "dynamic",
            n_packets: Optional[int] = None,
            lease_overhead_frac: Optional[float] = None,
            lease_k_max: Optional[int] = None,
            seeds: int = PREDICT_SEEDS) -> float:
    """Mean simulated co-execution time for one candidate, over a fixed
    seed set (identical for every candidate: comparisons are exact)."""
    devs = sim_devices(cal, kernel)
    skw = {"n_packets": n_packets} if n_packets is not None else {}
    total = 0.0
    for seed in range(seeds):
        cfg = sim_config(cal, scheduler=scheduler, scheduler_kwargs=skw,
                         lease_overhead_frac=lease_overhead_frac,
                         lease_k_max=lease_k_max, seed=seed)
        total += simulate(total_work, lws, devs, cfg).total_time
    return total / seeds


def search(cal: Calibration, kernel: str, total_work: int, lws: int, *,
           scheduler: str = "dynamic",
           n_packets_grid: Sequence[int] = N_PACKETS_GRID,
           lws_grid: Optional[Sequence[int]] = None,
           lease_frac_grid: Sequence[float] = LEASE_FRAC_GRID,
           lease_k_max_grid: Sequence[int] = LEASE_K_MAX_GRID,
           seeds: int = PREDICT_SEEDS,
           fingerprint: Optional[str] = None) -> SearchResult:
    """Two-stage grid sweep in the calibrated simulator.

    Stage 1 sweeps granularity (``n_packets`` x ``lws``) under default
    lease constants; stage 2 sweeps the lease growth law at the stage-1
    optimum.  The default configuration is always part of stage 1, and
    the final winner is re-compared against it on the same seeds — the
    result's ``winner.predicted_s <= default.predicted_s`` invariant is
    structural, not statistical.
    """
    lws_grid = list(lws_grid) if lws_grid else [lws]
    np_grid = list(dict.fromkeys(list(n_packets_grid)
                                 + [DEFAULT_N_PACKETS]))
    predictions: List[Tuple[Dict, float]] = []

    # stage 1: granularity
    best = None
    for w in lws_grid:
        for n in np_grid:
            t = predict(cal, kernel, total_work, w, scheduler=scheduler,
                        n_packets=n, seeds=seeds)
            predictions.append(({"n_packets": n, "lws": w}, t))
            if best is None or t < best[2]:
                best = (n, w, t)
    best_n, best_w, best_t = best

    # stage 2: lease growth law at the stage-1 optimum
    best_lease: Tuple[Optional[float], Optional[int]] = (None, None)
    for frac in lease_frac_grid:
        for k_max in lease_k_max_grid:
            t = predict(cal, kernel, total_work, best_w,
                        scheduler=scheduler, n_packets=best_n,
                        lease_overhead_frac=frac, lease_k_max=k_max,
                        seeds=seeds)
            predictions.append(({"n_packets": best_n, "lws": best_w,
                                 "lease_overhead_frac": frac,
                                 "lease_k_max": k_max}, t))
            if t < best_t:
                best_t, best_lease = t, (frac, k_max)

    default_t = predict(cal, kernel, total_work, lws, scheduler=scheduler,
                        n_packets=DEFAULT_N_PACKETS, seeds=seeds)
    threshold = crossover_bytes(cal.transfer_base_s,
                                cal.transfer_s_per_byte, cal.wake_cost_s)
    default = TunedConfig(
        kernel=kernel, fingerprint=fingerprint, scheduler=scheduler,
        scheduler_kwargs={"n_packets": DEFAULT_N_PACKETS}, lws=lws,
        predicted_s=default_t, predicted_default_s=default_t)
    if best_t >= default_t:
        # structural guarantee: the defaults are in the space, so a sweep
        # that can't beat them returns them (never-worse by construction)
        winner = default
    else:
        winner = TunedConfig(
            kernel=kernel, fingerprint=fingerprint, scheduler=scheduler,
            scheduler_kwargs={"n_packets": best_n}, lws=best_w,
            lease_overhead_s=cal.sched_overhead_s,
            lease_overhead_frac=best_lease[0],
            lease_k_max=best_lease[1],
            async_threshold_bytes=threshold,
            predicted_s=best_t, predicted_default_s=default_t)
    return SearchResult(winner=winner, default=default,
                        predictions=predictions)


def confirm_on_hardware(configs: Sequence[TunedConfig],
                        run_fn: Callable[[TunedConfig], object], *,
                        rounds: int = 5) -> Tuple[int, Dict[int, float]]:
    """Interleaved-median shoot-out between candidate configs on the
    real engine.  ``run_fn(cfg)`` executes ONE run under ``cfg``; the
    shared protocol handles rotation and medians.  Returns the winning
    index and the per-candidate medians."""
    from repro.tune.microbench import _interleaved_medians
    interleaved = _interleaved_medians()
    idx = list(range(len(configs)))
    med = interleaved(idx, lambda i: run_fn(configs[i]), rounds)
    best = min(idx, key=lambda i: med[i])
    return best, med
