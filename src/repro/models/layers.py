"""Model layers: RMSNorm, RoPE, GQA/MLA attention (train + chunked-causal
prefill + cached decode), SwiGLU MLP, sort-based MoE with capacity, Mamba1.

All layers are pure functions over (params, inputs).  Parameter builders
return ``(params, logical_axes)`` pairs with identical tree structure; the
logical axes feed ``repro.parallel.ShardingResolver``.

Attention is implemented with an exact *blocked causal* schedule (python loop
over query blocks, ``lax.scan`` over that block's kv prefix with online
softmax) so the 32k prefill compiles to O(n_blocks) compact loops, keeps the
working set bounded, and does not pay the 2x masked-FLOP tax of the naive
"mask everything" formulation.  A Pallas flash-attention kernel
(`repro.kernels.flash_attention`) is the TPU drop-in for the inner loop.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain

Params = Dict[str, Any]
Axes = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rmsnorm(x, w, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, dim, theta, dtype=jnp.float32):
    """positions: (...,) int -> cos/sin (..., dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (S?, D/2) broadcastable over leading
    dims."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    # cos/sin: (S, d2) -> (S, 1, d2) to broadcast over heads
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# blocked-causal attention core (online softmax over kv chunks)
# ---------------------------------------------------------------------------

def _flash_inner(q, k, v, *, diag_mask: bool, chunk: int,
                 score_dtype=jnp.float32):
    """q: (B, T, KH, G, D); k,v: (B, L, KH, D) with L % chunk == 0.
    Returns (B, T, KH, G, D). Online-softmax scan over kv chunks; only the
    final chunk gets the triangular mask (when diag_mask).  The materialized
    score/prob buffers use `score_dtype` (bf16 halves the dominant HBM
    traffic of long-context cells); running max/denominator/accumulator
    stay f32."""
    B, T, KH, G, D = q.shape
    L = k.shape[1]
    n = L // chunk
    scale = 1.0 / math.sqrt(D)
    kc = k.reshape(B, n, chunk, KH, D)
    vc = v.reshape(B, n, chunk, KH, D)
    qf = q.astype(score_dtype)
    neg = jnp.asarray(-60000.0 if score_dtype == jnp.bfloat16 else -jnp.inf,
                      score_dtype)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, is_last = xs
        s = jnp.einsum("btkgd,bckd->btkgc", qf, kj.astype(score_dtype),
                       preferred_element_type=score_dtype) * scale
        if diag_mask:
            # triangular mask applies only on the diagonal (last) chunk, where
            # q block and kv block are the same block: relative triangle.
            tri = (jnp.arange(chunk)[None, :] <= jnp.arange(T)[:, None])
            tri = tri[None, :, None, None, :]
            s = jnp.where(jnp.logical_or(~is_last, tri), s, neg)
        m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
        p = jnp.exp(s.astype(jnp.float32)
                    - m_new[..., None]).astype(score_dtype)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1).astype(jnp.float32)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "btkgc,bckd->btkgd", p, vj.astype(score_dtype),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, T, KH, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, T, KH, G), jnp.float32)
    a0 = jnp.zeros((B, T, KH, G, D), jnp.float32)
    is_last = jnp.arange(n) == (n - 1)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), is_last))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def blocked_causal_attention(q, k, v, chunk: int, score_dtype=jnp.float32):
    """Exact causal attention. q: (B,S,H,D); k,v: (B,S,KH,D)."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, D)
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fallback: single block
    nq = S // chunk
    outs = []
    for j in range(nq):  # static python loop -> O(nq) compact scans
        qj = qg[:, j * chunk:(j + 1) * chunk]
        kv_len = (j + 1) * chunk
        outs.append(_flash_inner(qj, k[:, :kv_len], v[:, :kv_len],
                                 diag_mask=True, chunk=chunk,
                                 score_dtype=score_dtype))
    out = jnp.concatenate(outs, axis=1) if nq > 1 else outs[0]
    return out.reshape(B, S, H, D)


def cached_decode_attention(q, k_cache, v_cache, pos):
    """Single-token attention over a static-size cache.
    q: (B,1,H,D); caches: (B,Smax,KH,D); pos: () current position.

    The caches are consumed in their storage dtype with f32 dot
    accumulation (`preferred_element_type`) — materializing an f32 copy of
    the cache was 82% of the decode-step HBM traffic (§Perf qwen3
    decode_32k iteration)."""
    B, _, H, D = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, D).astype(k_cache.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    s *= 1.0 / math.sqrt(D)
    valid = (jnp.arange(k_cache.shape[1]) <= pos)[None, None, None, :]
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def gqa_init(cfg: ModelConfig, key, dtype) -> Tuple[Params, Axes]:
    d, H, KH, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H, hd), dtype),
        "wk": _dense_init(ks[1], (d, KH, hd), dtype),
        "wv": _dense_init(ks[2], (d, KH, hd), dtype),
        "wo": _dense_init(ks[3], (H, hd, d), dtype,
                          scale=1.0 / math.sqrt(H * hd)),
    }
    a = {
        "wq": ("d_model", "heads", None),
        "wk": ("d_model", "kv_heads", None),
        "wv": ("d_model", "kv_heads", None),
        "wo": ("heads", None, "d_model"),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
        a["q_norm"] = (None,)
        a["k_norm"] = (None,)
    return p, a


def gqa_apply(cfg: ModelConfig, p: Params, x, positions, *, res=None,
              cache: Optional[Dict] = None, pos=None):
    """x: (B,S,d). Train/prefill when cache is None or being filled; decode
    when x has S==1 and ``cache``/``pos`` are given with a full cache."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, res, ("batch", "seq", "heads", None))
    new_cache = None
    if cache is not None and pos is not None:
        # decode: insert the new k/v at `pos`, attend over the cache
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        out = cached_decode_attention(q, kc, vc, pos)
        new_cache = {"k": kc, "v": vc}
    else:
        out = blocked_causal_attention(q, k, v, cfg.attn_chunk,
                                       jnp.dtype(cfg.score_dtype))
        if cache is not None:  # prefill: write the whole prefix
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": kc, "v": vc}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def gqa_cache_init(cfg: ModelConfig, batch, max_seq, dtype):
    KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    z = jnp.zeros((batch, max_seq, KH, hd), dtype)
    axes = ("batch", "kv_seq", "kv_heads", None)
    return {"k": z, "v": z}, {"k": axes, "v": axes}


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2): compressed kv cache, absorbed decode path
# ---------------------------------------------------------------------------

def mla_init(cfg: ModelConfig, key, dtype) -> Tuple[Params, Axes]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_dim = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H, qk_dim), dtype),
        "wkv_a": _dense_init(ks[1], (d, m.kv_lora_rank + m.rope_head_dim),
                             dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": _dense_init(ks[2], (m.kv_lora_rank, H,
                                     m.nope_head_dim + m.v_head_dim), dtype),
        "wo": _dense_init(ks[3], (H, m.v_head_dim, d),
                          dtype, scale=1.0 / math.sqrt(H * m.v_head_dim)),
    }
    a = {
        "wq": ("d_model", "heads", None),
        "wkv_a": ("d_model", "kv_lora"),
        "kv_norm": (None,),
        "wkv_b": ("kv_lora", "heads", None),
        "wo": ("heads", None, "d_model"),
    }
    return p, a


def mla_apply(cfg: ModelConfig, p: Params, x, positions, *, res=None,
              cache: Optional[Dict] = None, pos=None):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = m.nope_head_dim, m.rope_head_dim, m.v_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv, k_rope_flat = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    ckv = rmsnorm(ckv, p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    # (B, S, rope_d)
    k_rope = apply_rope(k_rope_flat[..., None, :], cos, sin)[..., 0, :]

    if cache is not None and pos is not None and S == 1:
        # --- absorbed decode: never expand the per-token K/V ---
        ckv_c = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope.astype(cache["krope"].dtype), (0, pos, 0))
        wkb_k = p["wkv_b"][..., :nope]            # (R, H, nope)
        wkb_v = p["wkv_b"][..., nope:]            # (R, H, vd)
        # q_nope absorbed into latent space: (B,1,H,R); the compressed cache
        # is consumed in its storage dtype with f32 accumulation (see
        # cached_decode_attention)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wkb_k,
                           preferred_element_type=jnp.float32)
        s = jnp.einsum("bshr,btr->bhst", q_lat.astype(ckv_c.dtype), ckv_c,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bshk,btk->bhst", q_rope.astype(kr_c.dtype), kr_c,
                        preferred_element_type=jnp.float32)
        s *= 1.0 / math.sqrt(nope + rope_d)
        valid = (jnp.arange(ckv_c.shape[1]) <= pos)[None, None, None, :]
        s = jnp.where(valid, s, -jnp.inf)
        w = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", w.astype(ckv_c.dtype), ckv_c,
                           preferred_element_type=jnp.float32)
        out = jnp.einsum("bshr,rhv->bshv", o_lat.astype(wkb_v.dtype), wkb_v,
                         preferred_element_type=jnp.float32)
        out = out.astype(x.dtype)
        new_cache = {"ckv": ckv_c, "krope": kr_c}
    else:
        # --- expanded path (train / prefill) ---
        kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"])
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope,
             jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d))],
            axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        qq = constrain(qq, res, ("batch", "seq", "heads", None))
        # pad v up to qk head dim for the shared attention core, then slice
        pad = (nope + rope_d) - vd
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad > 0 else v
        out = blocked_causal_attention(qq, k, v_p, cfg.attn_chunk,
                                       jnp.dtype(cfg.score_dtype))[..., :vd]
        new_cache = None
        if cache is not None:
            ckv_c = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0))
            kr_c = jax.lax.dynamic_update_slice(
                cache["krope"], k_rope.astype(cache["krope"].dtype), (0, 0, 0))
            new_cache = {"ckv": ckv_c, "krope": kr_c}
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, new_cache


def mla_cache_init(cfg: ModelConfig, batch, max_seq, dtype):
    m = cfg.mla
    c = {"ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
         "krope": jnp.zeros((batch, max_seq, m.rope_head_dim), dtype)}
    a = {"ckv": ("batch", "kv_seq", "kv_lora"),
         "krope": ("batch", "kv_seq", None)}
    return c, a


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, dtype, d_ff=None) -> Tuple[Params, Axes]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_gate": _dense_init(ks[0], (d, f), dtype),
         "w_up": _dense_init(ks[1], (d, f), dtype),
         "w_down": _dense_init(ks[2], (f, d), dtype)}
    a = {"w_gate": ("d_model", "d_ff"),
         "w_up": ("d_model", "d_ff"),
         "w_down": ("d_ff", "d_model")}
    return p, a


def mlp_apply(cfg: ModelConfig, p: Params, x, res=None):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = constrain(h, res, ("batch", "seq", "d_ff"))
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE: top-k router + sort-based capacity dispatch (production formulation:
# the sort/gather lowers to the EP all-to-all under GSPMD)
# ---------------------------------------------------------------------------

def moe_init(cfg: ModelConfig, key, dtype) -> Tuple[Params, Axes]:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.moe.n_routed
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": _dense_init(ks[1], (E, d, f), dtype),
        "w_up": _dense_init(ks[2], (E, d, f), dtype),
        "w_down": _dense_init(ks[3], (E, f, d), dtype),
    }
    a = {
        # router stays REPLICATED on the model axis: it is ~d*E params, but
        # sharding its E contraction costs a (B,S,d) partial-sum all-reduce
        # in every backward pass (§Perf dbrx iteration 3: ~300 GiB/device
        # per step on dbrx-132b)
        "router": ("d_model", None),
        "w_gate": ("experts", "d_model", "d_ff"),
        "w_up": ("experts", "d_model", "d_ff"),
        "w_down": ("experts", "d_ff", "d_model"),
    }
    if cfg.moe.n_shared:
        sp, sa = mlp_init(cfg, ks[4], dtype, d_ff=cfg.moe.n_shared * f)
        p["shared"] = sp
        a["shared"] = sa
    return p, a


def _moe_global_dispatch(cfg, p, x, res):
    """Naive whole-batch scatter dispatch.  GSPMD cannot partition the
    token->expert scatter/gather (it falls back to full rematerialization:
    ~12-24 GiB replicating collectives per layer on dbrx-132b); kept as the
    §Perf ablation baseline."""
    B, S, d = x.shape
    E, k = cfg.moe.n_routed, cfg.moe.top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = int(max(1, math.ceil(cfg.moe.capacity_factor * k * T / E)))
    flat_idx = gate_idx.reshape(-1)                          # (T*k,)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)    # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)         # exclusive
    slot_pos = jnp.take_along_axis(pos_in_e, flat_idx[:, None], axis=1)[:, 0]
    keep = slot_pos < C
    dest = jnp.where(keep, flat_idx * C + slot_pos, E * C)   # dropped -> pad

    buf = jnp.zeros((E * C + 1, d), x.dtype)
    tok_rep = jnp.repeat(xt, k, axis=0)                      # (T*k, d)
    buf = buf.at[dest].set(tok_rep, mode="drop")
    eb = buf[:E * C].reshape(E, C, d)
    eb = constrain(eb, res, ("experts", "capacity", None))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", eb, p["w_up"])
    h = constrain(h, res, ("experts", "capacity", "d_ff"))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    out_flat = out_e.reshape(E * C, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.minimum(dest, E * C - 1)], 0.0)
    combined = (gathered.reshape(T, k, d)
                * gate_vals[..., None].astype(x.dtype)).sum(axis=1)
    return combined.reshape(B, S, d), probs, gate_idx


def _moe_grouped_dispatch(cfg, p, x, res):
    """Group-local dispatch (GShard-style, batch rows as groups): the
    position cumsum, scatter and combine gather all stay LOCAL to each batch
    row (batched scatter/gather => shard-local under the batch sharding).

    Layout insight (see EXPERIMENTS.md §Perf, dbrx iteration 2): activations
    are replicated over the `model` axis, so the locally-scattered expert
    buffer (B, E, Cg, d) is too — slicing E per model shard is
    communication-FREE.  Expert matmuls then run sharded (batch->data,
    experts->model); the only cross-device movement in the whole MoE layer
    is the combine's all-gather of (B, E*Cg, d) over the model axis —
    ~14x less wire than even the all-to-all relayout formulation, ~200x
    less than the naive global scatter."""
    B, S, d = x.shape
    E, k = cfg.moe.n_routed, cfg.moe.top_k
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    Cg = int(max(1, math.ceil(cfg.moe.capacity_factor * k * S / E)))
    flat_idx = gate_idx.reshape(B, S * k)                    # (B, S*k)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)    # (B, S*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot           # exclusive, LOCAL
    slot_pos = jnp.take_along_axis(pos_in_e, flat_idx[..., None],
                                   axis=2)[..., 0]           # (B, S*k)
    keep = slot_pos < Cg
    dest = jnp.where(keep, flat_idx * Cg + slot_pos, E * Cg)

    tok_rep = jnp.repeat(x, k, axis=1)                       # (B, S*k, d)
    buf = jnp.zeros((B, E * Cg + 1, d), x.dtype)
    buf = jax.vmap(lambda bb, dd, uu: bb.at[dd].set(uu, mode="drop"))(
        buf, dest, tok_rep)
    # expert-shard the buffer over `model`: local slice, no communication
    eb = buf[:, :E * Cg].reshape(B, E, Cg, d)
    eb = constrain(eb, res, ("batch", "experts", None, None))

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", eb, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", eb, p["w_up"])
    h = constrain(h, res, ("batch", "experts", None, "d_ff"))
    out_e = jnp.einsum("becf,efd->becd", h, p["w_down"])     # (B,E,Cg,d)
    # combine: each row needs all its experts' outputs -> one all-gather
    # of out_e over the model axis, then a local batched gather
    out_b = out_e.reshape(B, E * Cg, d)
    out_b = constrain(out_b, res, ("batch", None, None))
    safe = jnp.minimum(dest, E * Cg - 1)
    gathered = jax.vmap(lambda ob, dd: ob[dd])(out_b, safe)  # (B, S*k, d)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    combined = (gathered.reshape(B, S, k, d)
                * gate_vals[..., None].astype(x.dtype)).sum(axis=2)
    return combined, probs.reshape(B * S, E), gate_idx.reshape(B * S, k)


def moe_apply(cfg: ModelConfig, p: Params, x, res=None, rng=None):
    """x: (B,S,d) -> (B,S,d); token-dropping capacity MoE."""
    if cfg.moe.dispatch == "grouped":
        y, probs, gate_idx = _moe_grouped_dispatch(cfg, p, x, res)
    else:
        y, probs, gate_idx = _moe_global_dispatch(cfg, p, x, res)
    if cfg.moe.n_shared:
        y = y + mlp_apply(cfg, p["shared"], x, res)
    # aux load-balancing loss (Switch-style), returned for the train loss
    E = cfg.moe.n_routed
    density = jnp.mean(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32),
                       axis=(0, 1))
    mean_prob = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E * jnp.sum(density * mean_prob)
    return y, aux


# ---------------------------------------------------------------------------
# Mamba1 block (selective scan; chunked associative scan for train/prefill)
# ---------------------------------------------------------------------------

def mamba_init(cfg: ModelConfig, key, dtype) -> Tuple[Params, Axes]:
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
    dtr = cfg.resolved_dt_rank
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    p = {
        "in_proj": _dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": _dense_init(ks[1], (dc, di), dtype,
                              scale=1.0 / math.sqrt(dc)),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], (di, dtr + 2 * ds), dtype),
        "dt_proj": _dense_init(ks[3], (dtr, di), dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d), dtype),
    }
    a = {
        "in_proj": ("d_model", "d_inner"),
        "conv_w": ("conv", "d_inner"),
        "conv_b": ("d_inner",),
        "x_proj": ("d_inner", None),
        "dt_proj": ("dt_rank", "d_inner"),
        "dt_bias": ("d_inner",),
        "A_log": ("d_inner", None),
        "D": ("d_inner",),
        "out_proj": ("d_inner", "d_model"),
    }
    return p, a


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,di); w: (dc,di). state: (B,dc-1,di)."""
    dc = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(dc))
    new_state = xp[:, -(dc - 1):, :] if dc > 1 else None
    return y + b, new_state


def _ssm_scan_chunked(a, b, C, h0, chunk):
    """h_t = a_t * h_{t-1} + b_t ; y_t = sum_s C_t[s] h_t[:,s].
    a,b: (B,S,di,ds); C: (B,S,ds). Chunked associative scan (compile-small,
    FLOP-countable); returns y (B,S,di), h_final (B,di,ds)."""
    B, S, di, ds = a.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk
    ac = jnp.moveaxis(a.reshape(B, n, chunk, di, ds), 1, 0)
    bc = jnp.moveaxis(b.reshape(B, n, chunk, di, ds), 1, 0)
    Cc = jnp.moveaxis(C.reshape(B, n, chunk, ds), 1, 0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def body(h, xs):
        aj, bj, Cj = xs
        # prefix scan within the chunk
        pa, pb = jax.lax.associative_scan(combine, (aj, bj), axis=1)
        hs = pa * h[:, None] + pb                       # (B,chunk,di,ds)
        y = jnp.einsum("bcds,bcs->bcd", hs, Cj)
        return hs[:, -1], y

    h_fin, ys = jax.lax.scan(body, h0, (ac, bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    return y, h_fin


def mamba_apply(cfg: ModelConfig, p: Params, x, *, res=None,
                cache: Optional[Dict] = None, decode: bool = False):
    """x: (B,S,d). Train/prefill (decode=False) or single-step decode
    (S==1, cache={'h','conv'})."""
    B, S, _ = x.shape
    di, ds = cfg.d_inner, cfg.ssm.d_state
    dtr = cfg.resolved_dt_rank
    xz = x @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    xin = constrain(xin, res, ("batch", "seq", "d_inner"))
    conv_state = cache.get("conv") if cache else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"],
                                state=conv_state if decode else None)
    xc = jax.nn.silu(xc)
    proj = xc @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dtr] @ p["dt_proj"] + p["dt_bias"])
    Bmat = proj[..., dtr:dtr + ds].astype(jnp.float32)     # (B,S,ds)
    Cmat = proj[..., dtr + ds:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                               # (di,ds)
    dt32 = dt.astype(jnp.float32)
    a = jnp.exp(dt32[..., None] * A)                       # (B,S,di,ds)
    b = (dt32 * xc.astype(jnp.float32))[..., None] * Bmat[:, :, None, :]
    if decode:
        h0 = cache["h"]
        h = a[:, 0] * h0 + b[:, 0]
        y = jnp.einsum("bds,bs->bd", h, Cmat[:, 0])[:, None, :]
        new_h = h
    else:
        h0 = (cache["h"] if cache is not None
              else jnp.zeros((B, di, ds), jnp.float32))
        y, new_h = _ssm_scan_chunked(a, b, Cmat, h0, cfg.scan_chunk)
    y = y.astype(x.dtype) + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"h": new_h}
        if new_conv is not None:
            new_cache["conv"] = new_conv.astype(cache["conv"].dtype) \
                if "conv" in cache else new_conv
        elif "conv" in cache:
            new_cache["conv"] = cache["conv"]
    return out, new_cache


def mamba_cache_init(cfg: ModelConfig, batch, dtype):
    di, ds, dc = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
    c = {"h": jnp.zeros((batch, di, ds), jnp.float32)}
    a = {"h": ("batch", "d_inner", None)}
    if dc > 1:
        c["conv"] = jnp.zeros((batch, dc - 1, di), dtype)
        a["conv"] = ("batch", None, "d_inner")
    return c, a
