"""Unified decoder LM covering all ten assigned architectures.

The stack is ``embed -> [pre_blocks] -> scan(super-blocks) -> norm -> head``.
A *super-block* is the repeating period of ``cfg.block_period`` layers (1 for
uniform stacks; 8 for jamba's attn:mamba 1:7 + MoE-every-2 pattern); its
parameters are stacked over ``n_blocks`` and the stack is a single
``jax.lax.scan`` (rematerialized for training) so the HLO stays compact
enough for the 512-way GSPMD compile.

Modalities: ``vlm`` consumes precomputed patch embeddings for the first
``n_patches`` positions (frontend stub per the assignment); ``audio`` embeds
``n_codebooks`` parallel EnCodec token streams (summed) and predicts all
codebooks per step.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(cfg: ModelConfig, layer_idx: int, key, dtype):
    kmix, kmlp, kn = jax.random.split(key, 3)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), dtype),
                 "ln2": jnp.ones((cfg.d_model,), dtype)}
    a: Params = {"ln1": ("d_model",), "ln2": ("d_model",)}
    mix = cfg.mixer_kind(layer_idx)
    if mix == "attn":
        sub = L.mla_init if cfg.attn_kind == "mla" else L.gqa_init
        p["mixer"], a["mixer"] = sub(cfg, kmix, dtype)
    else:
        p["mixer"], a["mixer"] = L.mamba_init(cfg, kmix, dtype)
    if cfg.mlp_kind(layer_idx) == "moe":
        p["mlp"], a["mlp"] = L.moe_init(cfg, kmlp, dtype)
    elif cfg.d_ff > 0:
        p["mlp"], a["mlp"] = L.mlp_init(cfg, kmlp, dtype)
    else:
        # pure-Mamba blocks (falcon-mamba) have no MLP: drop ln2 as well
        del p["ln2"], a["ln2"]
    return p, a


def _block_init(cfg: ModelConfig, block_start: int, key, dtype):
    P = cfg.block_period
    p, a = {}, {}
    for i in range(P):
        p[f"sub{i}"], a[f"sub{i}"] = _layer_init(
            cfg, block_start + i, jax.random.fold_in(key, i), dtype)
    return p, a


def init_params(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    """Returns (params, logical_axes) with identical tree structure."""
    cfg.validate()
    dtype = _dtype(cfg)
    kE, kB, kH, kP = jax.random.split(key, 4)
    p: Params = {}
    a: Params = {}
    if cfg.frontend == "encodec_stub":
        p["embed"] = L._dense_init(kE, (cfg.n_codebooks, cfg.vocab_size,
                                        cfg.d_model), dtype, scale=0.02)
        a["embed"] = (None, "vocab", "d_model")
    else:
        p["embed"] = L._dense_init(kE, (cfg.vocab_size, cfg.d_model), dtype,
                                   scale=0.02)
        a["embed"] = ("vocab", "d_model")
    # leading dense layers (outside the scan), e.g. deepseek first_dense=1
    pre = []
    pre_a = []
    for i in range(cfg.moe.first_dense):
        lp, la = _layer_init(cfg, i, jax.random.fold_in(kP, i), dtype)
        pre.append(lp)
        pre_a.append(la)
    if pre:
        p["pre_blocks"] = pre
        a["pre_blocks"] = pre_a
    # stacked super-blocks
    P = cfg.block_period
    n_blocks = (cfg.n_layers - cfg.moe.first_dense) // P
    blocks = []
    block_axes = None
    for b in range(n_blocks):
        bp, ba = _block_init(cfg, cfg.moe.first_dense + b * P,
                             jax.random.fold_in(kB, b), dtype)
        blocks.append(bp)
        block_axes = ba
    p["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    a["blocks"] = jax.tree.map(lambda ax: (None,) + ax, block_axes,
                               is_leaf=lambda x: isinstance(x, tuple))
    p["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    a["final_norm"] = ("d_model",)
    if not cfg.tie_embeddings:
        out_dim = cfg.vocab_size * (cfg.n_codebooks
                                    if cfg.frontend == "encodec_stub" else 1)
        p["lm_head"] = L._dense_init(kH, (cfg.d_model, out_dim), dtype)
        a["lm_head"] = ("d_model", "vocab")
    return p, a


def init_abstract(cfg: ModelConfig):
    """(ShapeDtypeStruct params, logical axes) without allocation.  The axes
    tree is static python, captured by closure during the abstract trace."""
    captured = {}

    def build():
        p, a = init_params(cfg, jax.random.PRNGKey(0))
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(build)
    return shapes, captured["axes"]


def param_count(cfg: ModelConfig) -> Tuple[int, int]:
    """(total_params, active_params) — `active` discounts routed experts to
    the activated fraction (top_k/n_routed) and drops the input embedding
    gather, for the 6·N_active·D useful-FLOPs estimate."""
    params, _ = init_abstract(cfg)
    total = sum(int(np_prod(x.shape)) for x in jax.tree.leaves(params))
    routed = 0

    def walk(t):
        nonlocal routed
        if isinstance(t, dict):
            if "router" in t:  # an MoE mlp subtree
                for k in ("w_gate", "w_up", "w_down"):
                    routed += int(np_prod(t[k].shape))
            for v in t.values():
                if isinstance(v, (dict, list)):
                    walk(v)
        elif isinstance(t, list):
            for v in t:
                walk(v)

    walk(params)
    if cfg.moe.n_routed:
        active = total - routed + routed * cfg.moe.top_k / cfg.moe.n_routed
    else:
        active = total
    emb = int(np_prod(params["embed"].shape))
    return total, int(active - emb)


def np_prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: Params, tokens, patches=None):
    if cfg.frontend == "encodec_stub":
        # tokens: (B, S, n_codebooks)
        x = 0.
        for cb in range(cfg.n_codebooks):
            x = x + jnp.take(params["embed"][cb], tokens[..., cb], axis=0)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "vit_stub" and patches is not None:
        npatch = patches.shape[1]
        x = jnp.concatenate([patches.astype(x.dtype), x[:, npatch:]], axis=1)
    return x


def lm_head(cfg: ModelConfig, params: Params, x):
    if cfg.tie_embeddings:
        w = params["embed"]
        if cfg.frontend == "encodec_stub":
            w = w.reshape(-1, cfg.d_model)
        logits = x @ w.T
    else:
        logits = x @ params["lm_head"]
    if cfg.frontend == "encodec_stub":
        logits = logits.reshape(logits.shape[:-1]
                                + (cfg.n_codebooks, cfg.vocab_size))
    return logits


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, lp: Params, layer_idx: int, x, positions,
                 res, cache=None, pos=None, decode=False):
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    mix = cfg.mixer_kind(layer_idx)
    mc = cache.get("mixer") if cache is not None else None
    if mix == "attn":
        fn = L.mla_apply if cfg.attn_kind == "mla" else L.gqa_apply
        h, new_mc = fn(cfg, lp["mixer"], h, positions, res=res,
                       cache=mc, pos=pos)
    else:
        h, new_mc = L.mamba_apply(cfg, lp["mixer"], h, res=res,
                                  cache=mc, decode=decode)
    x = x + h
    if "mlp" in lp:
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.mlp_kind(layer_idx) == "moe":
            h, a = L.moe_apply(cfg, lp["mlp"], h, res=res)
            aux = aux + a
        else:
            h = L.mlp_apply(cfg, lp["mlp"], h, res=res)
        x = x + h
    new_cache = {"mixer": new_mc} if cache is not None else None
    return x, aux, new_cache


def _apply_block(cfg: ModelConfig, bp: Params, x, positions, res,
                 cache=None, pos=None, decode=False):
    P = cfg.block_period
    aux = jnp.zeros((), jnp.float32)
    new_cache = {} if cache is not None else None
    for i in range(P):
        li = cfg.moe.first_dense + i   # periodic kinds: representative index
        lc = cache.get(f"sub{i}") if cache is not None else None
        x, a, nc = _apply_layer(cfg, bp[f"sub{i}"], li, x, positions, res,
                                cache=lc, pos=pos, decode=decode)
        aux = aux + a
        if new_cache is not None:
            new_cache[f"sub{i}"] = nc
    return x, aux, new_cache


def _auto_groups(n_blocks: int) -> int:
    """Largest divisor of n_blocks that is <= sqrt(n_blocks)."""
    g = 1
    d = 1
    while d * d <= n_blocks:
        if n_blocks % d == 0:
            g = d
        d += 1
    return g


def _remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    if cfg.remat_policy == "everything":
        return jax.checkpoint_policies.everything_saveable
    return jax.checkpoint_policies.nothing_saveable


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Params, tokens, *, patches=None,
            res=None, remat: bool = True):
    """Training/scoring forward. tokens: (B,S) int32 (or (B,S,CB) audio).
    Returns (logits, aux_loss)."""
    x = embed_tokens(cfg, params, tokens, patches)
    x = constrain(x, res, ("batch", "seq", None))
    S = x.shape[1]
    positions = jnp.arange(S)

    def block_fn(x, bp):
        y, aux, _ = _apply_block(cfg, bp, x, positions, res)
        return y, aux

    aux_total = jnp.zeros((), jnp.float32)
    for lp in params.get("pre_blocks", []):
        li = 0
        x, a, _ = _apply_layer(cfg, lp, li, x, positions, res)
        aux_total = aux_total + a
    body = block_fn
    if remat and cfg.remat_inner != "none":
        body = jax.checkpoint(block_fn, policy=_remat_policy(cfg),
                              prevent_cse=False)

    def scan_body(carry, bp):
        x, aux = carry
        y, a = body(x, bp)
        return (y, aux + a), None

    blocks = params["blocks"]
    n_blocks = jax.tree.leaves(blocks)[0].shape[0]
    G = cfg.remat_groups or _auto_groups(n_blocks)
    if remat and G > 1 and n_blocks % G == 0:
        # two-level remat: only G group-boundary activations are saved;
        # each group's interior is recomputed during its backward segment
        seg = n_blocks // G
        grouped = jax.tree.map(
            lambda t: t.reshape((G, seg) + t.shape[1:]), blocks)

        def group_body(carry, gp):
            out, _ = jax.lax.scan(scan_body, carry, gp)
            return out, None

        outer = jax.checkpoint(group_body, policy=_remat_policy(cfg),
                               prevent_cse=False)
        (x, aux_total), _ = jax.lax.scan(outer, (x, aux_total), grouped)
    else:
        (x, aux_total), _ = jax.lax.scan(scan_body, (x, aux_total), blocks)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(cfg, params, x)
    logits = constrain(logits, res, ("batch", "seq", None)
                       if cfg.frontend != "encodec_stub"
                       else ("batch", "seq", None, None))
    return logits, aux_total


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Returns (cache, logical_axes) pytrees.  With ``decode_unroll`` the
    per-block caches are an UNSTACKED list (so donation aliases each buffer
    in place during decode); otherwise stacked over n_blocks for the scan."""
    dtype = _dtype(cfg)

    def layer_cache(layer_idx):
        mix = cfg.mixer_kind(layer_idx)
        if mix == "attn":
            sub = (L.mla_cache_init if cfg.attn_kind == "mla"
                   else L.gqa_cache_init)
            c, a = sub(cfg, batch, max_seq, dtype)
        else:
            c, a = L.mamba_cache_init(cfg, batch, dtype)
        return {"mixer": c}, {"mixer": a}

    P = cfg.block_period
    n_blocks = (cfg.n_layers - cfg.moe.first_dense) // P
    bc, ba = {}, {}
    for i in range(P):
        bc[f"sub{i}"], ba[f"sub{i}"] = layer_cache(cfg.moe.first_dense + i)
    if cfg.decode_unroll:
        cache = {"blocks": [jax.tree.map(lambda x: jnp.array(x), bc)
                            for _ in range(n_blocks)]}
        axes = {"blocks": [ba] * n_blocks}
    else:
        cache = {"blocks": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_blocks,) + x.shape), bc)}
        axes = {"blocks": jax.tree.map(lambda ax: (None,) + ax, ba,
                                       is_leaf=lambda x: isinstance(x, tuple))}
    pre_c, pre_a = [], []
    for i in range(cfg.moe.first_dense):
        c, a = layer_cache(i)
        pre_c.append(c)
        pre_a.append(a)
    if pre_c:
        cache["pre_blocks"] = pre_c
        axes["pre_blocks"] = pre_a
    return cache, axes


def _with_cache_scan(cfg, params, cache, x, positions, res, pos, decode):
    aux = jnp.zeros((), jnp.float32)
    new_pre = []
    for i, lp in enumerate(params.get("pre_blocks", [])):
        x, a, nc = _apply_layer(cfg, lp, i, x, positions, res,
                                cache=cache["pre_blocks"][i], pos=pos,
                                decode=decode)
        new_pre.append(nc)
        aux = aux + a

    if isinstance(cache["blocks"], list) and decode:
        # unrolled decode: per-block caches are separate (donatable) buffers
        # -> in-place updates, no scan-carry double buffering;
        # params stay stacked — static slices are read-only views.  The
        # optimization barrier pins the per-layer slice: without it the CPU
        # backend's bf16-dot f32-conversion gets hoisted above the slice and
        # materializes f32 copies of the ENTIRE weight stack (dbrx-132b:
        # 3x 9.8 GiB per layer; §Perf cell C).
        blocks_p = params["blocks"]
        new_blocks = []
        for i, bc in enumerate(cache["blocks"]):
            if isinstance(blocks_p, list):
                bp = blocks_p[i]     # unstacked serving weights (preferred)
            else:
                bp = jax.tree.map(lambda t: t[i], blocks_p)
            # tie this layer's weights to the running activation: otherwise
            # the scheduler hoists every layer's (CPU-backend) bf16->f32
            # weight conversion to the front and keeps them all live at once
            bp, x = jax.lax.optimization_barrier((bp, x))
            x, a, nc = _apply_block(cfg, bp, x, positions, res,
                                    cache=bc, pos=pos, decode=decode)
            new_blocks.append(nc)
    else:
        blocks_cache = cache["blocks"]
        blocks_p = params["blocks"]
        unstack = False
        if isinstance(blocks_cache, list):
            # prefill with unrolled-style caches: stack for the scan
            blocks_cache = jax.tree.map(lambda *xs: jnp.stack(xs),
                                        *blocks_cache)
            unstack = True
        if isinstance(blocks_p, list):   # unstacked serving weights
            blocks_p = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks_p)

        def scan_body(x, xs):
            bp, bc = xs
            y, a, nc = _apply_block(cfg, bp, x, positions, res,
                                    cache=bc, pos=pos, decode=decode)
            return y, nc

        x, new_blocks = jax.lax.scan(scan_body, x,
                                     (blocks_p, blocks_cache))
        if unstack:
            n = len(cache["blocks"])
            new_blocks = [jax.tree.map(lambda t: t[i], new_blocks)
                          for i in range(n)]
    new_cache = {"blocks": new_blocks}
    if new_pre:
        new_cache["pre_blocks"] = new_pre
    return x, new_cache


def prefill(cfg: ModelConfig, params: Params, tokens, cache, *,
            patches=None, res=None):
    """Fill the cache with the prompt; returns (logits_last, new_cache)."""
    x = embed_tokens(cfg, params, tokens, patches)
    x = constrain(x, res, ("batch", "seq", None))
    S = x.shape[1]
    positions = jnp.arange(S)
    x, new_cache = _with_cache_scan(cfg, params, cache, x, positions, res,
                                    pos=None, decode=False)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(cfg, params, x[:, -1:])
    return logits, new_cache


def decode_step(cfg: ModelConfig, params: Params, token, cache, pos, *,
                res=None):
    """One decode step. token: (B,1) int32 (or (B,1,CB)); pos: () int32.
    Returns (logits, new_cache)."""
    x = embed_tokens(cfg, params, token)
    positions = jnp.full((1,), pos)
    x, new_cache = _with_cache_scan(cfg, params, cache, x, positions, res,
                                    pos=pos, decode=True)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head(cfg, params, x)
    return logits, new_cache
