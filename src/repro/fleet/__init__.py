"""Fleet tier: deadline-aware routing over N replica sessions.

The serving stack scaled out: a :class:`FleetRouter` places
deadline-stamped requests across replica sessions with pluggable
placement policies (registered like schedulers), sheds at the router via
the shared EDF admission, and grows/shrinks the fleet with an
:class:`ElasticAutoscaler` through the sessions' ``add_device`` /
``remove_device`` membership hooks.  ``simulate_fleet`` is the
policy-validation twin (epoch co-simulation over
``simulate_serving`` resume states); ``FleetServer``/``ReplicaWorker``
run the same router against real threaded sessions.
"""
from repro.fleet.autoscale import (AutoscaleConfig, ElasticAutoscaler,
                                   ScaleEvent)
from repro.fleet.placement import (PLACEMENTS, DeadlinePlacement,
                                   LeastResidualPlacement, PlacementPolicy,
                                   PlacementSpec, PowerPropPlacement,
                                   ReplicaState, RoundRobinPlacement,
                                   StaticPlacement, available_placements,
                                   make_placement, placement_accepts,
                                   placement_spec, register_placement,
                                   unregister_placement)
from repro.fleet.router import FleetRouter, Placed, RouterConfig
from repro.fleet.sim import (FleetSimResult, SimReplica, crosscheck_fleet,
                             simulate_fleet)
from repro.fleet.worker import FleetServer, ReplicaWorker

__all__ = [
    "AutoscaleConfig", "DeadlinePlacement", "ElasticAutoscaler",
    "FleetRouter", "FleetServer", "FleetSimResult", "LeastResidualPlacement",
    "PLACEMENTS", "Placed", "PlacementPolicy", "PlacementSpec",
    "PowerPropPlacement", "ReplicaState", "ReplicaWorker",
    "RoundRobinPlacement", "RouterConfig", "ScaleEvent", "SimReplica",
    "StaticPlacement", "available_placements", "crosscheck_fleet",
    "make_placement", "placement_accepts", "placement_spec",
    "register_placement", "simulate_fleet", "unregister_placement",
]
