"""Threaded fleet: ReplicaWorkers behind a FleetRouter.

The real-execution counterpart of ``fleet/sim.py``: each
:class:`ReplicaWorker` wraps a :class:`~repro.serve.server.CoexecServer`
(its own ``EngineSession``, its own model replicas, its own dispatch
thread) and consumes whatever the router places on it.  Workers run with
``policy="none"`` — admission and shedding happened AT THE ROUTER; a
replica executes everything it is handed.

Elastic membership is literal: an autoscaler "up"/"down" event is applied
to the worker's session through the existing ``add_device`` /
``remove_device`` hooks (``ReplicaWorker.activate`` / ``deactivate``).
In-flight submits are unaffected — the session snapshots its device list
at dispatch time — so a scale-down never corrupts a running round; it
only stops new rounds from using the removed groups (locked by
tests/test_elastic.py).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.device import DeviceGroup
from repro.fleet.autoscale import ElasticAutoscaler, ScaleEvent
from repro.fleet.router import FleetRouter, RouterConfig
from repro.serve.replica import Replica
from repro.serve.server import CoexecServer, ServeOutcome, ServerConfig
from repro.serve.stats import summarize
from repro.serve.workload import Request, RequestQueue


class ReplicaWorker:
    """One routed executor: a CoexecServer consuming its placed share.

    The worker thread drains an inbox the router fills; each drain becomes
    one dispatch round on the worker's session.  ``declared_power`` is the
    capacity (requests/s) the worker advertises to the router up front;
    measured powers flow back through :meth:`measured_power`.
    """

    def __init__(self, name: str, replicas: Sequence[Replica],
                 cfg: ServerConfig, *, declared_power: float = 1.0):
        if declared_power <= 0:
            raise ValueError("declared_power must be > 0")
        self.name = name
        self.declared_power = declared_power
        # shedding is the router's job: the worker admits nothing away
        self.cfg = dataclasses.replace(cfg, policy="none")
        self.server = CoexecServer(replicas, self.cfg,
                                   initial_power={r.name: declared_power
                                                  / len(replicas)
                                                  for r in replicas})
        self.results: Dict[int, np.ndarray] = {}
        self.dispatch: Dict[str, int] = {}
        self.completed: List[Request] = []
        self._inbox: List[Request] = []
        self._inflight = 0                   # requests inside a round
        self._cv = threading.Condition()
        self._stop = False
        self._t0: Optional[float] = None
        self._thread = threading.Thread(target=self._loop,
                                        name=f"fleet-{name}", daemon=True)

    # -- elastic membership (the add_device/remove_device hooks) -------------
    def activate(self) -> None:
        """(Re-)attach this worker's device groups to its session."""
        session = self.server.session
        have = {d.name for d in session.devices}
        for r in self.server.replicas:
            if r.name not in have:
                session.add_device(DeviceGroup(r.name))

    def deactivate(self) -> None:
        """Detach the device groups: in-flight rounds finish untouched
        (devices were snapshotted at dispatch); new rounds can't start."""
        for r in self.server.replicas:
            self.server.session.remove_device(r.name)

    # -- the routed feed -----------------------------------------------------
    def start(self, t0: float) -> None:
        self._t0 = t0
        self._thread.start()

    def submit(self, requests: Sequence[Request]) -> None:
        with self._cv:
            if self._stop:
                raise RuntimeError(f"worker {self.name!r} is stopped")
            for r in requests:
                r.gen_alloc = self.cfg.gen
            self._inbox.extend(requests)
            self._cv.notify_all()

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._inbox and not self._stop:
                    self._cv.wait()
                if not self._inbox and self._stop:
                    return
                batch = self._inbox
                self._inbox = []
                self._inflight += len(batch)
            batch.sort(key=lambda r: (r.deadline, r.rid))
            now = time.perf_counter() - self._t0
            self.server._run_round(batch, now, self._t0, self.results,
                                   self.dispatch)
            with self._cv:
                self.completed.extend(batch)
                self._inflight -= len(batch)
                self._cv.notify_all()

    # -- router feedback -----------------------------------------------------
    def measured_power(self) -> Optional[float]:
        """Measured requests/s across the worker's replicas (None until
        the first round calibrates it)."""
        p = sum(self.server._power.values())
        return p if p > 0 and self.server._calibrated else None

    def backlog(self) -> int:
        """Routed-but-unfinished requests (inbox + in-round)."""
        with self._cv:
            return len(self._inbox) + self._inflight

    def drain(self) -> None:
        """Block until every routed request has completed."""
        with self._cv:
            while self._inbox or self._inflight:
                self._cv.wait()

    def stop(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._thread.is_alive():
            self._thread.join()
        self.server.close()


class FleetServer:
    """Open-loop serving across ReplicaWorkers, placed by a FleetRouter.

    The run loop polls the request queue, routes every arrival (EDF
    admission + placement + autoscaling at the router), hands placements
    to the owning workers, and periodically feeds measured worker powers
    and backlogs back into the router's EWMA book — the same
    predict/measure/correct cycle as ``simulate_fleet``, on real threads.
    """

    def __init__(self, workers: Sequence[ReplicaWorker],
                 router_cfg: Optional[RouterConfig] = None, *,
                 autoscaler: Optional[ElasticAutoscaler] = None,
                 standby: Sequence[str] = (),
                 poll_interval_s: float = 2e-3,
                 feedback_interval_s: float = 0.05):
        self.workers = list(workers)
        self._by_name = {w.name: w for w in self.workers}
        if len(self._by_name) != len(self.workers):
            raise ValueError("duplicate worker names")
        self.router = FleetRouter(
            [(w.name, w.declared_power) for w in self.workers],
            router_cfg, autoscaler=autoscaler, standby=standby,
            on_scale=self._apply_scale)
        for name in standby:
            self._by_name[name].deactivate()
        self.poll_interval_s = poll_interval_s
        self.feedback_interval_s = feedback_interval_s

    def _apply_scale(self, ev: ScaleEvent) -> None:
        w = self._by_name[ev.replica]
        if ev.action == "up":
            w.activate()
        else:
            w.deactivate()

    def run(self, queue: RequestQueue) -> ServeOutcome:
        t0 = time.perf_counter()
        for w in self.workers:
            w.start(t0)
        pending: List[Request] = []
        last_fb = 0.0
        try:
            while True:
                now = time.perf_counter() - t0
                pending.extend(queue.poll(now))
                if now - last_fb >= self.feedback_interval_s:
                    last_fb = now
                    for i, w in enumerate(self.workers):
                        p = w.measured_power()
                        # backlog in request units == the router's work
                        # units (every threaded request is one unit)
                        self.router.feedback(i, now, measured_power=p,
                                             measured_resid=w.backlog())
                if not pending:
                    nxt = queue.next_arrival()
                    if nxt is None:
                        break
                    time.sleep(min(max(nxt - now, 0.0) + 1e-4,
                                   self.feedback_interval_s))
                    continue
                placed, pending = self.router.route(pending, now)
                per_worker: Dict[int, List[Request]] = {}
                for p in placed:
                    if p.replica is not None:
                        per_worker.setdefault(p.replica, []).append(p.request)
                for idx, batch in per_worker.items():
                    self.workers[idx].submit(batch)
                if not placed:
                    time.sleep(self.poll_interval_s)
            for w in self.workers:
                w.drain()
        finally:
            for w in self.workers:
                w.stop()
        requests: List[Request] = list(self.router.shed)
        results: Dict[int, np.ndarray] = {}
        dispatch: Dict[str, int] = {}
        for w in self.workers:
            requests.extend(w.completed)
            results.update(w.results)
            for k, v in w.dispatch.items():
                dispatch[f"{w.name}:{k}"] = v
        stats = summarize(requests, duration=time.perf_counter() - t0,
                          dispatch=dispatch)
        return ServeOutcome(stats=stats, requests=requests, results=results)
