"""Epoch-based fleet co-simulation over ``simulate_serving`` resume hooks.

Every router policy must survive the same cross-examination the batch
schedulers get from scale1000: drive it against the calibrated
discrete-event replica models and check the outcomes.  ``simulate_fleet``
couples the analytic :class:`FleetRouter` to N *measured* replicas:

    per arrival:  router.route() — admission, placement, autoscaling —
                  against the router's EWMA book (predictions);
    per epoch:    each replica executes its routed requests through
                  ``simulate_serving(..., resume=state)``, continuing its
                  own device clocks / EWMA powers / jitter stream
                  (measurements);
    epoch end:    measured residual work and measured alive power feed
                  back into the router's book (``FleetRouter.feedback``).

The router never sees inside a replica — only declared powers up front
and measured (power, residual) feedback afterwards, exactly the contract
the threaded fleet server has.  ``crosscheck_fleet`` then replays each
replica's routed assignment one-shot through ``simulate_serving`` (via
the trace record/replay machinery, so accounting starts clean) and
compares aggregate outcomes — the fleet-level analogue of scale1000's
threaded-vs-simulated agreement gate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.simulate import SimConfig, SimDevice, ServeSimResult, \
    simulate_serving
from repro.fleet.autoscale import ElasticAutoscaler
from repro.fleet.router import FleetRouter, RouterConfig
from repro.serve.stats import ServeStats, summarize
from repro.serve.workload import TraceWorkload


@dataclass
class SimReplica:
    """One modeled replica: a named device fleet the router places onto."""
    name: str
    devices: List[SimDevice]
    lws: int = 1

    def declared_power(self) -> float:
        """What the replica advertises to the router: the (possibly
        biased) offline profile — same information Static trusts."""
        return sum(d.throughput * d.profile_bias for d in self.devices)


@dataclass
class FleetSimResult:
    requests: List                          # all offered, accounting filled
    stats: ServeStats
    router: FleetRouter
    replica_requests: Dict[str, List]       # replica -> routed requests
    replica_results: Dict[str, ServeSimResult]
    epochs: int = 0

    @property
    def scale_events(self):
        return self.router.scale_events


def simulate_fleet(requests: Sequence, replicas: Sequence[SimReplica],
                   cfg: SimConfig, router_cfg: Optional[RouterConfig] = None,
                   *, autoscaler: Optional[ElasticAutoscaler] = None,
                   standby: Sequence[str] = (),
                   epoch_s: float = 0.25,
                   batch_window_s: float = 0.0) -> FleetSimResult:
    """Route ``requests`` across ``replicas`` and execute epoch by epoch.

    Replica-side admission runs with ``policy="none"``: shedding is the
    ROUTER's decision (shared EDF admission + deadline placement); a
    replica executes everything routed to it.  ``epoch_s`` is the
    feedback granularity — measured residual/power reach the router once
    per epoch, so a smaller epoch adapts faster at more feedback traffic
    (the fleet-level lease-size trade).
    """
    if epoch_s <= 0:
        raise ValueError("epoch_s must be > 0")
    names = [rep.name for rep in replicas]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate replica names: {names}")
    router = FleetRouter(
        [(rep.name, rep.declared_power()) for rep in replicas],
        router_cfg, autoscaler=autoscaler, standby=standby)
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
    n = len(replicas)
    states = [None] * n                     # per-replica ServeSimState
    routed_all: List[List] = [[] for _ in range(n)]
    busy_total: List[List[float]] = [[] for _ in range(n)]
    last_res: List[Optional[ServeSimResult]] = [None] * n
    epochs = 0
    i = 0
    carry: List = []                        # leftover beyond admit quantum

    def execute_epoch(chunks: List[List], t_end: float) -> None:
        for k, chunk in enumerate(chunks):
            if not chunk:
                continue
            res = simulate_serving(chunk, replicas[k].lws,
                                   replicas[k].devices, cfg,
                                   policy="none",
                                   batch_window_s=batch_window_s,
                                   resume=states[k])
            states[k] = res.state
            last_res[k] = res
            routed_all[k].extend(chunk)
            if res.all_dead:
                # the replica's whole device fleet died: it leaves the
                # placement set for good, like a failed device in a run
                router.states[k].active = False
        # measured feedback: outstanding work on real device clocks, the
        # schedulers' online power estimates, and the measured energy
        # cost (cumulative joules over cumulative completed work — the
        # ``energy`` placement's J/wg signal), blended into the router's
        # EWMA book (replicas with no traffic yet keep their declared
        # profile)
        for k in range(n):
            st = states[k]
            if st is None:
                continue
            jwg = None
            res = last_res[k]
            if res is not None and res.energy_j > 0:
                done_wg = sum(r.size for r in routed_all[k]
                              if r.finish is not None)
                if done_wg > 0:
                    jwg = res.energy_j / done_wg
            router.feedback(k, t_end,
                            measured_power=st.alive_power() or None,
                            measured_resid=st.residual_wg(t_end),
                            measured_j_wg=jwg)

    while i < len(reqs) or carry:
        t0 = reqs[i].arrival if i < len(reqs) else carry[0].arrival
        t1 = t0 + epoch_s
        epoch_chunks: List[List] = [[] for _ in range(n)]
        progressed = False
        while i < len(reqs) and reqs[i].arrival < t1:
            r = reqs[i]
            i += 1
            placed, carry = router.route(carry + [r], r.arrival)
            progressed = progressed or bool(placed)
            for p in placed:
                if p.replica is not None:
                    epoch_chunks[p.replica].append(p.request)
        if carry and i >= len(reqs):
            # drain the quantum leftover at the epoch boundary
            placed, carry = router.route(carry, t1)
            progressed = progressed or bool(placed)
            for p in placed:
                if p.replica is not None:
                    epoch_chunks[p.replica].append(p.request)
            if not progressed and carry:
                raise RuntimeError(
                    f"router made no progress on {len(carry)} queued "
                    "requests (admission quantum too small for any single "
                    "request?)")
        execute_epoch(epoch_chunks, t1)
        epochs += 1

    duration = max((r.finish for r in reqs if r.finish is not None),
                   default=0.0)
    # fleet energy: each replica's last (cumulative) report covers its
    # whole resumed timeline, so the fleet total is a plain sum
    fleet_j = sum(res.energy_j for res in last_res if res is not None)
    stats = summarize(reqs, duration=duration or None, energy_j=fleet_j)
    return FleetSimResult(
        requests=reqs, stats=stats, router=router,
        replica_requests={replicas[k].name: routed_all[k]
                          for k in range(n)},
        replica_results={replicas[k].name: last_res[k]
                         for k in range(n) if last_res[k] is not None},
        epochs=epochs)


def crosscheck_fleet(result: FleetSimResult, replicas: Sequence[SimReplica],
                     cfg: SimConfig, *,
                     batch_window_s: float = 0.0) -> Dict[str, float]:
    """Replay each replica's routed assignment ONE-SHOT and compare.

    The epoch-chunked co-simulation and a one-shot ``simulate_serving``
    over the same assignment should agree: chunking only changes *when*
    the replica learns about requests, not the device model.  The replay
    goes through :class:`TraceWorkload` (accounting cleared — satellite
    dogfood), runs with the same config, and the aggregate on-time count
    is compared.  Returns ``{"cosim_attainment", "replay_attainment",
    "abs_diff"}`` for the benchmark's tolerance gate.
    """
    by_name = {rep.name: rep for rep in replicas}
    offered = len(result.requests)
    on_time_replay = 0
    for name, routed in result.replica_requests.items():
        if not routed:
            continue
        rep = by_name[name]
        fresh = TraceWorkload.from_requests(routed).requests()
        res = simulate_serving(fresh, rep.lws, rep.devices, cfg,
                               policy="none",
                               batch_window_s=batch_window_s)
        on_time_replay += sum(1 for r in res.requests if r.met_slo)
    cosim = result.stats.slo_attainment
    replay = on_time_replay / offered if offered else 0.0
    return {"cosim_attainment": cosim,
            "replay_attainment": replay,
            "abs_diff": abs(cosim - replay)}
