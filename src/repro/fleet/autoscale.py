"""Elastic autoscaling: replicas join and leave the fleet under load.

The router's queue-delay estimate (aggregate outstanding work over
aggregate ready power) is the one signal: a *sustained* breach of the
delay target scales up (activating a standby replica, which becomes
placeable only after its warm-up — joining is not free), a sustained idle
period scales down.  Flapping is penalized through the warm-up cost
account: a joined replica may not leave until it has been resident long
enough to amortize ``payback x warmup_s`` of the capacity its warm-up
burned, and every action starts a cooldown during which the autoscaler
holds still.  The decision layer is execution-agnostic — the discrete
fleet simulator and the threaded fleet server both drive ``step()`` and
apply its events through the session membership hooks
(``add_device`` / ``remove_device``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.fleet.placement import ReplicaState


@dataclass
class AutoscaleConfig:
    target_delay_s: float = 0.25       # router queue-delay SLO
    breach_s: float = 0.2              # sustained breach before scale-up
    idle_delay_s: float = 0.02         # delay below this counts as idle
    idle_s: float = 0.75               # sustained idle before scale-down
    warmup_s: float = 0.15             # join warm-up (not placeable yet)
    cooldown_s: float = 0.4            # min gap between scale actions
    # flap penalty: a joined replica must stay resident at least
    # payback * warmup_s (+ cooldown) before it may be scaled down, so a
    # join always amortizes the capacity its warm-up burned
    payback: float = 4.0
    min_replicas: int = 1
    max_replicas: Optional[int] = None

    def __post_init__(self):
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if (self.max_replicas is not None
                and self.max_replicas < self.min_replicas):
            raise ValueError("max_replicas must be >= min_replicas")


@dataclass
class ScaleEvent:
    t: float
    action: str                        # "up" | "down"
    replica: str
    queue_delay_s: float               # the signal at decision time
    reason: str


class ElasticAutoscaler:
    """Queue-delay-driven membership controller over ReplicaStates.

    Pure decision logic: ``step(now, states)`` flips ``active``/``warm_at``
    on the states it scales and returns the event (or None).  Whoever owns
    real resources (the threaded fleet server) subscribes to events and
    mirrors them onto sessions via the membership hooks.
    """

    def __init__(self, cfg: Optional[AutoscaleConfig] = None, **kw):
        self.cfg = cfg if cfg is not None else AutoscaleConfig(**kw)
        self.events: List[ScaleEvent] = []
        self.warmup_cost_s = 0.0       # total warm-up capacity burned
        self._breach_since: Optional[float] = None
        self._idle_since: Optional[float] = None
        self._last_action_t = -math.inf
        self._last_up_t = -math.inf

    # -- signal --------------------------------------------------------------
    @staticmethod
    def queue_delay(now: float, states: Sequence[ReplicaState]) -> float:
        """Fleet queue delay: aggregate outstanding work over aggregate
        READY power (a warming replica contributes nothing yet)."""
        ready = [s for s in states if s.ready(now)]
        if not ready:
            return math.inf
        power = sum(s.power for s in ready)
        work = sum(s.resid for s in ready)
        return work / max(power, 1e-12)

    # -- control loop --------------------------------------------------------
    def step(self, now: float,
             states: Sequence[ReplicaState]) -> Optional[ScaleEvent]:
        cfg = self.cfg
        delay = self.queue_delay(now, states)
        active = [s for s in states if s.active]
        if delay > cfg.target_delay_s:
            self._idle_since = None
            if self._breach_since is None:
                self._breach_since = now
            if (now - self._breach_since >= cfg.breach_s
                    and now - self._last_action_t >= cfg.cooldown_s
                    and (cfg.max_replicas is None
                         or len(active) < cfg.max_replicas)):
                standby = [s for s in states if not s.active]
                if standby:
                    return self._scale_up(now, standby, delay)
        elif delay < cfg.idle_delay_s:
            self._breach_since = None
            if self._idle_since is None:
                self._idle_since = now
            if (now - self._idle_since >= cfg.idle_s
                    and now - self._last_action_t >= cfg.cooldown_s
                    and len(active) > cfg.min_replicas):
                return self._scale_down(now, active, delay)
        else:
            # neither breaching nor idle: both dwell clocks reset — only
            # SUSTAINED signals act, transient blips never flap the fleet
            self._breach_since = None
            self._idle_since = None
        return None

    def _scale_up(self, now: float, standby: List[ReplicaState],
                  delay: float) -> ScaleEvent:
        # most powerful standby first: one join should clear the breach
        s = max(standby, key=lambda s: (s.power0, s.name))
        s.active = True
        s.warm_at = now + self.cfg.warmup_s
        s.joined_at = now
        s.last_t = now
        s.resid = 0.0
        self.warmup_cost_s += self.cfg.warmup_s
        self._last_up_t = now
        ev = ScaleEvent(t=now, action="up", replica=s.name,
                        queue_delay_s=delay,
                        reason=f"queue delay {delay:.3f}s > target "
                               f"{self.cfg.target_delay_s:.3f}s for "
                               f">= {self.cfg.breach_s:.3f}s")
        self._record(ev, now)
        return ev

    def _scale_down(self, now: float, active: List[ReplicaState],
                    delay: float) -> Optional[ScaleEvent]:
        cfg = self.cfg
        min_residency = cfg.payback * cfg.warmup_s + cfg.cooldown_s
        if now - self._last_up_t < min_residency:
            # fleet-wide flap guard: the latest join must amortize its
            # warm-up before ANY replica may leave — shrinking a fleet
            # that just paid to grow is the flap being penalized
            return None
        # only replicas that amortized their join may leave; prefer the
        # emptiest, then the weakest, then the youngest
        candidates = [s for s in active
                      if now - s.joined_at >= min_residency]
        if not candidates:
            return None
        s = min(candidates, key=lambda s: (s.resid, s.power0, s.name))
        s.active = False
        ev = ScaleEvent(t=now, action="down", replica=s.name,
                        queue_delay_s=delay,
                        reason=f"queue delay {delay:.3f}s < idle "
                               f"{cfg.idle_delay_s:.3f}s for "
                               f">= {cfg.idle_s:.3f}s")
        self._record(ev, now)
        return ev

    def _record(self, ev: ScaleEvent, now: float) -> None:
        self.events.append(ev)
        self._last_action_t = now
        self._breach_since = None
        self._idle_since = None

    # -- accounting ----------------------------------------------------------
    def flaps(self) -> int:
        """Direction reversals faster than the guards should allow: an up
        undone by a down before its warm-up amortized, or a down undone
        by an up faster than a genuine new breach could dwell.  A healthy
        controller reports 0 — the residency/cooldown/dwell guards make
        these structurally impossible, and this measures that claim."""
        cfg = self.cfg
        up_down = cfg.payback * cfg.warmup_s + cfg.cooldown_s
        down_up = max(cfg.cooldown_s, cfg.breach_s)
        n = 0
        for a, b in zip(self.events, self.events[1:]):
            if a.action == "up" and b.action == "down" \
                    and b.t - a.t < up_down:
                n += 1
            if a.action == "down" and b.action == "up" \
                    and b.t - a.t < down_up:
                n += 1
        return n

    def summary(self) -> dict:
        return {
            "events": [(e.t, e.action, e.replica) for e in self.events],
            "ups": sum(1 for e in self.events if e.action == "up"),
            "downs": sum(1 for e in self.events if e.action == "down"),
            "flaps": self.flaps(),
            "warmup_cost_s": self.warmup_cost_s,
        }
