"""Placement policies: which replica serves each request.

The fleet router's analogue of the scheduler registry — policies are
plain classes registered by name (``register_placement``), constructed by
``make_placement``, and the router consults exactly one per request:

    place(request, now, states) -> replica index, or None to SHED

Only deadline-aware policies ever return None; shedding is a *router*
decision (the replica never second-guesses it).  ``ReplicaState`` is the
router's per-replica book: a declared (offline) power, an online EWMA
power, and an EWMA of outstanding work that drains analytically at the
service rate between measurements — the same estimate-then-measure shape
as the schedulers' HGuided power adaptation, one rung up.

Built-ins:

* ``round_robin``     — cycle the ready replicas (the naivest baseline).
* ``static``          — deterministic weighted round-robin over DECLARED
  powers (largest-remainder credits).  Never adapts; this is the "best
  static single-replica assignment" family the benchmark must beat.
* ``power_prop``      — the same credit scheme over the *online* EWMA
  powers: adapts to measured capacity, blind to queue depth.
* ``least_residual``  — join-shortest-queue, weighted: place on the
  replica with the smallest predicted queue delay (EWMA outstanding work
  over EWMA power).
* ``deadline``        — EDF-aware least-finish-time: place on the ready
  replica predicted to *finish this request soonest*; if no replica can
  make the deadline, shed at the router so doomed work never displaces
  feasible work queued behind it.
* ``energy``          — joule-aware deadline placement: among the replicas
  predicted to MAKE the deadline, place on the one with the lowest
  measured J/work-group (the ``j_wg`` EWMA fed back by the driver);
  replicas without energy feedback yet, or infeasible requests, fall back
  to the ``deadline`` behavior — so with joule-blind replicas the two
  policies are identical.
"""
from __future__ import annotations

import functools
import inspect
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence


@dataclass
class ReplicaState:
    """The router's book on one replica.

    ``power0`` is the declared (offline-profiled) capacity in wg/s;
    ``power`` is the online EWMA the router refines from measured replica
    feedback; ``resid`` is the EWMA of outstanding (placed, unfinished)
    work, drained analytically at the service rate between updates.
    ``active``/``warm_at`` are the autoscaler's membership bits: a
    scaled-up replica is placeable only once its warm-up has elapsed.
    """
    name: str
    power0: float                          # declared capacity, wg/s
    power: float = 0.0                     # online EWMA capacity
    resid: float = 0.0                     # EWMA outstanding work, wg
    active: bool = True
    warm_at: float = 0.0                   # placeable from this time
    joined_at: float = 0.0                 # last activation time
    last_t: float = 0.0                    # residual drain clock
    placed: int = 0                        # requests routed here
    shed_for: int = 0                      # sheds attributed at placement
    # measured joules per work-group (EWMA from driver feedback); 0.0
    # means "no energy feedback yet" — energy placement then treats the
    # replica as cost-unknown and falls back to finish-time ordering
    j_wg: float = 0.0

    def __post_init__(self):
        if self.power <= 0.0:
            self.power = self.power0

    def drain(self, now: float) -> None:
        """Outstanding work drains at the service rate between updates."""
        if now > self.last_t:
            self.resid = max(0.0,
                             self.resid - (now - self.last_t) * self.power)
            self.last_t = now

    def ready(self, now: float) -> bool:
        return self.active and now >= self.warm_at

    def queue_delay(self, now: float) -> float:
        """Predicted wait before a request placed now starts draining."""
        return self.resid / max(self.power, 1e-12)

    def pred_finish(self, now: float, size: float) -> float:
        """Predicted completion of a size-``size`` request placed now."""
        return now + (self.resid + size) / max(self.power, 1e-12)

    def pred_joules(self, size: float) -> float:
        """Predicted energy of a size-``size`` request here (0.0 while
        the replica has no energy feedback)."""
        return size * self.j_wg


class PlacementPolicy:
    """Base contract: stateless between fleets, stateful within one."""

    def place(self, req, now: float,
              states: Sequence[ReplicaState]) -> Optional[int]:
        """Index into ``states`` for ``req``, or None to shed at the
        router.  Implementations must only pick ``ready(now)`` replicas;
        ``_ready`` provides the candidate list (never empty while any
        replica is active — a warming fleet falls back to active ones)."""
        raise NotImplementedError

    @staticmethod
    def _ready(now: float, states: Sequence[ReplicaState]) -> List[int]:
        ready = [i for i, s in enumerate(states) if s.ready(now)]
        if ready:
            return ready
        # every active replica still warming: the fleet must not drop on
        # the floor — queue onto the active set (it will be warm by then)
        return [i for i, s in enumerate(states) if s.active] or \
            list(range(len(states)))


class RoundRobinPlacement(PlacementPolicy):
    """Cycle the ready replicas, capacity-blind."""

    def __init__(self):
        self._i = 0

    def place(self, req, now, states):
        ready = self._ready(now, states)
        pick = ready[self._i % len(ready)]
        self._i += 1
        return pick


class _WeightedCredit(PlacementPolicy):
    """Deterministic weighted round-robin by largest-remainder credits:
    every placement grants each candidate ``w_i / sum(w)`` credit and
    spends one credit on the argmax — long-run shares converge to the
    weights with no randomness (bit-identical replays)."""

    def _weight(self, s: ReplicaState) -> float:
        raise NotImplementedError

    def __init__(self):
        self._credit: Dict[str, float] = {}

    def place(self, req, now, states):
        ready = self._ready(now, states)
        weights = {i: max(self._weight(states[i]), 1e-12) for i in ready}
        total = sum(weights.values())
        for i in ready:
            self._credit[states[i].name] = \
                self._credit.get(states[i].name, 0.0) + weights[i] / total
        pick = max(ready, key=lambda i: (self._credit[states[i].name], -i))
        self._credit[states[pick].name] -= 1.0
        return pick


class StaticPlacement(_WeightedCredit):
    """Weighted by DECLARED powers only — the no-feedback baseline.

    This is the strongest member of the "static single-replica
    assignment" family: each request is deterministically pinned to one
    replica in proportion to the offline capacity profile, exactly like a
    Static scheduler chunk split.  It pays for profile bias, stragglers
    and queue imbalance the same way Static does in the paper.
    """

    def _weight(self, s):
        return s.power0


class PowerPropPlacement(_WeightedCredit):
    """Weighted by the ONLINE EWMA powers: adapts to measured capacity
    (a straggling replica's share decays), but stays queue-blind."""

    def _weight(self, s):
        return s.power


class LeastResidualPlacement(PlacementPolicy):
    """Weighted join-shortest-queue: smallest predicted queue delay wins
    (EWMA outstanding work over EWMA power; ties break to the faster
    replica, then the lowest index for determinism)."""

    def place(self, req, now, states):
        ready = self._ready(now, states)
        return min(ready, key=lambda i: (states[i].queue_delay(now),
                                         -states[i].power, i))


class DeadlinePlacement(PlacementPolicy):
    """EDF-aware earliest-finish placement with router-level shedding.

    Each candidate's completion is predicted from its EWMA residual and
    power; the request goes to the soonest predicted finisher.  If even
    that finisher would miss the deadline (by more than ``slack_margin``
    seconds of grace), the request is shed AT THE ROUTER: admitting it
    anywhere would burn fleet capacity on a doomed request and drag the
    feasible work queued behind it past its deadlines too — the paper's
    time-constrained argument, applied to placement.
    """

    def __init__(self, shed: bool = True, slack_margin: float = 0.0):
        self.shed = shed
        self.slack_margin = slack_margin

    def place(self, req, now, states):
        ready = self._ready(now, states)
        size = float(getattr(req, "size", 1))
        pick = min(ready, key=lambda i: (states[i].pred_finish(now, size),
                                         -states[i].power, i))
        if (self.shed and states[pick].pred_finish(now, size)
                > req.deadline + self.slack_margin):
            states[pick].shed_for += 1
            return None
        return pick


class EnergyPlacement(DeadlinePlacement):
    """Joule-aware deadline placement: cheapest feasible replica wins.

    The candidate set is restricted to replicas predicted to make the
    request's deadline (plus ``slack_margin`` grace); among those the
    request goes to the lowest predicted J/request (measured ``j_wg``
    EWMA × size), ties to the earliest finisher.  Cold start is a
    deterministic one-shot probe: a feasible replica with no energy
    feedback AND no traffic yet gets the request, so every replica's
    J/wg is measured before steady-state routing settles — without the
    probe an idle efficient replica would never be discovered.  When NO
    replica is feasible, behavior degrades to :class:`DeadlinePlacement`
    exactly: shed at the router (``shed=True``) or place on the earliest
    predicted finisher.  With joule-blind fleets every ``j_wg`` stays 0
    and — after each replica's single probe placement, which
    finish-order ties to the deadline pick anyway — the policy matches
    ``deadline``.
    """

    def place(self, req, now, states):
        ready = self._ready(now, states)
        size = float(getattr(req, "size", 1))
        feasible = [i for i in ready
                    if states[i].pred_finish(now, size)
                    <= req.deadline + self.slack_margin]
        if not feasible:
            return super().place(req, now, states)
        unprobed = [i for i in feasible
                    if states[i].j_wg <= 0 and states[i].placed == 0]
        if unprobed:
            return min(unprobed,
                       key=lambda i: (states[i].pred_finish(now, size), i))
        measured = [i for i in feasible if states[i].j_wg > 0]
        if measured:
            return min(measured,
                       key=lambda i: (states[i].pred_joules(size),
                                      states[i].pred_finish(now, size), i))
        return min(feasible, key=lambda i: (states[i].pred_finish(now, size),
                                            -states[i].power, i))


# -- registry (mirrors core/scheduler.py's scheduler registry) ---------------

@dataclass
class PlacementSpec:
    cls: type
    defaults: Dict[str, object] = field(default_factory=dict)


_REGISTRY: Dict[str, PlacementSpec] = {}

# Back-compat-style view: name -> zero-config constructor, kept in
# lockstep with _REGISTRY exactly like core.scheduler.SCHEDULERS.
PLACEMENTS: Dict[str, Callable[..., PlacementPolicy]] = {}


def register_placement(name: str, cls: type, *,
                       defaults: Optional[Mapping[str, object]] = None,
                       overwrite: bool = False) -> type:
    """Register a placement policy under ``name`` (the fleet's Tier-3
    plugin hook — same contract shape as ``register_scheduler``)."""
    if not (isinstance(cls, type) and issubclass(cls, PlacementPolicy)):
        raise TypeError(f"placement {name!r} must be a PlacementPolicy "
                        f"subclass, got {cls!r}")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"placement {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    spec = PlacementSpec(cls, dict(defaults or {}))
    _REGISTRY[name] = spec
    PLACEMENTS[name] = cls if not spec.defaults else \
        functools.partial(cls, **spec.defaults)
    return cls


def unregister_placement(name: str) -> None:
    _REGISTRY.pop(name, None)
    PLACEMENTS.pop(name, None)


def available_placements() -> List[str]:
    return sorted(_REGISTRY)


def placement_spec(name: str) -> PlacementSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown placement {name!r}; registered: "
                       f"{available_placements()}") from None


def placement_accepts(name: str, param: str) -> bool:
    """True if ``name``'s constructor takes ``param`` (capability probe,
    mirroring ``scheduler_accepts``)."""
    for klass in placement_spec(name).cls.__mro__:
        init = klass.__dict__.get("__init__")
        if init is None:
            continue
        params = inspect.signature(init).parameters
        if param in params:
            return True
        if not any(p.kind is inspect.Parameter.VAR_KEYWORD
                   for p in params.values()):
            return False
    return False


def make_placement(name: str, **kw) -> PlacementPolicy:
    spec = placement_spec(name)
    merged = {**spec.defaults, **kw}
    return spec.cls(**merged)


register_placement("round_robin", RoundRobinPlacement)
register_placement("static", StaticPlacement)
register_placement("power_prop", PowerPropPlacement)
register_placement("least_residual", LeastResidualPlacement)
register_placement("deadline", DeadlinePlacement)
register_placement("energy", EnergyPlacement)
