"""FleetRouter: deadline-aware placement over N replica sessions.

One rung up from ``CoexecServer``: where the server schedules *packets*
across devices inside one session, the router places *requests* across
whole replica sessions — and the paper's argument recurs at this level
too.  Placement and admission decisions amortize across many replicas
only if the management layer stays cheap and adapts online; a static
assignment pays for profile bias and stragglers with tail latency exactly
like a Static scheduler chunk split.

The router is execution-agnostic: it owns the per-replica book
(``ReplicaState``), the placement policy (registered like a scheduler),
the shared EDF admission (serve/admission.py — shedding is decided HERE,
not at the replica) and the optional elastic autoscaler.  Drivers feed it
arrivals and measurements:

* the discrete-event fleet simulator (``fleet/sim.py``) drives it against
  ``simulate_serving``-modeled replicas at 1000-replica scale;
* the threaded fleet server (``fleet/worker.py``) drives it against real
  ``EngineSession``-backed replica workers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.fleet.autoscale import ElasticAutoscaler, ScaleEvent
from repro.fleet.placement import (PlacementPolicy, ReplicaState,
                                   make_placement)
from repro.serve.admission import AdmissionConfig, EdfAdmission


@dataclass
class RouterConfig:
    placement: str = "deadline"
    placement_kwargs: Dict = field(default_factory=dict)
    # admission policy at the router ("shed" | "none"): EDF order +
    # quantum + fleet-infeasibility shedding BEFORE placement.  Per-replica
    # infeasibility shedding is the deadline placement policy's call.
    admit: str = "shed"
    admit_quantum_s: float = math.inf
    # EWMA smoothing for measured replica feedback (power and residual);
    # same role as ServerConfig.ewma one rung down
    ewma: float = 0.5


@dataclass
class Placed:
    """One routing decision: where a request went (or why it didn't)."""
    request: object
    replica: Optional[int]               # index into router.states; None=shed
    pred_finish: Optional[float] = None  # router's prediction at placement


class FleetRouter:
    """Deadline-aware request placement over an elastic replica fleet."""

    def __init__(self, replicas: Sequence[Tuple[str, float]],
                 cfg: Optional[RouterConfig] = None, *,
                 autoscaler: Optional[ElasticAutoscaler] = None,
                 standby: Sequence[str] = (),
                 on_scale: Optional[Callable[[ScaleEvent], None]] = None):
        """``replicas``: (name, declared_power_wg_s) pairs.  Names listed
        in ``standby`` start inactive — autoscaler spares that join on a
        sustained queue-delay breach.  ``on_scale`` is the resource hook:
        the threaded server mirrors events onto worker sessions with the
        ``add_device``/``remove_device`` membership hooks."""
        self.cfg = cfg or RouterConfig()
        if self.cfg.admit not in ("shed", "none"):
            raise ValueError(f"router admit must be 'shed' or 'none', "
                             f"got {self.cfg.admit!r}")
        names = [n for n, _ in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        unknown = set(standby) - set(names)
        if unknown:
            raise ValueError(f"standby names not in fleet: {sorted(unknown)}")
        self.states: List[ReplicaState] = [
            ReplicaState(name=n, power0=p, active=n not in standby)
            for n, p in replicas]
        self.placement: PlacementPolicy = make_placement(
            self.cfg.placement, **self.cfg.placement_kwargs)
        self.admission = EdfAdmission(AdmissionConfig(
            policy=self.cfg.admit, round_quantum_s=self.cfg.admit_quantum_s,
            unit_work=False))
        self.autoscaler = autoscaler
        self.on_scale = on_scale
        self.shed: List = []               # requests shed at the router
        self.predicted: Dict[int, float] = {}   # rid -> predicted finish
        self.scale_events: List[ScaleEvent] = []

    # -- bookkeeping ---------------------------------------------------------
    def index_of(self, name: str) -> int:
        for i, s in enumerate(self.states):
            if s.name == name:
                return i
        raise KeyError(name)

    def ready_indices(self, now: float) -> List[int]:
        return [i for i, s in enumerate(self.states) if s.ready(now)]

    def fleet_power(self, now: float) -> float:
        return sum(self.states[i].power for i in self.ready_indices(now))

    def fleet_residual(self, now: float) -> float:
        return sum(self.states[i].resid for i in self.ready_indices(now))

    def queue_delay(self, now: float) -> float:
        return self.fleet_residual(now) / max(self.fleet_power(now), 1e-12)

    # -- the routing step ----------------------------------------------------
    def route(self, pending: List, now: float
              ) -> Tuple[List[Placed], List]:
        """Admit + place every routable request in ``pending``.

        Returns ``(placements, leftover)``: one :class:`Placed` per
        admitted request (``replica=None`` means shed — either the shared
        EDF admission predicted fleet-wide infeasibility, or the deadline
        placement found no replica that makes the deadline), and the
        leftover beyond the admission quantum, which stays queued for the
        caller's next poll.  Residuals drain to ``now`` first; the
        autoscaler (if any) steps on the fresh signal before placement.
        """
        for s in self.states:
            s.drain(now)
        if self.autoscaler is not None:
            ev = self.autoscaler.step(now, self.states)
            if ev is not None:
                self.scale_events.append(ev)
                if self.on_scale is not None:
                    self.on_scale(ev)
        shed_mark = len(self.shed)
        admitted, leftover = self.admission.admit(
            pending, now,
            total_power=self.fleet_power(now),
            residual_wg=self.fleet_residual(now),
            calibrated=True,
            completed=self.shed)
        out: List[Placed] = []
        for r in self.shed[shed_mark:]:    # admission-shed (fleet-infeasible)
            out.append(Placed(request=r, replica=None))
        for r in admitted:
            idx = self.placement.place(r, now, self.states)
            if idx is None:        # placement-shed (no feasible replica)
                r.shed = True
                self.shed.append(r)
                out.append(Placed(request=r, replica=None))
                continue
            s = self.states[idx]
            pred = s.pred_finish(now, float(r.size))
            s.resid += float(r.size)
            s.placed += 1
            self.predicted[r.rid] = pred
            out.append(Placed(request=r, replica=idx, pred_finish=pred))
        return out, leftover

    # -- measurement feedback ------------------------------------------------
    def feedback(self, idx: int, now: float, *,
                 measured_power: Optional[float] = None,
                 measured_resid: Optional[float] = None,
                 measured_j_wg: Optional[float] = None) -> None:
        """Blend a replica's measured capacity / outstanding work /
        energy cost into the router's EWMA book (the driver calls this
        per round or epoch).  ``measured_j_wg`` is the replica's joules
        per work-group — the ``energy`` placement's routing signal."""
        a = self.cfg.ewma
        s = self.states[idx]
        s.drain(now)
        if measured_power is not None and measured_power > 0:
            s.power = a * measured_power + (1 - a) * s.power
        if measured_resid is not None:
            s.resid = a * max(measured_resid, 0.0) + (1 - a) * s.resid
        if measured_j_wg is not None and measured_j_wg > 0:
            s.j_wg = measured_j_wg if s.j_wg <= 0 else \
                a * measured_j_wg + (1 - a) * s.j_wg

    def summary(self) -> dict:
        d = {
            "placement": self.cfg.placement,
            "placed": {s.name: s.placed for s in self.states},
            "shed_at_router": len(self.shed),
            "scale": (self.autoscaler.summary()
                      if self.autoscaler is not None else None),
        }
        return d

    def __repr__(self) -> str:
        active = sum(1 for s in self.states if s.active)
        return (f"FleetRouter({self.cfg.placement!r}, "
                f"{active}/{len(self.states)} replicas active, "
                f"shed={len(self.shed)})")
