"""The tiered co-execution API (EngineCL's usability thesis, in JAX).

Three tiers, increasing control:

  * **Tier-1** ``coexec(program, devices=...)`` — single call, paper-tuned
    defaults (HGuidedOpt, parallel init, registered buffers); accepts a
    ``region=`` sub-NDRange.
  * **Tier-2** ``EngineSession`` — executable cache, buffer registry and
    elastic device membership shared across many programs;
    ``session.submit(program) -> RunHandle`` (``.result()``, ``.done()``,
    ``.cancel()``) overlaps input prep with in-flight runs;
    ``submit(..., deps=[h1, h2])`` builds a dependency DAG dispatched
    ready-set style (each node starts the moment its actual predecessors
    finish; cancelled predecessors cascade, failed ones raise
    ``DependencyError``), and ``submit(..., journal=RunJournal(path))``
    journals packet commits so ``resume_run`` restarts a killed graph
    executing only never-committed packets;
    ``register_workload`` + ``submit(..., region=..., mode=OffloadMode.
    ROI)`` is the paper's ROI offloading, ``mode=OffloadMode.BINARY`` its
    self-contained binary offloading.
  * **Tier-3** extension points — ``register_scheduler`` (plugin registry),
    ``DevicePolicy`` (discovery/ordering), ``BufferPolicy`` (Runtime
    buffer handling).

Work geometry is first-class: ``Region``/``Dim`` describe 1-D and 2-D
NDRanges with per-dimension offset/size/lws; every scheduler carves them
(2-D as row panels) and every ``RunResult`` carries a per-phase
``PhaseBreakdown`` (init / h2d / roi / d2h / teardown).  The memory
subsystem (``repro.core.membuf``) backs ``BufferPolicy.POOLED`` — the
default for warm ROI submits: run buffers lease from the session's
``BufferArena`` and staging overlaps compute on the ``TransferPipeline``
(pooled outputs are recycled views; copy what you keep).

See docs/api.md for the tier table and the offload-modes guide.
"""
from repro.api.handles import CancelledError, DependencyError, RunHandle
from repro.api.policies import (BufferPolicy, DevicePolicy, OffloadMode,
                                StaticDevicePolicy)
from repro.api.session import EngineSession
from repro.api.tier1 import coexec
from repro.ckpt.checkpoint import ResumeReport, RunJournal, resume_run
from repro.core.membuf import (ArenaPartition, ArenaStats, BufferArena,
                               TransferPipeline)
from repro.core.metrics import PhaseBreakdown
from repro.core.region import Dim, Region
from repro.core.runtime import Program
from repro.core.scheduler import (GraphProgress, available_schedulers,
                                  register_scheduler, scheduler_accepts,
                                  unregister_scheduler)
from repro.tenancy import (FleetArbiter, PacketWindow, TenantConfig,
                           exclusive_overlaps, fair_share_index)

__all__ = [
    "ArenaPartition", "ArenaStats", "BufferArena", "BufferPolicy",
    "CancelledError", "DependencyError", "DevicePolicy", "Dim",
    "EngineSession", "FleetArbiter", "GraphProgress", "OffloadMode",
    "PacketWindow", "PhaseBreakdown", "Program", "Region", "ResumeReport",
    "RunHandle", "RunJournal", "StaticDevicePolicy", "TenantConfig",
    "TransferPipeline", "available_schedulers", "coexec",
    "exclusive_overlaps", "fair_share_index", "register_scheduler",
    "resume_run", "scheduler_accepts", "unregister_scheduler",
]
