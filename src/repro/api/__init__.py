"""The tiered co-execution API (EngineCL's usability thesis, in JAX).

Three tiers, increasing control:

  * **Tier-1** ``coexec(program, devices=...)`` — single call, paper-tuned
    defaults (HGuidedOpt, parallel init, registered buffers).
  * **Tier-2** ``EngineSession`` — executable cache, buffer registry and
    elastic device membership shared across many programs;
    ``session.submit(program) -> RunHandle`` (``.result()``, ``.done()``,
    ``.cancel()``) overlaps input prep with in-flight runs.
  * **Tier-3** extension points — ``register_scheduler`` (plugin registry),
    ``DevicePolicy`` (discovery/ordering), ``BufferPolicy`` (Runtime
    buffer handling).

See docs/api.md for the tier table and the ``Engine`` migration guide.
"""
from repro.api.handles import CancelledError, RunHandle
from repro.api.policies import BufferPolicy, DevicePolicy, StaticDevicePolicy
from repro.api.session import EngineSession
from repro.api.tier1 import coexec
from repro.core.runtime import Program
from repro.core.scheduler import (available_schedulers, register_scheduler,
                                  scheduler_accepts, unregister_scheduler)

__all__ = [
    "BufferPolicy", "CancelledError", "DevicePolicy", "EngineSession",
    "Program", "RunHandle", "StaticDevicePolicy", "available_schedulers",
    "coexec", "register_scheduler", "scheduler_accepts",
    "unregister_scheduler",
]
