"""Tier-3 extension points and submit policies: offload mode, buffer and
device policies.

Schedulers have their own Tier-3 hook — ``repro.core.scheduler.
register_scheduler`` — so all three of the paper's architectural roles
(Runtime buffers, device discovery, load balancing) are extensible without
touching the session.
"""
from __future__ import annotations

import enum
from typing import List, Sequence

from repro.core.device import DeviceGroup
from repro.core.membuf import BufferPolicy

__all__ = ["BufferPolicy", "DevicePolicy", "OffloadMode",
           "StaticDevicePolicy"]


class OffloadMode(enum.Enum):
    """How a submit pays the paper's management overheads.

    * ``BINARY`` — the paper's binary offloading: the submit is fully
      self-contained, init -> offload -> teardown.  Executables are built
      fresh (never taken from the session cache) and any cached state under
      the program's name is evicted afterwards; the phase breakdown charges
      the full init and teardown to THIS run.  This is the per-run cost a
      one-shot offload actually pays.
    * ``ROI`` — the paper's region-of-interest offloading: the program
      must first be registered as a persistent workload
      (``EngineSession.register_workload``), which pays init once; each
      ROI submit then executes a sub-region (``region=``) against the
      registered executables and buffers, so back-to-back submits pay only
      the ROI window.  This is where the paper's optimizations yield
      17.4% instead of 7.5%.

    ``None`` (the default at ``submit``) keeps the session's legacy
    semantics: executables cached per session policy, no forced teardown.
    """
    BINARY = "binary"
    ROI = "roi"


# BufferPolicy lives in repro.core.membuf (the memory subsystem owns the
# Runtime's buffer-handling contracts: PER_PACKET / REGISTERED / POOLED);
# it is re-exported here because it is a Tier-3 policy surface.


class DevicePolicy:
    """Device discovery + ordering hook.

    The default discovers one DeviceGroup per visible JAX device and keeps
    the backend's order.  Subclass to pin custom fleets (throttled groups,
    mesh sub-slices, remote executors) or to reorder (e.g. weakest-first so
    Static delivery matches the paper's CPU,iGPU,GPU layout).
    """

    def discover(self) -> List[DeviceGroup]:
        import jax
        return [DeviceGroup(f"{d.platform}{i}", device=d)
                for i, d in enumerate(jax.devices())]

    def order(self, devices: Sequence[DeviceGroup]) -> List[DeviceGroup]:
        return list(devices)

    def resolve(self, devices=None) -> List[DeviceGroup]:
        """Explicit devices win; otherwise discover.  Always ordered."""
        devs = list(devices) if devices is not None else self.discover()
        devs = self.order(devs)
        if not devs:
            raise ValueError("DevicePolicy produced no devices")
        names = [d.name for d in devs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate device names: {names}")
        return devs


class StaticDevicePolicy(DevicePolicy):
    """A fixed, pre-built fleet (the common case in tests/benchmarks)."""

    def __init__(self, devices: Sequence[DeviceGroup]):
        self._devices = list(devices)

    def discover(self) -> List[DeviceGroup]:
        return list(self._devices)
