"""RunHandle: the future-like handle returned by ``EngineSession.submit``.

Deliberately a subset of ``concurrent.futures.Future`` (result / done /
cancel / exception) so callers can overlap input preparation with in-flight
runs — exactly as the paper's init optimization overlaps compiles — without
learning a new waiting idiom.  ``CancelledError`` is the standard library's.

A handle may also be a **predecessor** of later submits
(``EngineSession.submit(program, deps=[handle])``): the session dispatches
the dependent the moment every predecessor finishes.  Dependency outcomes
surface here too — a cancelled predecessor cascades (dependents transition
to the CANCELLED terminal state), and a failed predecessor fails its
dependents with :class:`DependencyError` on ``result()``.
"""
from __future__ import annotations

import threading
from concurrent.futures import CancelledError
from typing import Any, Callable, List, Optional

__all__ = ["CancelledError", "DependencyError", "RunHandle"]


class DependencyError(RuntimeError):
    """A run could not start because a predecessor failed.

    Raised from ``RunHandle.result()`` / stored as its ``exception()`` on
    every (transitive) dependent of a failed submit.  ``cause`` is the
    predecessor's own exception (also chained via ``__cause__``)."""

    def __init__(self, program_name: str, dep_name: str,
                 cause: Optional[BaseException] = None):
        super().__init__(
            f"run of {program_name!r} not started: predecessor "
            f"{dep_name!r} failed ({cause!r})")
        self.program_name = program_name
        self.dep_name = dep_name
        self.cause = cause

_PENDING = "pending"
_RUNNING = "running"
_DONE = "done"
_CANCELLED = "cancelled"


class RunHandle:
    """Handle for one submitted program; created only by EngineSession."""

    def __init__(self, program_name: str, seq: int,
                 discard: Optional[Callable[[], None]] = None,
                 deps: Optional[List["RunHandle"]] = None):
        self.program_name = program_name
        self.seq = seq                       # session-wide submit index
        self.deps: List["RunHandle"] = list(deps or [])  # predecessors
        self._lock = threading.Lock()
        self._event = threading.Event()
        self._state = _PENDING
        self._result: Any = None
        self._exception: Optional[BaseException] = None
        self._discard = discard              # session queue-removal hook
        self._callbacks: List[Callable[["RunHandle"], None]] = []

    # -- caller side --------------------------------------------------------
    def done(self) -> bool:
        """True once the run finished, errored, or was cancelled."""
        return self._event.is_set()

    def running(self) -> bool:
        return self._state == _RUNNING

    def cancelled(self) -> bool:
        return self._state == _CANCELLED

    def succeeded(self) -> bool:
        """True once the run finished and produced a RunResult."""
        return (self._event.is_set() and self._state == _DONE
                and self._exception is None)

    def failed(self) -> bool:
        """True once the run finished with an exception."""
        return self._event.is_set() and self._exception is not None

    def cancel(self) -> bool:
        """Cancel if still queued.  Returns False once dispatch started —
        in-flight co-execution is not interrupted (packets already carved
        must commit exactly once).  A successful cancel removes the
        submission from the session queue immediately: ``done()`` flips
        right away and the dispatcher never sees (nor pays init for) it."""
        with self._lock:
            if self._state != _PENDING:
                return False
            self._state = _CANCELLED
        self._event.set()
        if self._discard is not None:
            # outside self._lock: the hook takes the session queue lock and
            # the dispatcher takes these locks in the opposite order
            self._discard()
        self._run_callbacks()
        return True

    def result(self, timeout: Optional[float] = None):
        """Block until the RunResult is ready; re-raises run errors."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"run of {self.program_name!r} not done after {timeout}s")
        if self._state == _CANCELLED:
            raise CancelledError(f"run of {self.program_name!r} cancelled")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"run of {self.program_name!r} not done after {timeout}s")
        if self._state == _CANCELLED:
            raise CancelledError(f"run of {self.program_name!r} cancelled")
        return self._exception

    def add_done_callback(self, fn: Callable[["RunHandle"], None]) -> None:
        """Call ``fn(handle)`` once the handle reaches a terminal state
        (done, errored, or cancelled).  If it already has, ``fn`` runs
        immediately on the calling thread; otherwise on whichever thread
        completes the handle.  Callback exceptions are swallowed — a
        misbehaving observer must not corrupt the dispatcher."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        try:
            fn(self)
        except Exception:
            pass

    def _run_callbacks(self) -> None:
        with self._lock:
            cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            try:
                fn(self)
            except Exception:
                pass

    # -- session side -------------------------------------------------------
    def _start(self) -> bool:
        """Dispatcher claims the handle; False if it was cancelled first."""
        with self._lock:
            if self._state != _PENDING:
                return False
            self._state = _RUNNING
            return True

    def _set_result(self, result) -> None:
        with self._lock:
            if self._state in (_DONE, _CANCELLED):
                return  # terminal states are final (cancel/settle race)
            self._result = result
            self._state = _DONE
        self._event.set()
        self._run_callbacks()

    def _set_exception(self, exc: BaseException) -> None:
        with self._lock:
            if self._state in (_DONE, _CANCELLED):
                return  # terminal states are final (cancel/settle race)
            self._exception = exc
            self._state = _DONE
        self._event.set()
        self._run_callbacks()

    def _cascade_cancel(self) -> bool:
        """Session-side cascade: a cancelled predecessor cancels this
        still-pending dependent.  Unlike ``cancel()`` this may also claim
        a handle the dispatcher has not started (the dispatcher itself
        performs the cascade, so there is no race with ``_start``)."""
        with self._lock:
            if self._state != _PENDING:
                return False
            self._state = _CANCELLED
        self._event.set()
        self._run_callbacks()
        return True

    def __repr__(self) -> str:
        return (f"RunHandle({self.program_name!r}, seq={self.seq}, "
                f"state={self._state})")
