"""RunHandle: the future-like handle returned by ``EngineSession.submit``.

Deliberately a subset of ``concurrent.futures.Future`` (result / done /
cancel / exception) so callers can overlap input preparation with in-flight
runs — exactly as the paper's init optimization overlaps compiles — without
learning a new waiting idiom.  ``CancelledError`` is the standard library's.
"""
from __future__ import annotations

import threading
from concurrent.futures import CancelledError
from typing import Any, Callable, Optional

__all__ = ["CancelledError", "RunHandle"]

_PENDING = "pending"
_RUNNING = "running"
_DONE = "done"
_CANCELLED = "cancelled"


class RunHandle:
    """Handle for one submitted program; created only by EngineSession."""

    def __init__(self, program_name: str, seq: int,
                 discard: Optional[Callable[[], None]] = None):
        self.program_name = program_name
        self.seq = seq                       # session-wide submit index
        self._lock = threading.Lock()
        self._event = threading.Event()
        self._state = _PENDING
        self._result: Any = None
        self._exception: Optional[BaseException] = None
        self._discard = discard              # session queue-removal hook

    # -- caller side --------------------------------------------------------
    def done(self) -> bool:
        """True once the run finished, errored, or was cancelled."""
        return self._event.is_set()

    def running(self) -> bool:
        return self._state == _RUNNING

    def cancelled(self) -> bool:
        return self._state == _CANCELLED

    def cancel(self) -> bool:
        """Cancel if still queued.  Returns False once dispatch started —
        in-flight co-execution is not interrupted (packets already carved
        must commit exactly once).  A successful cancel removes the
        submission from the session queue immediately: ``done()`` flips
        right away and the dispatcher never sees (nor pays init for) it."""
        with self._lock:
            if self._state != _PENDING:
                return False
            self._state = _CANCELLED
        self._event.set()
        if self._discard is not None:
            # outside self._lock: the hook takes the session queue lock and
            # the dispatcher takes these locks in the opposite order
            self._discard()
        return True

    def result(self, timeout: Optional[float] = None):
        """Block until the RunResult is ready; re-raises run errors."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"run of {self.program_name!r} not done after {timeout}s")
        if self._state == _CANCELLED:
            raise CancelledError(f"run of {self.program_name!r} cancelled")
        if self._exception is not None:
            raise self._exception
        return self._result

    def exception(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"run of {self.program_name!r} not done after {timeout}s")
        if self._state == _CANCELLED:
            raise CancelledError(f"run of {self.program_name!r} cancelled")
        return self._exception

    # -- session side -------------------------------------------------------
    def _start(self) -> bool:
        """Dispatcher claims the handle; False if it was cancelled first."""
        with self._lock:
            if self._state != _PENDING:
                return False
            self._state = _RUNNING
            return True

    def _set_result(self, result) -> None:
        with self._lock:
            self._result = result
            self._state = _DONE
        self._event.set()

    def _set_exception(self, exc: BaseException) -> None:
        with self._lock:
            self._exception = exc
            self._state = _DONE
        self._event.set()

    def __repr__(self) -> str:
        return (f"RunHandle({self.program_name!r}, seq={self.seq}, "
                f"state={self._state})")
