"""Tier-1: ``coexec`` — one call, paper-tuned defaults.

Hides scheduler and optimization choices behind the configuration the
paper found best: HGuidedOpt balancing, parallel init with executable
caching, registered buffers.  For reuse across runs (where the paper's
optimizations actually pay off), hold an ``EngineSession`` instead.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.device import DeviceGroup
from repro.core.metrics import RunResult
from repro.core.region import Region
from repro.core.runtime import Program
from repro.api.policies import BufferPolicy, DevicePolicy
from repro.api.session import EngineSession


def coexec(program: Program,
           devices: Optional[Sequence[DeviceGroup]] = None, *,
           scheduler: Optional[str] = None,
           scheduler_kwargs: Optional[Dict] = None,
           powers: Optional[List[float]] = None,
           buffer_policy: BufferPolicy = BufferPolicy.REGISTERED,
           device_policy: Optional[DevicePolicy] = None,
           parallel_init: bool = True,
           init_cost_s: float = 0.0,
           region: Optional[Region] = None,
           dispatch: str = "leased",
           tuned=None) -> RunResult:
    """Co-execute ``program`` across ``devices`` and return its RunResult.

    ``devices=None`` discovers the fleet via ``device_policy`` (default:
    one group per visible JAX device).  The result's ``output`` attribute
    holds the assembled array, bit-identical to a single-device run.
    ``region`` restricts the one-shot run to a sub-NDRange of the program
    (lws-aligned per dimension); for *repeated* ROI offloads hold an
    ``EngineSession`` and use ``register_workload`` + ROI-mode submits.
    ``dispatch`` selects the scheduler hand-off: ``"leased"`` (default,
    lock-amortized packet plans) or ``"per_packet"`` (the classic
    one-lock-per-packet baseline).  ``tuned`` accepts a
    ``repro.tune.TunedConfig`` (or ``True`` for a calibration-cache
    lookup): autotuned scheduler choice, lease constants, and transfer
    crossover become the run's defaults; explicit kwargs still win.
    """
    with EngineSession(devices,
                       scheduler=scheduler,
                       scheduler_kwargs=scheduler_kwargs,
                       buffer_policy=buffer_policy,
                       device_policy=device_policy,
                       parallel_init=parallel_init,
                       init_cost_s=init_cost_s,
                       dispatch=dispatch,
                       tuned=tuned,
                       name=f"coexec[{program.name}]") as session:
        return session.submit(program, powers=powers,
                              region=region).result()
