"""Tier-2: EngineSession — one session, many programs, reused primitives.

The paper's optimizations only pay off when costly primitives (compiled
executables, registered buffers) are *reused across runs*.  The session is
where that reuse lives:

  * an **executable cache** keyed by (program, device) — back-to-back
    submits of the same program pay ``init_cost_s`` at most once per device
    per session, not once per run;
  * a **buffer registry** recording which (program, device) pairs have
    registered input buffers (``BufferPolicy.REGISTERED`` commits outputs
    in place against them);
  * **elastic device membership** across runs (``add_device`` /
    ``remove_device`` renormalize scheduler powers on the next submit);
  * a **WorkerPool** of device threads reused run-to-run;
  * an async **submit graph**: ``submit(program) -> RunHandle`` returns
    immediately, so callers overlap input preparation with in-flight runs
    exactly as the init optimization overlaps compiles.  A submit may name
    predecessor handles (``deps=[h1, h2]``): the session maintains the
    dependency DAG and its **ready-set dispatcher** starts each dependent
    the moment its actual predecessors finish — true DAG dispatch, not
    level-by-level barriers.  Independent submits keep strict FIFO order
    at the default ``max_inflight=1`` (one co-execution owns the fleet at
    a time — the paper's co-execution model); raising ``max_inflight``
    lets several ready runs co-execute over the shared fleet, which is
    what lets a multi-stage pipeline fill one stage's drain tail with the
    next stage's packets.  Predecessor results flow into dependents via
    the ``feed`` hook (called with the deps' RunResults just before
    dispatch), so pooled predecessor outputs are consumed in place —
    inter-stage data never round-trips through fresh staging.  A
    cancelled predecessor cascades (dependents transition to CANCELLED);
    a failed predecessor fails dependents with ``DependencyError``.
  * a **workload registry** for the paper's ROI offloading:
    ``register_workload(program)`` pays init once (executables built,
    buffers registered on every device); subsequent
    ``submit(program, region=..., mode=OffloadMode.ROI)`` calls execute
    sub-regions warm.  ``mode=OffloadMode.BINARY`` is the opposite
    contract: fully self-contained init -> offload -> teardown per submit.

Blocking callers use ``session.run(program)`` or Tier-1
``coexec(program, devices=...)``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.device import DeviceGroup
from repro.core.membuf import ArenaStats, BufferArena
from repro.core.metrics import RunResult
from repro.core.region import Region
from repro.core.runtime import Program, WorkerPool, _RunContext
from repro.core.scheduler import GraphProgress, scheduler_spec
from repro.tenancy.arbiter import FleetArbiter, TenantConfig
from repro.api.handles import DependencyError, RunHandle
from repro.api.policies import BufferPolicy, DevicePolicy, OffloadMode


@dataclass(eq=False)          # identity semantics: queue removal on cancel
class _Submission:
    """Everything one queued run needs, captured at submit time."""
    program: Program
    powers: Optional[List[float]]
    scheduler: str
    scheduler_kwargs: Dict
    cache: bool
    collect: Optional[Callable]
    region: Optional[Region] = None
    mode: Optional[OffloadMode] = None
    buffer_policy: Optional[BufferPolicy] = None
    dispatch: Optional[str] = None
    deps: List[RunHandle] = field(default_factory=list)
    feed: Optional[Callable] = None      # feed(dep_results) before dispatch
    journal: Optional[object] = None     # RunJournal for packet commits
    journal_key: Optional[str] = None
    handle: RunHandle = field(default=None)  # type: ignore[assignment]


class EngineSession:
    """A long-lived co-execution session over an elastic device fleet."""

    def __init__(self, devices: Optional[Sequence[DeviceGroup]] = None, *,
                 scheduler: Optional[str] = None,
                 scheduler_kwargs: Optional[Dict] = None,
                 buffer_policy: BufferPolicy = BufferPolicy.REGISTERED,
                 device_policy: Optional[DevicePolicy] = None,
                 parallel_init: bool = True,
                 cache_executables: bool = True,
                 init_cost_s: float = 0.0,
                 reset_device_stats: bool = True,
                 arena_capacity_bytes: int = 256 << 20,
                 arena_ring: int = 2,
                 dispatch: str = "leased",
                 max_inflight: int = 1,
                 arbiter: Optional[FleetArbiter] = None,
                 tenant: Optional[TenantConfig] = None,
                 lease_overhead_s: Optional[float] = None,
                 lease_overhead_frac: Optional[float] = None,
                 lease_k_max: Optional[int] = None,
                 async_threshold_bytes: Optional[int] = None,
                 tuned=None,
                 name: str = "session"):
        if dispatch not in ("leased", "per_packet"):
            raise ValueError(f"dispatch must be 'leased' or 'per_packet', "
                             f"got {dispatch!r}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, "
                             f"got {max_inflight}")
        if tenant is not None and arbiter is None:
            raise ValueError("tenant= requires arbiter= (a TenantConfig "
                             "only means something on a shared fleet)")
        # how many READY submits may co-execute at once.  1 (default)
        # preserves strict FIFO: one run owns the fleet at a time.  >1 is
        # the DAG-pipelining mode: a dependent whose predecessors are done
        # co-executes with unrelated runs over the shared fleet.
        self.max_inflight = max_inflight
        self.dispatch = dispatch
        self.device_policy = device_policy or DevicePolicy()
        if devices is None and arbiter is not None:
            # tenant sessions default to the arbiter's fleet
            self._devices: List[DeviceGroup] = list(arbiter.devices)
        else:
            self._devices = self.device_policy.resolve(devices)
        # calibrated-constants path: a TunedConfig (passed directly, as a
        # dict, as a file path, or ``tuned=True`` for a cache lookup by
        # this fleet's fingerprint) supplies DEFAULTS for the scheduler
        # choice, the lease growth law, and the transfer crossover —
        # explicit kwargs always win (repro.tune).
        self.tuned = None
        if tuned is not None and tuned is not False:
            from repro.tune.cache import resolve_tuned
            self.tuned = resolve_tuned(tuned, devices=self._devices)
        if self.tuned is not None:
            t = self.tuned
            if scheduler is None and t.scheduler:
                scheduler = t.scheduler
                if scheduler_kwargs is None and t.scheduler_kwargs:
                    scheduler_kwargs = dict(t.scheduler_kwargs)
            if lease_overhead_s is None:
                lease_overhead_s = t.lease_overhead_s
            if lease_overhead_frac is None:
                lease_overhead_frac = t.lease_overhead_frac
            if lease_k_max is None:
                lease_k_max = t.lease_k_max
            if async_threshold_bytes is None:
                async_threshold_bytes = t.async_threshold_bytes
        scheduler = scheduler or "hguided_opt"
        scheduler_spec(scheduler)            # fail fast on unknown names
        self.scheduler = scheduler
        self.scheduler_kwargs = dict(scheduler_kwargs or {})
        # non-None subset applied onto every run's fresh scheduler instance
        self.lease_params = {k: v for k, v in (
            ("lease_overhead_s", lease_overhead_s),
            ("lease_overhead_frac", lease_overhead_frac),
            ("lease_k_max", lease_k_max)) if v is not None} or None
        self.async_threshold_bytes = async_threshold_bytes
        self.buffer_policy = buffer_policy
        self.parallel_init = parallel_init
        self.cache_executables = cache_executables
        # emulated fixed driver-primitive cost paid per executable build;
        # the cache amortizes it across submits (paper's init optimization)
        self.init_cost_s = init_cost_s
        self.reset_device_stats = reset_device_stats
        self.name = name
        self._graph = GraphProgress()
        # multi-tenant mode: the session registers with the arbiter and
        # shares ITS pool + arena (an ArenaPartition namespaces this
        # tenant's keys); every device pull is arbiter-gated.  Solo mode
        # (arbiter=None) keeps the session-owned fast path unchanged.
        self.arbiter = arbiter
        self._tenant = None
        if arbiter is not None:
            tcfg = tenant if tenant is not None else TenantConfig(name=name)
            self._tenant = arbiter.register(
                tcfg, demand=lambda: self._graph.remaining() > 0)
            self.arena = self._tenant.arena
            self._pool = arbiter.pool
            self._owns_pool = False
        else:
            # the memory subsystem: session-owned buffer arena backing
            # POOLED runs (register_workload/evict manage its entries;
            # close drains it)
            self.arena = BufferArena(capacity_bytes=arena_capacity_bytes,
                                     ring=arena_ring, name=f"{name}-arena")
            self._pool = WorkerPool(name=name)
            self._owns_pool = True

        self._executables: Dict[Tuple[str, str], Callable] = {}
        self._buffer_registry: Dict[Tuple[str, str], int] = {}
        self._workloads: Dict[str, Program] = {}   # ROI-registered programs
        self.init_payments = 0               # executable builds performed
        self._lock = threading.Lock()
        # the pending set IS the dependency graph: submissions hold their
        # predecessor handles, and the ready-set dispatcher scans in submit
        # order (FIFO among simultaneously-ready nodes)
        self._pending: List[_Submission] = []
        self._inflight = 0                   # started, not yet terminal
        self._issued: "weakref.WeakSet[RunHandle]" = weakref.WeakSet()
        self._cv = threading.Condition()
        self._closing = False
        self._submitting = 0                 # submit/register calls in body
        self._seq = 0
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name=f"{name}-dispatch", daemon=True)
        self._dispatcher.start()

    @property
    def tenant(self):
        """The session's TenantHandle on a shared fleet (None when the
        session owns its devices — the solo fast path)."""
        return self._tenant

    # -- elastic membership --------------------------------------------------
    @property
    def devices(self) -> List[DeviceGroup]:
        with self._lock:
            return list(self._devices)

    def add_device(self, dev: DeviceGroup) -> None:
        with self._lock:
            if any(d.name == dev.name for d in self._devices):
                raise ValueError(f"device {dev.name!r} already in session")
            self._devices.append(dev)

    def remove_device(self, name: str) -> None:
        with self._lock:
            self._devices = [d for d in self._devices if d.name != name]
            for key in [k for k in self._executables if k[1] == name]:
                del self._executables[key]
            for key in [k for k in self._buffer_registry if k[1] == name]:
                del self._buffer_registry[key]

    # -- caches --------------------------------------------------------------
    @property
    def executables(self) -> Dict[Tuple[str, str], Callable]:
        """(program_name, device_name) -> compiled range executable."""
        with self._lock:
            return dict(self._executables)

    @property
    def buffer_registry(self) -> Dict[Tuple[str, str], int]:
        """(program_name, device_name) -> number of buffer registrations
        for cached programs (1 everywhere means full reuse)."""
        with self._lock:
            return dict(self._buffer_registry)

    def evict(self, program_name: str) -> None:
        """Drop a program's cached executables/buffers (all devices) and
        its arena entries (pooled run buffers)."""
        with self._lock:
            for key in [k for k in self._executables
                        if k[0] == program_name]:
                del self._executables[key]
            for key in [k for k in self._buffer_registry
                        if k[0] == program_name]:
                del self._buffer_registry[key]
        self.arena.evict(program_name)

    @property
    def arena_stats(self) -> ArenaStats:
        """Counters/gauges of the session's buffer arena."""
        return self.arena.stats

    # -- workload registry (ROI offloading) ----------------------------------
    @property
    def workloads(self) -> Dict[str, Program]:
        """name -> registered persistent workload (ROI-mode targets)."""
        with self._lock:
            return dict(self._workloads)

    def register_workload(self, program: Program, *,
                          build: bool = True) -> Program:
        """Register ``program`` as a persistent workload and pay init NOW.

        Executables are built (and buffers registered) on every current
        device up front, so subsequent ``mode=OffloadMode.ROI`` submits —
        the paper's repeated sub-region offloads — run warm from the first
        one.  ``build=False`` only records the workload (init is then paid
        lazily by the first submit).  Returns the registered program.
        """
        program.validate()
        self._begin_op()
        try:
            return self._register_workload_op(program, build=build)
        finally:
            self._end_op()

    def _register_workload_op(self, program: Program, *,
                              build: bool) -> Program:
        with self._lock:
            devices = list(self._devices)
        if build:
            # parallel init, same as the dispatch path: registration costs
            # one init window, not n_devices serial ones
            errors: List[BaseException] = []

            def compile_one(dev):
                try:
                    self._compile_for(program, dev, cache=True)
                except BaseException as e:
                    errors.append(e)

            threads = [threading.Thread(target=compile_one, args=(d,),
                                        daemon=True) for d in devices]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            if errors:
                raise errors[0]
            # pre-populate the arena's output ring for the full-region
            # shape, so even the FIRST pooled ROI submit of the whole
            # workload hits instead of allocating (sub-region ROIs create
            # their own keys on first submit and are warm from the second)
            region = program.work_region
            out_cols = program.out_cols if region.ndim == 1 \
                else region.dims[1].size * program.out_cols
            out_rows = region.dims[0].size * program.out_rows_per_wg
            self.arena.register(program.name, "host", (out_rows, out_cols),
                                program.out_dtype)
        with self._lock:
            self._workloads[program.name] = program
        return program

    def unregister_workload(self, name: str) -> None:
        """Drop a registered workload and evict its cached state."""
        with self._lock:
            self._workloads.pop(name, None)
        self.evict(name)

    def _compile_for(self, program: Program, dev: DeviceGroup,
                     cache: bool) -> Callable:
        key = (program.name, dev.name)
        if cache:
            with self._lock:
                fn = self._executables.get(key)
            if fn is not None:
                return fn
        if self.init_cost_s:
            time.sleep(self.init_cost_s)      # driver primitive cost
        fn = program.build(dev)
        with self._lock:
            self.init_payments += 1
            if cache and self.cache_executables:
                # ephemeral (cache=False) programs must not grow the
                # registries: a serving session submits one uniquely-named
                # round program per dispatch round
                self._executables[key] = fn
                self._buffer_registry[key] = \
                    self._buffer_registry.get(key, 0) + 1
        return fn

    # -- close/submit serialization ------------------------------------------
    def _begin_op(self) -> None:
        """Open a submit/register critical window.  ``close()`` waits for
        every open window before tearing anything down, so an in-flight
        ``submit()`` either completes (and its submission is drained by
        the closing dispatcher) or never passed this gate — the queue
        discard hook can no longer race a concurrent close."""
        with self._cv:
            if self._closing:
                raise RuntimeError(f"session {self.name!r} is closed")
            self._submitting += 1

    def _end_op(self) -> None:
        with self._cv:
            self._submitting -= 1
            self._cv.notify_all()

    # -- submission ----------------------------------------------------------
    def submit(self, program: Program, *,
               powers: Optional[List[float]] = None,
               scheduler: Optional[str] = None,
               scheduler_kwargs: Optional[Dict] = None,
               collect: Optional[Callable] = None,
               cache: bool = True,
               region: Optional[Region] = None,
               mode: Optional[OffloadMode] = None,
               buffer_policy: Optional[BufferPolicy] = None,
               dispatch: Optional[str] = None,
               deps: Optional[Sequence[RunHandle]] = None,
               feed: Optional[Callable] = None,
               journal=None,
               journal_key: Optional[str] = None) -> RunHandle:
        """Enqueue a program; returns a future-like RunHandle immediately.

        ``powers`` overrides the per-device computing powers for this run;
        ``scheduler``/``scheduler_kwargs`` override the session defaults
        (e.g. a serving round's rotated Static order or deadline slack) —
        overriding the scheduler DROPS the session-level kwargs, which were
        tuned for a different class; ``collect(packet, result, device)``
        replaces array output assembly for reduction-style programs
        (called under the run's commit lock); ``cache=False`` skips the
        executable cache for ephemeral programs.

        ``region`` restricts the run to a sub-region of the program's
        NDRange (must be contained and per-dimension lws-aligned within
        it); the result's ``output`` covers just that sub-region.
        ``mode`` selects the paper's offload contract: ``BINARY`` builds
        fresh and tears down after (self-contained one-shot, full init +
        teardown charged to this run's phase breakdown), ``ROI`` requires
        the program to be ``register_workload``-ed and executes warm
        against the registered executables/buffers.

        ``buffer_policy`` overrides the session's buffer handling for this
        run.  ROI submits default to ``BufferPolicy.POOLED`` (arena-backed
        output + overlapped transfer pipeline — note the pooled
        result-lifetime contract: ``output`` is a recycled view, valid
        until the workload's ring cycles); everything else defaults to the
        session policy.

        ``dispatch`` overrides the session's scheduler hand-off mode for
        this run: ``"leased"`` (default — lease-amortized packet plans
        with the scheduler's adaptive ``lease``/``acquire`` path) or
        ``"per_packet"`` (one lock crossing per packet, the measurable
        baseline).

        ``deps`` lists predecessor RunHandles from THIS session: the run
        stays pending until every predecessor succeeds, then dispatches
        the moment the last one finishes (ready-set DAG dispatch — no
        level barriers).  A cancelled predecessor cascades (this handle
        transitions to CANCELLED); a failed one fails this handle with
        :class:`DependencyError`.  ``feed(dep_results)`` — if given — is
        called on the dispatch thread with the predecessors' RunResults
        (in ``deps`` order) just before init, so the program's closures
        can consume predecessor outputs in place; a ``feed`` that raises
        fails this run (and, transitively, its dependents).

        ``journal`` is a ``repro.ckpt.RunJournal``: every committed packet
        is appended (offset/size in the program's dim-0 frame under
        ``journal_key``, default the program name) so a killed graph can
        be resumed via ``repro.ckpt.resume_run`` executing only
        never-committed packets.
        """
        self._begin_op()
        try:
            return self._submit_locked_out(
                program, powers=powers, scheduler=scheduler,
                scheduler_kwargs=scheduler_kwargs, collect=collect,
                cache=cache, region=region, mode=mode,
                buffer_policy=buffer_policy, dispatch=dispatch,
                deps=deps, feed=feed, journal=journal,
                journal_key=journal_key)
        finally:
            self._end_op()

    def _submit_locked_out(self, program: Program, *,
                           powers, scheduler, scheduler_kwargs, collect,
                           cache, region, mode, buffer_policy, dispatch,
                           deps, feed, journal, journal_key) -> RunHandle:
        """``submit`` body, running inside a ``_begin_op`` window (the
        close/submit serialization gate)."""
        program.validate()
        if scheduler is not None:
            scheduler_spec(scheduler)        # fail fast, not in dispatcher
        if dispatch is not None and dispatch not in ("leased", "per_packet"):
            raise ValueError(
                f"{program.name}: dispatch must be 'leased' or "
                f"'per_packet', got {dispatch!r}")
        if mode is OffloadMode.ROI:
            with self._lock:
                registered = self._workloads.get(program.name)
            if registered is None:
                raise RuntimeError(
                    f"ROI submit of {program.name!r}: not a registered "
                    "workload — call session.register_workload(program) "
                    "first (ROI offloading reuses its executables and "
                    "buffers)")
            if registered is not program:
                # names key the caches: silently running the registered
                # instance's buffers for a different program object would
                # return the wrong data with no error
                raise ValueError(
                    f"ROI submit of {program.name!r}: a different program "
                    "instance is registered under this name; submit the "
                    "instance register_workload returned, or "
                    "unregister_workload first")
            cache = True
        elif mode is OffloadMode.BINARY:
            with self._lock:
                registered_name = program.name in self._workloads
            if registered_name:
                raise ValueError(
                    f"BINARY submit of {program.name!r}: it is a "
                    "registered workload, and BINARY teardown would "
                    "silently de-warm its ROI submits — "
                    "unregister_workload first")
            cache = False                    # init is paid by THIS run
        if region is not None:
            full = program.work_region
            if region.ndim != full.ndim:
                raise ValueError(
                    f"{program.name}: region {region} has {region.ndim} "
                    f"dims, program NDRange {full} has {full.ndim}")
            if not full.contains(region):
                raise ValueError(f"{program.name}: region {region} not "
                                 f"contained in program NDRange {full}")
            if not region.aligned_within(full):
                raise ValueError(
                    f"{program.name}: region {region} is not lws-aligned "
                    f"within {full} (per-dimension lws "
                    f"{tuple(d.lws for d in full.dims)})")
        if scheduler_kwargs is not None:
            skw = dict(scheduler_kwargs)
        elif scheduler is None or scheduler == self.scheduler:
            skw = dict(self.scheduler_kwargs)
        else:
            skw = {}
        if buffer_policy is None and mode is OffloadMode.ROI:
            # pooled is the default for warm ROI submits: that is where
            # buffer reuse and transfer overlap actually pay off
            buffer_policy = BufferPolicy.POOLED
        dep_list = list(deps or [])
        for d in dep_list:
            if not isinstance(d, RunHandle):
                raise TypeError(
                    f"{program.name}: deps must be RunHandles, got {d!r}")
            if d not in self._issued:
                raise ValueError(
                    f"{program.name}: dep {d!r} was not issued by this "
                    "session — cross-session dependencies are not "
                    "supported (the dispatcher could not drain them)")
        if feed is not None and not callable(feed):
            raise TypeError(f"{program.name}: feed must be callable")
        sub = _Submission(
            program=program, powers=powers,
            scheduler=scheduler or self.scheduler,
            scheduler_kwargs=skw,
            cache=cache, collect=collect,
            region=region, mode=mode,
            buffer_policy=buffer_policy,
            dispatch=dispatch,
            deps=dep_list, feed=feed,
            journal=journal, journal_key=journal_key)
        work = (region if region is not None
                else program.work_region).dims[0].size
        with self._cv:
            # no _closing re-check: this thread holds a _begin_op window,
            # so a concurrent close() waits for it — the submission lands
            # in the queue and is drained by the closing dispatcher
            sub.handle = RunHandle(program.name, self._seq,
                                   discard=lambda: self._discard(sub),
                                   deps=dep_list)
            self._seq += 1
            self._pending.append(sub)
            self._issued.add(sub.handle)
            # graph-wide accounting: static dim-0 total until the run
            # context attaches its live scheduler (see GraphProgress)
            self._graph.register(sub.handle, work)
            self._cv.notify_all()
        return sub.handle

    def _discard(self, sub: _Submission) -> None:
        """Remove a cancelled submission from the pending set (it must not
        wait for — nor pay — dispatch).  Wakes the dispatcher so the
        cancel cascades to dependents immediately."""
        with self._cv:
            try:
                self._pending.remove(sub)
            except ValueError:
                pass                          # already popped by dispatch
            self._cv.notify_all()
        self._graph.complete(sub.handle)

    def run(self, program: Program, **kw) -> RunResult:
        """Blocking convenience: ``submit(...).result()``."""
        return self.submit(program, **kw).result()

    # -- dispatch ------------------------------------------------------------
    def _next_action_locked(self) -> Optional[Tuple[str, _Submission]]:
        """Scan the pending set (submit order) for the first actionable
        node.  Called under ``self._cv``; pops the submission it returns.

        Ready-set state machine per pending node:
          * any predecessor CANCELLED  -> ``("cancel", sub)`` — cascade;
          * any predecessor failed     -> ``("dep_failed", sub)``;
          * all predecessors succeeded -> ``("run", sub)`` iff an inflight
            slot is free (no deps == trivially ready);
          * otherwise the node stays pending.
        """
        for sub in list(self._pending):
            if any(d.cancelled() for d in sub.deps):
                self._pending.remove(sub)
                return ("cancel", sub)
            if any(d.failed() for d in sub.deps):
                self._pending.remove(sub)
                return ("dep_failed", sub)
            if (self._inflight < self.max_inflight
                    and all(d.succeeded() for d in sub.deps)):
                self._pending.remove(sub)
                return ("run", sub)
        return None

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                action = self._next_action_locked()
                while action is None:
                    if (self._closing and not self._pending
                            and self._inflight == 0
                            and self._submitting == 0):
                        # closing, graph drained, and no submit/register
                        # still inside its _begin_op window
                        return
                    self._cv.wait()
                    action = self._next_action_locked()
                kind, sub = action
                if kind == "run":
                    self._inflight += 1
            if kind == "cancel":
                # predecessor cancelled -> this node cancels too; its own
                # dependents cascade on the next scan (transitively)
                sub.handle._cascade_cancel()
                self._graph.complete(sub.handle)
            elif kind == "dep_failed":
                failed = next(d for d in sub.deps if d.failed())
                exc = DependencyError(sub.program.name,
                                      failed.program_name,
                                      cause=failed._exception)
                exc.__cause__ = failed._exception
                sub.handle._set_exception(exc)
                self._graph.complete(sub.handle)
            elif not sub.handle._start():     # cancelled while pending
                self._graph.complete(sub.handle)
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()
            else:
                self._pool.submit(self._runner(sub))

    def _runner(self, sub: _Submission) -> Callable[[], None]:
        """Job body for one started node: feed predecessor results, run,
        settle the handle, free the inflight slot."""
        def job() -> None:
            try:
                if sub.feed is not None:
                    sub.feed([d.result(timeout=0) for d in sub.deps])
                sub.handle._set_result(self._execute(sub))
            except BaseException as e:        # surfaced via handle.result()
                sub.handle._set_exception(e)
            finally:
                self._graph.complete(sub.handle)
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()
        return job

    def remaining_work(self) -> int:
        """Outstanding dim-0 work across every non-terminal submit of the
        session's graph: in-flight runs report their schedulers' exact
        lease/retry/pool accounting, pending nodes their static totals."""
        return self._graph.remaining()

    def _execute(self, sub: _Submission) -> RunResult:
        with self._lock:
            devices = [d for d in self._devices
                       if self.reset_device_stats or not d.dead]
        if not devices:
            raise RuntimeError(
                f"{sub.program.name}: session has no live devices")
        if sub.powers is not None and len(sub.powers) != len(devices):
            raise ValueError(
                f"{sub.program.name}: got {len(sub.powers)} powers for "
                f"{len(devices)} devices")
        policy = sub.buffer_policy if sub.buffer_policy is not None \
            else self.buffer_policy
        ctx = _RunContext(
            sub.program, devices,
            scheduler=sub.scheduler,
            scheduler_kwargs=sub.scheduler_kwargs,
            compile_fn=lambda dev: self._compile_for(sub.program, dev,
                                                     sub.cache),
            pool=self._pool,
            buffer_policy=policy,
            arena=self.arena if policy.pooled else None,
            parallel_init=self.parallel_init,
            reset_device_stats=self.reset_device_stats,
            powers=sub.powers,
            collect=sub.collect,
            region=sub.region,
            dispatch=sub.dispatch or self.dispatch,
            journal=sub.journal,
            journal_key=sub.journal_key,
            progress=self._graph,
            progress_key=sub.handle,
            tenant=self._tenant,
            lease_params=self.lease_params,
            async_threshold_bytes=self.async_threshold_bytes)
        if self._tenant is not None:
            # run brackets: exclusive tenants fence the fleet here, and
            # the arbiter catches the tenant's virtual time up on
            # idle->active so sleepers don't hoard credit
            self._tenant.begin_run()
            try:
                result = ctx.execute()
            finally:
                self._tenant.end_run()
        else:
            result = ctx.execute()
        if sub.mode is OffloadMode.BINARY:
            # the binary contract tears down per submit: evict anything
            # cached under this name (stale earlier registrations included)
            # and charge the eviction to this run's teardown phase
            t0 = time.perf_counter()
            self.evict(sub.program.name)
            extra = time.perf_counter() - t0
            if result.phases is not None:
                result.phases = dataclasses.replace(
                    result.phases,
                    teardown_s=result.phases.teardown_s + extra)
                result.binary_time = result.phases.binary
        return result

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Drain the pending graph, release the arena, stop the pool — in
        that order.  The dispatcher drains every pending submission in
        topological order (dependents run after — or fail/cancel cleanly
        with — their predecessors; no queued ``_Submission`` leaks), and
        the graph must drain *before* the arena closes (an in-flight
        pooled run acquires from it) and the arena must release its
        entries *before* ``WorkerPool.close()`` — a close racing in-flight
        submits must not leak arena entries behind a dead pool."""
        with self._cv:
            if self._closing:
                return
            self._closing = True
            self._cv.notify_all()
        self._dispatcher.join()              # drains graph + open submits
        if self._tenant is not None:
            # tenant mode: retire from the arbiter (drops this tenant's
            # arena partition keys); the SHARED arena/pool stay open for
            # co-tenants and are closed by FleetArbiter.close()
            self.arbiter.unregister(self._tenant)
        else:
            self.arena.close()               # pooled buffers released
        if self._owns_pool:
            self._pool.close()

    def __enter__(self) -> "EngineSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"EngineSession({self.name!r}, devices="
                f"{[d.name for d in self.devices]}, "
                f"scheduler={self.scheduler!r}, "
                f"cached={len(self._executables)})")
