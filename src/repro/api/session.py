"""Tier-2: EngineSession — one session, many programs, reused primitives.

The paper's optimizations only pay off when costly primitives (compiled
executables, registered buffers) are *reused across runs*.  The session is
where that reuse lives:

  * an **executable cache** keyed by (program, device) — back-to-back
    submits of the same program pay ``init_cost_s`` at most once per device
    per session, not once per run;
  * a **buffer registry** recording which (program, device) pairs have
    registered input buffers (``BufferPolicy.REGISTERED`` commits outputs
    in place against them);
  * **elastic device membership** across runs (``add_device`` /
    ``remove_device`` renormalize scheduler powers on the next submit);
  * a **WorkerPool** of device threads reused run-to-run;
  * an async **submit queue**: ``submit(program) -> RunHandle`` returns
    immediately, so callers overlap input preparation with in-flight runs
    exactly as the init optimization overlaps compiles.  Submitted programs
    dispatch strictly in order (one co-execution owns the fleet at a time —
    the paper's co-execution model), but never block the submitting thread.
  * a **workload registry** for the paper's ROI offloading:
    ``register_workload(program)`` pays init once (executables built,
    buffers registered on every device); subsequent
    ``submit(program, region=..., mode=OffloadMode.ROI)`` calls execute
    sub-regions warm.  ``mode=OffloadMode.BINARY`` is the opposite
    contract: fully self-contained init -> offload -> teardown per submit.

Blocking callers use ``session.run(program)`` or Tier-1
``coexec(program, devices=...)``.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.device import DeviceGroup
from repro.core.membuf import ArenaStats, BufferArena
from repro.core.metrics import RunResult
from repro.core.region import Region
from repro.core.runtime import Program, WorkerPool, _RunContext
from repro.core.scheduler import scheduler_spec
from repro.api.handles import RunHandle
from repro.api.policies import BufferPolicy, DevicePolicy, OffloadMode


@dataclass(eq=False)          # identity semantics: queue removal on cancel
class _Submission:
    """Everything one queued run needs, captured at submit time."""
    program: Program
    powers: Optional[List[float]]
    scheduler: str
    scheduler_kwargs: Dict
    cache: bool
    collect: Optional[Callable]
    region: Optional[Region] = None
    mode: Optional[OffloadMode] = None
    buffer_policy: Optional[BufferPolicy] = None
    dispatch: Optional[str] = None
    handle: RunHandle = field(default=None)  # type: ignore[assignment]


class EngineSession:
    """A long-lived co-execution session over an elastic device fleet."""

    def __init__(self, devices: Optional[Sequence[DeviceGroup]] = None, *,
                 scheduler: str = "hguided_opt",
                 scheduler_kwargs: Optional[Dict] = None,
                 buffer_policy: BufferPolicy = BufferPolicy.REGISTERED,
                 device_policy: Optional[DevicePolicy] = None,
                 parallel_init: bool = True,
                 cache_executables: bool = True,
                 init_cost_s: float = 0.0,
                 reset_device_stats: bool = True,
                 arena_capacity_bytes: int = 256 << 20,
                 arena_ring: int = 2,
                 dispatch: str = "leased",
                 name: str = "session"):
        scheduler_spec(scheduler)            # fail fast on unknown names
        if dispatch not in ("leased", "per_packet"):
            raise ValueError(f"dispatch must be 'leased' or 'per_packet', "
                             f"got {dispatch!r}")
        self.dispatch = dispatch
        self.device_policy = device_policy or DevicePolicy()
        self._devices: List[DeviceGroup] = \
            self.device_policy.resolve(devices)
        self.scheduler = scheduler
        self.scheduler_kwargs = dict(scheduler_kwargs or {})
        self.buffer_policy = buffer_policy
        self.parallel_init = parallel_init
        self.cache_executables = cache_executables
        # emulated fixed driver-primitive cost paid per executable build;
        # the cache amortizes it across submits (paper's init optimization)
        self.init_cost_s = init_cost_s
        self.reset_device_stats = reset_device_stats
        self.name = name
        # the memory subsystem: session-owned buffer arena backing POOLED
        # runs (register_workload/evict manage its entries; close drains it)
        self.arena = BufferArena(capacity_bytes=arena_capacity_bytes,
                                 ring=arena_ring, name=f"{name}-arena")

        self._executables: Dict[Tuple[str, str], Callable] = {}
        self._buffer_registry: Dict[Tuple[str, str], int] = {}
        self._workloads: Dict[str, Program] = {}   # ROI-registered programs
        self.init_payments = 0               # executable builds performed
        self._lock = threading.Lock()

        self._pool = WorkerPool(name=name)
        self._queue: "collections.deque[_Submission]" = collections.deque()
        self._cv = threading.Condition()
        self._closing = False
        self._seq = 0
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name=f"{name}-dispatch", daemon=True)
        self._dispatcher.start()

    # -- elastic membership --------------------------------------------------
    @property
    def devices(self) -> List[DeviceGroup]:
        with self._lock:
            return list(self._devices)

    def add_device(self, dev: DeviceGroup) -> None:
        with self._lock:
            if any(d.name == dev.name for d in self._devices):
                raise ValueError(f"device {dev.name!r} already in session")
            self._devices.append(dev)

    def remove_device(self, name: str) -> None:
        with self._lock:
            self._devices = [d for d in self._devices if d.name != name]
            for key in [k for k in self._executables if k[1] == name]:
                del self._executables[key]
            for key in [k for k in self._buffer_registry if k[1] == name]:
                del self._buffer_registry[key]

    # -- caches --------------------------------------------------------------
    @property
    def executables(self) -> Dict[Tuple[str, str], Callable]:
        """(program_name, device_name) -> compiled range executable."""
        with self._lock:
            return dict(self._executables)

    @property
    def buffer_registry(self) -> Dict[Tuple[str, str], int]:
        """(program_name, device_name) -> number of buffer registrations
        for cached programs (1 everywhere means full reuse)."""
        with self._lock:
            return dict(self._buffer_registry)

    def evict(self, program_name: str) -> None:
        """Drop a program's cached executables/buffers (all devices) and
        its arena entries (pooled run buffers)."""
        with self._lock:
            for key in [k for k in self._executables
                        if k[0] == program_name]:
                del self._executables[key]
            for key in [k for k in self._buffer_registry
                        if k[0] == program_name]:
                del self._buffer_registry[key]
        self.arena.evict(program_name)

    @property
    def arena_stats(self) -> ArenaStats:
        """Counters/gauges of the session's buffer arena."""
        return self.arena.stats

    # -- workload registry (ROI offloading) ----------------------------------
    @property
    def workloads(self) -> Dict[str, Program]:
        """name -> registered persistent workload (ROI-mode targets)."""
        with self._lock:
            return dict(self._workloads)

    def register_workload(self, program: Program, *,
                          build: bool = True) -> Program:
        """Register ``program`` as a persistent workload and pay init NOW.

        Executables are built (and buffers registered) on every current
        device up front, so subsequent ``mode=OffloadMode.ROI`` submits —
        the paper's repeated sub-region offloads — run warm from the first
        one.  ``build=False`` only records the workload (init is then paid
        lazily by the first submit).  Returns the registered program.
        """
        program.validate()
        with self._cv:
            if self._closing:
                raise RuntimeError(f"session {self.name!r} is closed")
        with self._lock:
            devices = list(self._devices)
        if build:
            # parallel init, same as the dispatch path: registration costs
            # one init window, not n_devices serial ones
            errors: List[BaseException] = []

            def compile_one(dev):
                try:
                    self._compile_for(program, dev, cache=True)
                except BaseException as e:
                    errors.append(e)

            threads = [threading.Thread(target=compile_one, args=(d,),
                                        daemon=True) for d in devices]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            if errors:
                raise errors[0]
            # pre-populate the arena's output ring for the full-region
            # shape, so even the FIRST pooled ROI submit of the whole
            # workload hits instead of allocating (sub-region ROIs create
            # their own keys on first submit and are warm from the second)
            region = program.work_region
            out_cols = program.out_cols if region.ndim == 1 \
                else region.dims[1].size * program.out_cols
            out_rows = region.dims[0].size * program.out_rows_per_wg
            self.arena.register(program.name, "host", (out_rows, out_cols),
                                program.out_dtype)
        with self._lock:
            self._workloads[program.name] = program
        return program

    def unregister_workload(self, name: str) -> None:
        """Drop a registered workload and evict its cached state."""
        with self._lock:
            self._workloads.pop(name, None)
        self.evict(name)

    def _compile_for(self, program: Program, dev: DeviceGroup,
                     cache: bool) -> Callable:
        key = (program.name, dev.name)
        if cache:
            with self._lock:
                fn = self._executables.get(key)
            if fn is not None:
                return fn
        if self.init_cost_s:
            time.sleep(self.init_cost_s)      # driver primitive cost
        fn = program.build(dev)
        with self._lock:
            self.init_payments += 1
            if cache and self.cache_executables:
                # ephemeral (cache=False) programs must not grow the
                # registries: a serving session submits one uniquely-named
                # round program per dispatch round
                self._executables[key] = fn
                self._buffer_registry[key] = \
                    self._buffer_registry.get(key, 0) + 1
        return fn

    # -- submission ----------------------------------------------------------
    def submit(self, program: Program, *,
               powers: Optional[List[float]] = None,
               scheduler: Optional[str] = None,
               scheduler_kwargs: Optional[Dict] = None,
               collect: Optional[Callable] = None,
               cache: bool = True,
               region: Optional[Region] = None,
               mode: Optional[OffloadMode] = None,
               buffer_policy: Optional[BufferPolicy] = None,
               dispatch: Optional[str] = None) -> RunHandle:
        """Enqueue a program; returns a future-like RunHandle immediately.

        ``powers`` overrides the per-device computing powers for this run;
        ``scheduler``/``scheduler_kwargs`` override the session defaults
        (e.g. a serving round's rotated Static order or deadline slack) —
        overriding the scheduler DROPS the session-level kwargs, which were
        tuned for a different class; ``collect(packet, result, device)``
        replaces array output assembly for reduction-style programs
        (called under the run's commit lock); ``cache=False`` skips the
        executable cache for ephemeral programs.

        ``region`` restricts the run to a sub-region of the program's
        NDRange (must be contained and per-dimension lws-aligned within
        it); the result's ``output`` covers just that sub-region.
        ``mode`` selects the paper's offload contract: ``BINARY`` builds
        fresh and tears down after (self-contained one-shot, full init +
        teardown charged to this run's phase breakdown), ``ROI`` requires
        the program to be ``register_workload``-ed and executes warm
        against the registered executables/buffers.

        ``buffer_policy`` overrides the session's buffer handling for this
        run.  ROI submits default to ``BufferPolicy.POOLED`` (arena-backed
        output + overlapped transfer pipeline — note the pooled
        result-lifetime contract: ``output`` is a recycled view, valid
        until the workload's ring cycles); everything else defaults to the
        session policy.

        ``dispatch`` overrides the session's scheduler hand-off mode for
        this run: ``"leased"`` (default — lease-amortized packet plans
        with the scheduler's adaptive ``lease``/``acquire`` path) or
        ``"per_packet"`` (one lock crossing per packet, the measurable
        baseline).
        """
        program.validate()
        if scheduler is not None:
            scheduler_spec(scheduler)        # fail fast, not in dispatcher
        if dispatch is not None and dispatch not in ("leased", "per_packet"):
            raise ValueError(
                f"{program.name}: dispatch must be 'leased' or "
                f"'per_packet', got {dispatch!r}")
        if mode is OffloadMode.ROI:
            with self._lock:
                registered = self._workloads.get(program.name)
            if registered is None:
                raise RuntimeError(
                    f"ROI submit of {program.name!r}: not a registered "
                    "workload — call session.register_workload(program) "
                    "first (ROI offloading reuses its executables and "
                    "buffers)")
            if registered is not program:
                # names key the caches: silently running the registered
                # instance's buffers for a different program object would
                # return the wrong data with no error
                raise ValueError(
                    f"ROI submit of {program.name!r}: a different program "
                    "instance is registered under this name; submit the "
                    "instance register_workload returned, or "
                    "unregister_workload first")
            cache = True
        elif mode is OffloadMode.BINARY:
            with self._lock:
                registered_name = program.name in self._workloads
            if registered_name:
                raise ValueError(
                    f"BINARY submit of {program.name!r}: it is a "
                    "registered workload, and BINARY teardown would "
                    "silently de-warm its ROI submits — "
                    "unregister_workload first")
            cache = False                    # init is paid by THIS run
        if region is not None:
            full = program.work_region
            if region.ndim != full.ndim:
                raise ValueError(
                    f"{program.name}: region {region} has {region.ndim} "
                    f"dims, program NDRange {full} has {full.ndim}")
            if not full.contains(region):
                raise ValueError(f"{program.name}: region {region} not "
                                 f"contained in program NDRange {full}")
            if not region.aligned_within(full):
                raise ValueError(
                    f"{program.name}: region {region} is not lws-aligned "
                    f"within {full} (per-dimension lws "
                    f"{tuple(d.lws for d in full.dims)})")
        if scheduler_kwargs is not None:
            skw = dict(scheduler_kwargs)
        elif scheduler is None or scheduler == self.scheduler:
            skw = dict(self.scheduler_kwargs)
        else:
            skw = {}
        if buffer_policy is None and mode is OffloadMode.ROI:
            # pooled is the default for warm ROI submits: that is where
            # buffer reuse and transfer overlap actually pay off
            buffer_policy = BufferPolicy.POOLED
        sub = _Submission(
            program=program, powers=powers,
            scheduler=scheduler or self.scheduler,
            scheduler_kwargs=skw,
            cache=cache, collect=collect,
            region=region, mode=mode,
            buffer_policy=buffer_policy,
            dispatch=dispatch)
        with self._cv:
            if self._closing:
                raise RuntimeError(f"session {self.name!r} is closed")
            sub.handle = RunHandle(program.name, self._seq,
                                   discard=lambda: self._discard(sub))
            self._seq += 1
            self._queue.append(sub)
            self._cv.notify()
        return sub.handle

    def _discard(self, sub: _Submission) -> None:
        """Remove a cancelled submission from the queue (it must not wait
        for — nor pay — dispatch)."""
        with self._cv:
            try:
                self._queue.remove(sub)
            except ValueError:
                pass                          # already popped by dispatch

    def run(self, program: Program, **kw) -> RunResult:
        """Blocking convenience: ``submit(...).result()``."""
        return self.submit(program, **kw).result()

    # -- dispatch ------------------------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closing:
                    self._cv.wait()
                if not self._queue:
                    return                    # closing and drained
                sub = self._queue.popleft()
            if not sub.handle._start():
                continue                      # cancelled while queued
            try:
                sub.handle._set_result(self._execute(sub))
            except BaseException as e:        # surfaced via handle.result()
                sub.handle._set_exception(e)

    def _execute(self, sub: _Submission) -> RunResult:
        with self._lock:
            devices = [d for d in self._devices
                       if self.reset_device_stats or not d.dead]
        if not devices:
            raise RuntimeError(
                f"{sub.program.name}: session has no live devices")
        if sub.powers is not None and len(sub.powers) != len(devices):
            raise ValueError(
                f"{sub.program.name}: got {len(sub.powers)} powers for "
                f"{len(devices)} devices")
        policy = sub.buffer_policy if sub.buffer_policy is not None \
            else self.buffer_policy
        ctx = _RunContext(
            sub.program, devices,
            scheduler=sub.scheduler,
            scheduler_kwargs=sub.scheduler_kwargs,
            compile_fn=lambda dev: self._compile_for(sub.program, dev,
                                                     sub.cache),
            pool=self._pool,
            buffer_policy=policy,
            arena=self.arena if policy.pooled else None,
            parallel_init=self.parallel_init,
            reset_device_stats=self.reset_device_stats,
            powers=sub.powers,
            collect=sub.collect,
            region=sub.region,
            dispatch=sub.dispatch or self.dispatch)
        result = ctx.execute()
        if sub.mode is OffloadMode.BINARY:
            # the binary contract tears down per submit: evict anything
            # cached under this name (stale earlier registrations included)
            # and charge the eviction to this run's teardown phase
            t0 = time.perf_counter()
            self.evict(sub.program.name)
            extra = time.perf_counter() - t0
            if result.phases is not None:
                result.phases = dataclasses.replace(
                    result.phases,
                    teardown_s=result.phases.teardown_s + extra)
                result.binary_time = result.phases.binary
        return result

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Drain queued runs, release the arena, stop the pool — in that
        order.  The dispatch queue must drain *before* the arena closes
        (an in-flight pooled run acquires from it) and the arena must
        release its entries *before* ``WorkerPool.close()`` — a close
        racing in-flight submits must not leak arena entries behind a
        dead pool."""
        with self._cv:
            if self._closing:
                return
            self._closing = True
            self._cv.notify_all()
        self._dispatcher.join()              # drains every queued submit
        self.arena.close()                   # pooled buffers released
        self._pool.close()

    def __enter__(self) -> "EngineSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"EngineSession({self.name!r}, devices="
                f"{[d.name for d in self.devices]}, "
                f"scheduler={self.scheduler!r}, "
                f"cached={len(self._executables)})")
