"""Synthetic-corpus data pipeline.

Deterministic, seekable and *packet-sliceable*: ``batch_at(step)`` is a pure
function of (seed, step), so (a) restart-from-checkpoint replays the exact
stream with no state to save, (b) the co-execution runtime can hand disjoint
row ranges of one global batch to different device groups
(``slice_rows``) without materializing the whole batch on any host, and
(c) every host in a multi-controller deployment computes its own shard
locally.  A background prefetch thread keeps ``depth`` batches ready.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class DataConfig:
    seed: int = 1234
    # markov-chain synthetic text: next token depends on current (keeps the
    # loss learnable so the end-to-end example shows real convergence)
    markov_alpha: float = 0.7


class SyntheticPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 data: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data = data
        self.V = cfg.vocab_size
        # fixed random transition structure: tok -> preferred successor
        rng = np.random.default_rng(data.seed)
        self._succ = rng.integers(0, self.V, size=(self.V,), dtype=np.int64)

    # -- pure batch construction ------------------------------------------
    def batch_at(self, step: int,
                 rows: Optional[slice] = None) -> Dict[str, np.ndarray]:
        B, S = self.shape.global_batch, self.shape.seq_len
        r0, r1 = (rows.start or 0, rows.stop if rows and rows.stop else B) \
            if rows else (0, B)
        n = r1 - r0
        ss = np.random.SeedSequence([self.data.seed, step, r0, r1])
        rng = np.random.default_rng(ss)
        cb = self.cfg.n_codebooks if self.cfg.frontend == "encodec_stub" else 0
        shape = (n, S, cb) if cb else (n, S)
        noise = rng.integers(0, self.V, size=shape, dtype=np.int64)
        toks = np.empty(shape, dtype=np.int32)
        toks[:, 0] = noise[:, 0]
        a = self.data.markov_alpha
        follow = rng.random((n, S)) < a
        for t in range(1, S):
            prev = toks[:, t - 1]
            succ = self._succ[prev]
            toks[:, t] = np.where(
                follow[:, t][..., None] if cb else follow[:, t],
                succ, noise[:, t])
        out = {"tokens": toks}
        if self.cfg.frontend == "vit_stub":
            out["patches"] = rng.standard_normal(
                (n, self.cfg.n_patches, self.cfg.d_model)).astype(np.float32)
        return out

    def slice_rows(self, step: int, start: int,
                   size: int) -> Dict[str, np.ndarray]:
        """Co-execution packet: rows [start, start+size) of global batch."""
        return self.batch_at(step, rows=slice(start, start + size))

    # -- prefetching iterator ---------------------------------------------
    def iterator(self, start_step: int = 0, depth: int = 2) -> Iterator[Dict]:
        q: "queue.Queue" = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
