"""Memory subsystem: the buffer arena and the overlapped transfer pipeline.

The paper attributes most of the co-execution penalty in time-constrained
scenarios to runtime management overheads, buffer handling chief among
them; its EngineCL optimizations come from *reusing* buffers across runs
and *hiding* transfer latency behind compute (the DMA/compute-overlap
discipline of the MPSoC offloading literature).  This module is those two
optimizations as first-class, auditable objects:

* :class:`BufferArena` -- a size-bucketed pool of run buffers keyed by
  ``(program, device, shape, dtype)``.  Each key owns a small **ring**
  (default two entries: classic double buffering), so back-to-back warm
  submits of the same workload alternate between recycled buffers instead
  of allocating.  Free entries are bounded by ``capacity_bytes`` with LRU
  eviction; on a key miss the arena first *re-keys* an LRU free entry from
  the same size bucket before allocating fresh memory.  The arena is
  session-owned: ``EngineSession.register_workload`` pre-populates rings,
  ``EngineSession.evict`` / ``close`` drop them.

* :class:`TransferPipeline` -- a per-run stage-in -> compute -> stage-out
  coordinator.  While packet *k* computes on a device thread, packet
  *k+1*'s stage-in (scheduler pull + launch binding, the H2D window) runs
  on a prefetch thread, and packet *k-1*'s stage-out (device->host result
  conversion + commit into the run output, the D2H window) drains on a
  committer thread -- so device threads never block on host staging.

* :class:`BufferPolicy` -- the Runtime buffer-handling policy.  Grown from
  the paper's boolean ``opt_buffers`` into three named contracts (see the
  enum docstring); ``POOLED`` is the default for warm ROI submits.

**Result-lifetime contract (POOLED):** a pooled run's ``output`` is a view
into a recycled arena buffer.  It stays valid until the same workload's
output ring cycles back around (``ring`` submits later); copy it if you
need it past that.  This is exactly the device-buffer semantics the paper's
runtime exposes -- reuse is what makes warm offloads cheap.
"""
from __future__ import annotations

import enum
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "ArenaPartition",
    "ArenaStats",
    "BufferArena",
    "BufferLease",
    "BufferPolicy",
    "StageFuture",
    "TransferPipeline",
]


class BufferPolicy(enum.Enum):
    """How the Runtime feeds inputs and commits outputs (grown from the old
    boolean ``opt_buffers``).

    * ``REGISTERED`` -- the paper's buffer-flag optimization: inputs are
      registered once per device as read-only buffers (zero-copy slice
      views feed each packet), outputs are committed in place into a
      per-run preallocated result.
    * ``PER_PACKET`` -- the worst practice the paper's drivers exhibited:
      every packet bulk-copies, results are assembled from per-packet
      copies at the end.  Kept as a measurable baseline.
    * ``POOLED`` -- registered buffers plus the memory subsystem: the run
      output comes from the session's :class:`BufferArena` (no per-run
      allocation), and packets move through the :class:`TransferPipeline`
      (stage-in prefetched, stage-out committed off-thread) so device
      threads never block on host staging.  The default for warm ROI
      submits; pooled outputs are recycled views -- see the result-lifetime
      contract in the module docstring.
    """

    REGISTERED = "registered"
    PER_PACKET = "per_packet"
    POOLED = "pooled"

    @classmethod
    def from_flag(cls, opt_buffers: bool) -> "BufferPolicy":
        return cls.REGISTERED if opt_buffers else cls.PER_PACKET

    @property
    def registered(self) -> bool:
        """Outputs committed in place (no per-packet result copies)."""
        return self is not BufferPolicy.PER_PACKET

    @property
    def pooled(self) -> bool:
        return self is BufferPolicy.POOLED


# --------------------------------------------------------------------------
# Buffer arena
# --------------------------------------------------------------------------

_MIN_BUCKET = 256  # smallest bucket: sub-256B buffers all share one class


def bucket_bytes(nbytes: int) -> int:
    """Size class of a request: next power of two >= nbytes (min 256B).
    Bucketing is what lets a freed buffer back any same-class request,
    not just an identical shape."""
    b = _MIN_BUCKET
    while b < nbytes:
        b <<= 1
    return b


@dataclass
class ArenaStats:
    """Counters snapshot (all monotonic except the gauges at the end)."""

    acquires: int = 0
    hits: int = 0          # exact-key ring hit (a free ring entry)
    rekeys: int = 0        # size-bucket steal from another key
    misses: int = 0        # fresh allocation
    recycles: int = 0      # ring full: oldest leased entry overwritten
    evictions: int = 0     # entries dropped (LRU capacity or evict())
    # gauges
    entries: int = 0
    leases_out: int = 0
    bytes_pooled: int = 0  # free (reusable) bytes
    bytes_leased: int = 0  # bytes currently leased out

    @property
    def bytes_total(self) -> int:
        return self.bytes_pooled + self.bytes_leased


class _Entry:
    """One arena buffer: a raw byte block viewed per-lease as a typed
    (shape, dtype) array."""

    __slots__ = ("key", "raw", "cap", "stamp", "leased")

    def __init__(self, key: Tuple, cap: int, stamp: int):
        self.key = key
        self.raw = np.empty(cap, dtype=np.uint8)
        self.cap = cap
        self.stamp = stamp
        self.leased = False


class BufferLease:
    """A leased arena buffer: ``array`` is the (shape, dtype) view."""

    __slots__ = ("key", "array", "_entry")

    def __init__(self, key: Tuple, array: np.ndarray, entry: _Entry):
        self.key = key
        self.array = array
        self._entry = entry

    def __repr__(self) -> str:
        return f"BufferLease({self.key}, {self.array.shape})"


def arena_key(program: str, device: str, shape, dtype) -> Tuple:
    if np.isscalar(shape):
        shape = (int(shape),)
    else:
        shape = tuple(int(s) for s in shape)
    return (program, device, shape, np.dtype(dtype).str)


class BufferArena:
    """Per-session pool of run buffers (see module docstring).

    Thread-safe.  ``ring`` bounds the outstanding leases per key: the
    ``ring+1``-th acquire of a key recycles (overwrites) the oldest leased
    entry -- double buffering, the caller-visible lifetime contract.
    ``capacity_bytes`` bounds the *free* pool; least-recently-used free
    entries are evicted first.  Leased bytes are bounded separately by
    ``ring`` x live keys, and are dropped from tracking (never freed under
    the caller) by :meth:`evict` / :meth:`close`.
    """

    def __init__(self, capacity_bytes: int = 256 << 20, ring: int = 2,
                 name: str = "arena"):
        if ring < 1:
            raise ValueError(f"arena ring must be >= 1, got {ring}")
        self.capacity_bytes = int(capacity_bytes)
        self.ring = int(ring)
        self.name = name
        self._lock = threading.Lock()
        self._by_key: Dict[Tuple, List[_Entry]] = {}
        self._clock = 0
        self._stats = ArenaStats()
        self._closed = False

    # -- internal ----------------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _free_bytes_locked(self) -> int:
        return sum(e.cap for ents in self._by_key.values()
                   for e in ents if not e.leased)

    def _evict_lru_free_locked(self) -> None:
        """Drop LRU free entries until the free pool fits capacity_bytes."""
        over = self._free_bytes_locked() - self.capacity_bytes
        while over > 0:
            lru: Optional[_Entry] = None
            for ents in self._by_key.values():
                for e in ents:
                    if not e.leased and (lru is None or e.stamp < lru.stamp):
                        lru = e
            if lru is None:
                return
            self._by_key[lru.key].remove(lru)
            if not self._by_key[lru.key]:
                del self._by_key[lru.key]
            self._stats.evictions += 1
            over -= lru.cap

    def _steal_bucket_locked(self, cap: int) -> Optional[_Entry]:
        """LRU free entry of the same size class, re-keyed to the caller."""
        lru: Optional[_Entry] = None
        for ents in self._by_key.values():
            for e in ents:
                fits = not e.leased and e.cap == cap
                if fits and (lru is None or e.stamp < lru.stamp):
                    lru = e
        if lru is None:
            return None
        self._by_key[lru.key].remove(lru)
        if not self._by_key[lru.key]:
            del self._by_key[lru.key]
        return lru

    # -- public ------------------------------------------------------------
    def acquire(self, program: str, device: str, shape, dtype) -> BufferLease:
        """Lease a (shape, dtype) buffer for ``(program, device)``.

        Resolution order: free ring entry under the exact key (hit) ->
        recycle the oldest leased ring entry if the ring is full (the
        double-buffer overwrite) -> re-key an LRU free entry of the same
        size bucket -> allocate (miss).
        """
        key = arena_key(program, device, shape, dtype)
        itemsize = np.dtype(dtype).itemsize
        nbytes = int(np.prod(key[2], dtype=np.int64)) * itemsize
        cap = bucket_bytes(nbytes)
        with self._lock:
            if self._closed:
                raise RuntimeError(f"arena {self.name!r} is closed")
            self._stats.acquires += 1
            ents = self._by_key.setdefault(key, [])
            entry = None
            for e in ents:
                if not e.leased:
                    entry = e
                    self._stats.hits += 1
                    break
            if entry is None and len(ents) >= self.ring:
                # ring full, all leased: overwrite the oldest lease
                entry = min(ents, key=lambda e: e.stamp)
                self._stats.recycles += 1
            if entry is None:
                stolen = self._steal_bucket_locked(cap)
                if stolen is not None:
                    stolen.key = key
                    ents.append(stolen)
                    entry = stolen
                    self._stats.rekeys += 1
                else:
                    entry = _Entry(key, cap, 0)
                    ents.append(entry)
                    self._stats.misses += 1
            entry.leased = True
            entry.stamp = self._tick()
            self._evict_lru_free_locked()
            view = entry.raw[:nbytes].view(np.dtype(dtype)).reshape(key[2])
            return BufferLease(key, view, entry)

    def release(self, lease: BufferLease) -> None:
        """Return a lease to the free pool (optional -- the ring recycles
        unreleased leases; releasing early just widens reuse)."""
        with self._lock:
            e = lease._entry
            ents = self._by_key.get(e.key)
            if ents is None or e not in ents or not e.leased:
                return  # evicted/closed/double-release: nothing to do
            e.leased = False
            e.stamp = self._tick()
            self._evict_lru_free_locked()

    def register(self, program: str, device: str, shape, dtype,
                 count: Optional[int] = None) -> None:
        """Pre-populate a key's ring with ``count`` free entries (default:
        the full ring) so the first warm submit already hits."""
        key = arena_key(program, device, shape, dtype)
        itemsize = np.dtype(dtype).itemsize
        nbytes = int(np.prod(key[2], dtype=np.int64)) * itemsize
        cap = bucket_bytes(nbytes)
        n = self.ring if count is None else int(count)
        with self._lock:
            if self._closed:
                raise RuntimeError(f"arena {self.name!r} is closed")
            ents = self._by_key.setdefault(key, [])
            while len(ents) < min(n, self.ring):
                ents.append(_Entry(key, cap, self._tick()))
            self._evict_lru_free_locked()

    def evict(self, program: str) -> int:
        """Drop every entry keyed to ``program`` (all devices/shapes).
        Leased arrays stay valid for their holders; the arena just stops
        tracking them.  Returns the number of entries dropped."""
        with self._lock:
            victims = [k for k in self._by_key if k[0] == program]
            n = 0
            for k in victims:
                n += len(self._by_key.pop(k))
            self._stats.evictions += n
            return n

    def evict_prefix(self, prefix: str) -> int:
        """Drop every entry whose program key starts with ``prefix`` (a
        tenant partition closing: all its programs, all devices/shapes).
        Same holder semantics as :meth:`evict`."""
        with self._lock:
            victims = [k for k in self._by_key if k[0].startswith(prefix)]
            n = 0
            for k in victims:
                n += len(self._by_key.pop(k))
            self._stats.evictions += n
            return n

    def trim_prefix(self, prefix: str, cap_bytes: int) -> int:
        """Evict LRU *free* entries under ``prefix`` until that prefix's
        free bytes fit ``cap_bytes`` (the per-tenant LRU cap of an
        :class:`ArenaPartition`).  Leased entries are never touched — a
        tenant over its cap keeps its in-flight buffers and simply loses
        reuse.  Returns the number of entries evicted."""
        dropped = 0
        with self._lock:
            while True:
                free = [
                    e
                    for k, ents in self._by_key.items()
                    if k[0].startswith(prefix)
                    for e in ents
                    if not e.leased
                ]
                if sum(e.cap for e in free) <= cap_bytes:
                    return dropped
                lru = min(free, key=lambda e: e.stamp)
                self._by_key[lru.key].remove(lru)
                if not self._by_key[lru.key]:
                    del self._by_key[lru.key]
                self._stats.evictions += 1
                dropped += 1

    def stats_for_prefix(self, prefix: str) -> ArenaStats:
        """Gauges (entries / leases / bytes) restricted to keys under
        ``prefix``.  The monotonic counters stay arena-global (acquire
        resolution crosses partitions via bucket steals), so they are
        reported as zero here — read :attr:`stats` for them."""
        with self._lock:
            s = ArenaStats()
            for k, ents in self._by_key.items():
                if not k[0].startswith(prefix):
                    continue
                for e in ents:
                    s.entries += 1
                    if e.leased:
                        s.leases_out += 1
                        s.bytes_leased += e.cap
                    else:
                        s.bytes_pooled += e.cap
            return s

    def close(self) -> int:
        """Release everything and refuse further acquires.  Returns the
        number of entries dropped (leased holders keep their arrays)."""
        with self._lock:
            n = sum(len(v) for v in self._by_key.values())
            self._stats.evictions += n
            self._by_key.clear()
            self._closed = True
            return n

    @property
    def stats(self) -> ArenaStats:
        with self._lock:
            s = ArenaStats(**{f: getattr(self._stats, f) for f in
                              ("acquires", "hits", "rekeys", "misses",
                               "recycles", "evictions")})
            for ents in self._by_key.values():
                for e in ents:
                    s.entries += 1
                    if e.leased:
                        s.leases_out += 1
                        s.bytes_leased += e.cap
                    else:
                        s.bytes_pooled += e.cap
            return s

    def __repr__(self) -> str:
        s = self.stats
        return (f"BufferArena({self.name!r}, entries={s.entries}, "
                f"pooled={s.bytes_pooled}B, leased={s.bytes_leased}B, "
                f"hit%={100 * s.hits / max(1, s.acquires):.0f})")


# --------------------------------------------------------------------------
# Arena partitions (multi-tenant)
# --------------------------------------------------------------------------


class ArenaPartition:
    """A tenant's slice of a shared :class:`BufferArena`.

    Every program key is namespaced as ``"<tenant>::<program>"``, so two
    tenants registering the same workload name never alias ring entries.
    ``cap_bytes`` (optional) bounds the partition's *free* bytes with its
    own LRU trim on top of the arena-global capacity -- a noisy tenant
    cannot squat the whole pool with cold buffers.  Closing the partition
    evicts only the tenant's keys; the shared arena stays open for
    co-tenants.  Exposes the same acquire/release/register/evict surface
    the runtime expects from a session arena.
    """

    def __init__(self, arena: BufferArena, tenant: str,
                 cap_bytes: Optional[int] = None):
        self.arena = arena
        self.tenant = str(tenant)
        self.cap_bytes = None if cap_bytes is None else int(cap_bytes)
        self._prefix = self.tenant + "::"
        self._closed = False

    def scoped(self, program: str) -> str:
        return self._prefix + program

    def _trim(self) -> None:
        if self.cap_bytes is not None:
            self.arena.trim_prefix(self._prefix, self.cap_bytes)

    # -- BufferArena surface ------------------------------------------------
    def acquire(self, program: str, device: str, shape, dtype) -> BufferLease:
        if self._closed:
            raise RuntimeError(
                f"arena partition {self.tenant!r} is closed")
        lease = self.arena.acquire(self.scoped(program), device, shape, dtype)
        self._trim()
        return lease

    def release(self, lease: BufferLease) -> None:
        self.arena.release(lease)
        self._trim()

    def register(self, program: str, device: str, shape, dtype,
                 count: Optional[int] = None) -> None:
        if self._closed:
            raise RuntimeError(
                f"arena partition {self.tenant!r} is closed")
        self.arena.register(self.scoped(program), device, shape, dtype,
                            count=count)
        self._trim()

    def evict(self, program: str) -> int:
        return self.arena.evict(self.scoped(program))

    def close(self) -> int:
        """Drop this tenant's entries only; the shared arena stays open."""
        self._closed = True
        return self.arena.evict_prefix(self._prefix)

    @property
    def stats(self) -> ArenaStats:
        return self.arena.stats_for_prefix(self._prefix)

    def __repr__(self) -> str:
        s = self.stats
        return (f"ArenaPartition({self.tenant!r}, entries={s.entries}, "
                f"pooled={s.bytes_pooled}B, leased={s.bytes_leased}B)")


# --------------------------------------------------------------------------
# Transfer pipeline
# --------------------------------------------------------------------------


class StageFuture:
    """Tiny future for a prefetched stage-in (WorkerPool has no futures)."""

    __slots__ = ("_event", "_value", "_error")

    def __init__(self):
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    def _set(self, value: Any, error: Optional[BaseException]) -> None:
        self._value = value
        self._error = error
        self._event.set()

    def result(self) -> Any:
        self._event.wait()
        if self._error is not None:
            raise self._error
        return self._value


class TransferPipeline:
    """Per-run double-buffered staging coordinator.

    ``prefetch(fn)`` runs a stage-in on a pooled thread and returns a
    :class:`StageFuture` -- issued for packet *k+1* while packet *k*
    computes, so the device thread's next dispatch is already staged.
    ``stage_out(fn, nbytes)`` hands a commit (device->host conversion +
    in-place write) to the single committer thread; commits are FIFO,
    overlapped with subsequent computes.  ``flush()`` blocks until every
    commit landed; ``close()`` stops the committer.

    **Adaptive handoff:** a thread handoff costs a wakeup (tens to
    hundreds of microseconds on an oversubscribed host), so overlapping
    only pays above a staging-size crossover -- the same economics as a
    DMA engine.  Commits smaller than ``async_threshold_bytes`` run
    inline on the calling thread; larger ones go to the committer.

    ``h2d_busy_s`` / ``d2h_busy_s`` accumulate the staging work the
    pipeline handled (observability; the run's *phase* windows are
    stamped by its PhaseClock).
    """

    # hand-picked crossover for the reference container; sessions inject a
    # calibrated value (``async_threshold_bytes=`` / ``tuned=``) per host
    DEFAULT_ASYNC_THRESHOLD_BYTES = 256 << 10

    def __init__(self, pool, async_threshold_bytes: Optional[int] = None):
        if async_threshold_bytes is None:
            async_threshold_bytes = self.DEFAULT_ASYNC_THRESHOLD_BYTES
        if int(async_threshold_bytes) < 0:
            raise ValueError(f"async_threshold_bytes must be >= 0, "
                             f"got {async_threshold_bytes}")
        self._pool = pool            # WorkerPool-like: submit(fn) -> Event
        self.async_threshold_bytes = int(async_threshold_bytes)
        self._cv = threading.Condition()
        self._jobs: deque = deque()
        self._closed = False
        self._draining = 0           # commits currently executing
        self._done_event: Optional[threading.Event] = None
        self._time_lock = threading.Lock()
        self.h2d_busy_s = 0.0
        self.d2h_busy_s = 0.0
        self.commits = 0
        self.prefetches = 0

    # -- stage-in ----------------------------------------------------------
    def prefetch(self, fn: Callable[[], Any]) -> StageFuture:
        fut = StageFuture()

        def run():
            t0 = time.perf_counter()
            try:
                fut._set(fn(), None)
            except BaseException as e:  # surfaced at fut.result()
                fut._set(None, e)
            with self._time_lock:
                self.h2d_busy_s += time.perf_counter() - t0
                self.prefetches += 1

        self._pool.submit(run)
        return fut

    def note_h2d(self, seconds: float) -> None:
        """Credit inline stage-in work (the unprefetched first packet)."""
        with self._time_lock:
            self.h2d_busy_s += seconds

    # -- stage-out ---------------------------------------------------------
    def start(self) -> None:
        self._done_event = self._pool.submit(self._commit_loop)

    def stage_out(self, fn: Callable[[], None],
                  nbytes: Optional[int] = None) -> None:
        """Commit a packet result.  Small commits (below the async
        threshold) run inline -- a thread wakeup would cost more than the
        copy it hides; large ones overlap on the committer thread."""
        if nbytes is not None and nbytes < self.async_threshold_bytes:
            t0 = time.perf_counter()
            try:
                fn()
            finally:
                with self._time_lock:
                    self.d2h_busy_s += time.perf_counter() - t0
                    self.commits += 1
            return
        with self._cv:
            if self._closed:
                raise RuntimeError("TransferPipeline is closed")
            self._jobs.append(fn)
            self._cv.notify_all()

    def _commit_loop(self) -> None:
        while True:
            with self._cv:
                while not self._jobs and not self._closed:
                    self._cv.wait()
                if not self._jobs:
                    return  # closed and drained
                fn = self._jobs.popleft()
                self._draining += 1
            t0 = time.perf_counter()
            try:
                fn()  # commit closures handle their own errors
            finally:
                with self._time_lock:
                    self.d2h_busy_s += time.perf_counter() - t0
                    self.commits += 1
                with self._cv:
                    self._draining -= 1
                    self._cv.notify_all()

    def flush(self) -> None:
        """Block until the commit queue is empty and the committer idle."""
        with self._cv:
            while self._jobs or self._draining:
                self._cv.wait()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._done_event is not None:
            self._done_event.wait()
