"""Region: the NDRange work-description type (paper's offload geometry).

The paper distinguishes two offload styles — **binary** (a whole program
offloaded once, init -> teardown) and **ROI** (a *region of interest*
re-offloaded repeatedly against persistent state) — and its optimizations
pay 7.5% in the former but 17.4% in the latter.  Expressing that requires
the work geometry to be a first-class API type instead of a flat
``total_work`` integer:

* ``Dim(offset, size, lws)`` — one NDRange dimension: a half-open range
  ``[offset, offset + size)`` with an ``lws`` alignment unit (the local
  work size of that dimension).
* ``Region(dims)`` — 1-D or 2-D NDRange.  1-D regions are the classic
  work-group line the schedulers always carved; 2-D regions describe image
  workloads (rows x cols) and are carved as **row panels**: contiguous
  ``lws``-aligned runs of dim-0 spanning the full dim-1 extent, so every
  scheduler's 1-D carving law applies unchanged along dim 0.

Regions are value types (frozen, hashable).  Sub-regions (the paper's
ROIs) are validated with :meth:`Region.contains` (geometric containment)
and :meth:`Region.aligned_within` (per-dimension lws alignment relative
to the enclosing workload), so an ROI submit can never carve work the
registered buffers do not back.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple, Union

__all__ = ["Dim", "Region", "as_region"]


@dataclass(frozen=True)
class Dim:
    """One NDRange dimension: ``[offset, offset + size)``, lws-aligned."""
    offset: int
    size: int
    lws: int = 1

    def __post_init__(self):
        if self.offset < 0:
            raise ValueError(f"Dim offset must be >= 0, got {self.offset}")
        if self.size <= 0:
            raise ValueError(f"Dim size must be positive, got {self.size}")
        if self.lws <= 0:
            raise ValueError(f"Dim lws must be positive, got {self.lws}")

    @property
    def end(self) -> int:
        return self.offset + self.size

    def contains(self, other: "Dim") -> bool:
        return self.offset <= other.offset and other.end <= self.end

    def aligned_within(self, outer: "Dim") -> bool:
        """True if this dim starts on an ``outer.lws`` boundary (relative
        to ``outer``) and covers whole lws units — except a final
        remainder, which may stop exactly at ``outer.end``."""
        rel = self.offset - outer.offset
        if rel % outer.lws != 0:
            return False
        return self.size % outer.lws == 0 or self.end == outer.end


@dataclass(frozen=True)
class Region:
    """A 1-D or 2-D NDRange (dim 0 is the carved axis; dim 1, when
    present, is the row width carried whole in every packet)."""
    dims: Tuple[Dim, ...]

    def __post_init__(self):
        dims = tuple(self.dims)
        if not (1 <= len(dims) <= 2):
            raise ValueError(
                f"Region supports 1-D and 2-D NDRanges, got {len(dims)} dims")
        if not all(isinstance(d, Dim) for d in dims):
            raise TypeError("Region dims must be Dim instances")
        object.__setattr__(self, "dims", dims)

    # -- constructors -------------------------------------------------------
    @classmethod
    def line(cls, size: int, lws: int = 1, offset: int = 0) -> "Region":
        """1-D region: ``size`` work-groups from ``offset``."""
        return cls((Dim(offset, size, lws),))

    @classmethod
    def rect(cls, rows: int, cols: int, *,
             lws: Tuple[int, int] = (1, 1),
             offset: Tuple[int, int] = (0, 0)) -> "Region":
        """2-D region: ``rows x cols`` from ``offset`` (row-major)."""
        return cls((Dim(offset[0], rows, lws[0]),
                    Dim(offset[1], cols, lws[1])))

    # -- geometry -----------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.dims)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(d.size for d in self.dims)

    @property
    def offsets(self) -> Tuple[int, ...]:
        return tuple(d.offset for d in self.dims)

    @property
    def work(self) -> int:
        """Total work-items (product of per-dimension sizes)."""
        w = 1
        for d in self.dims:
            w *= d.size
        return w

    def contains(self, other: "Region") -> bool:
        return (self.ndim == other.ndim
                and all(a.contains(b)
                        for a, b in zip(self.dims, other.dims)))

    def aligned_within(self, outer: "Region") -> bool:
        """Per-dimension lws alignment of this region inside ``outer``."""
        return (self.ndim == outer.ndim
                and all(a.aligned_within(b)
                        for a, b in zip(self.dims, outer.dims)))

    def row_panel(self, rel_offset: int, size: int) -> "Region":
        """The packet geometry: ``size`` dim-0 units starting ``rel_offset``
        units into this region, spanning the full remaining dims."""
        d0 = self.dims[0]
        if rel_offset < 0 or rel_offset + size > d0.size:
            raise ValueError(
                f"row panel [{rel_offset}, {rel_offset + size}) outside "
                f"dim-0 extent [0, {d0.size})")
        return Region((Dim(d0.offset + rel_offset, size, d0.lws),)
                      + self.dims[1:])

    def __repr__(self) -> str:
        spans = "x".join(f"[{d.offset}:{d.end})/{d.lws}" for d in self.dims)
        return f"Region({spans})"


def as_region(work: Union[int, Region], lws: int = 1) -> Region:
    """Normalize the scheduler/Program work argument: a bare int is the
    legacy flat work-group count (1-D region at offset 0)."""
    if isinstance(work, Region):
        return work
    return Region.line(int(work), lws=lws)
