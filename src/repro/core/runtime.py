"""Dispatch engine: EngineCL's Runtime / Scheduler / Device threads in JAX.

Mirrors the paper's Fig. 2 architecture:

  * the **Runtime** (the session's dispatcher, repro.api.session) discovers
    executors, owns buffers/executable caches and orchestrates runs;
  * the **Scheduler** is the atomic packet queue (core/scheduler.py);
  * one **Device thread** per device group pulls packets, executes the
    program's range function and commits results.

This module is the *internal* layer of that stack: ``Program`` (the work
description), ``WorkerPool`` (session-scoped reusable device threads) and
``_RunContext`` (the per-submitted-program dispatch state).  The public
surface is the tiered API in ``repro.api``:

  * Tier-1 ``coexec(program, devices=...)`` — one call, paper-tuned
    defaults;
  * Tier-2 ``EngineSession`` — executable cache + buffer registry + elastic
    membership shared across *many* programs, ``submit() -> RunHandle``;
  * Tier-3 ``register_scheduler`` / ``DevicePolicy`` / ``BufferPolicy``
    extension points.

The paper's two runtime optimizations remain real, independent code paths:

  * parallel init (the old ``opt_init``) — device threads AOT-compile their
    executables *in parallel*, overlapped with the Runtime's scheduler
    preparation; compiled executables are cached on the session and reused
    across submits (the paper's "reuse of costly OpenCL primitives").
  * registered buffers (the old ``opt_buffers``, now
    ``BufferPolicy.REGISTERED``) — inputs are registered once per device
    (zero-copy slice views feed each packet), outputs are committed in
    place.  ``BufferPolicy.PER_PACKET`` reproduces the worst practice the
    paper's drivers exhibited: every packet copies, results are assembled
    from per-packet copies at the end.

Timing modes per the paper: ``binary`` (init -> teardown) and ``roi``
(transfer + compute only).

Fault tolerance: a device thread that raises (or whose DeviceGroup is
marked dead) has its in-flight packet requeued with provenance preserved
(same ``seq``, ``retried=True``); remaining devices absorb the work.

``Engine`` remains as a deprecated one-PR compatibility shim over
``EngineSession`` for out-of-tree users.
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.device import DeviceFailure, DeviceGroup
from repro.core.metrics import RunResult
from repro.core.scheduler import DeviceProfile, SchedulerBase, make_scheduler


@dataclass
class Program:
    """A single massively data-parallel task (the paper's redefined
    'program'): inputs, an output pattern, and a range kernel."""
    name: str
    total_work: int                       # in work-groups
    lws: int                              # work-group size (alignment unit)
    # build(device_group) -> fn(offset, size) -> np.ndarray (the range result)
    build: Optional[Callable[[DeviceGroup], Callable[[int, int], Any]]] = None
    # output row-width: result rows per work-group (paper's "out pattern")
    out_rows_per_wg: int = 1
    out_cols: int = 1
    out_dtype: Any = np.float32

    def validate(self) -> "Program":
        """Raise a clear ValueError now instead of a TypeError deep inside a
        device thread.  Called at session submit / engine construction."""
        if self.build is None or not callable(self.build):
            raise ValueError(
                f"Program {self.name!r}: 'build' must be a callable "
                "build(device) -> fn(offset, size); got "
                f"{self.build!r}.  Construct Programs via "
                "repro.core.programs or pass build= explicitly.")
        if self.total_work <= 0:
            raise ValueError(f"Program {self.name!r}: total_work must be "
                             f"positive, got {self.total_work}")
        if self.lws <= 0:
            raise ValueError(f"Program {self.name!r}: lws must be positive, "
                             f"got {self.lws}")
        return self


class WorkerPool:
    """Session-scoped pool of reusable device threads.

    Device threads are *pulled from the pool* per run instead of created per
    run: a session serving many back-to-back submits reuses the same OS
    threads (the thread-management analogue of the paper's primitive reuse).

    Deliberately NOT concurrent.futures.ThreadPoolExecutor: every run parks
    all n device threads on one Barrier, so the pool must grow unboundedly
    with the fleet — a bounded executor whose max_workers falls below the
    device count would deadlock the barrier.
    """

    def __init__(self, name: str = "coexec"):
        self._name = name
        self._lock = threading.Lock()
        self._idle: List["_Worker"] = []
        self._spawned = 0
        self._closed = False

    def submit(self, fn: Callable[[], None]) -> threading.Event:
        """Run ``fn`` on a pooled thread; returns its completion event."""
        with self._lock:
            if self._closed:
                raise RuntimeError("WorkerPool is closed")
            worker = self._idle.pop() if self._idle else None
            if worker is None:
                self._spawned += 1
                worker = _Worker(self, f"{self._name}-dev-{self._spawned}")
        return worker.run(fn)

    def _recycle(self, worker: "_Worker") -> None:
        with self._lock:
            if self._closed:
                worker.stop()
            else:
                self._idle.append(worker)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            idle, self._idle = self._idle, []
        for w in idle:
            w.stop()

    @property
    def size(self) -> int:
        return self._spawned


class _Worker:
    """One reusable pool thread: blocks on a job box, runs, recycles."""

    def __init__(self, pool: WorkerPool, name: str):
        self._pool = pool
        self._job: Optional[Tuple[Callable[[], None], threading.Event]] = None
        self._wake = threading.Semaphore(0)
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)
        self._thread.start()

    def run(self, fn: Callable[[], None]) -> threading.Event:
        done = threading.Event()
        self._job = (fn, done)
        self._wake.release()
        return done

    def stop(self) -> None:
        self._job = None
        self._wake.release()

    def _loop(self) -> None:
        while True:
            self._wake.acquire()
            job, self._job = self._job, None
            if job is None:
                return
            fn, done = job
            try:
                fn()
            except BaseException:
                # a job must never corpse the pool thread: device_thread
                # handles its own errors; this is the last-resort guard that
                # keeps a recycled worker alive for the next submit
                pass
            finally:
                done.set()
                self._pool._recycle(self)


class _RunContext:
    """Dispatch state for ONE submitted program (the session's inner engine).

    Owns the scheduler instance, the output buffer (or a caller-supplied
    ``collect`` hook for non-array reductions, e.g. gradient accumulation),
    and the per-run device bookkeeping.  Device threads are pulled from the
    session's WorkerPool; compiled executables come from ``compile_fn``
    (the session's cache).
    """

    def __init__(self, program: Program, devices: Sequence[DeviceGroup], *,
                 scheduler: str, scheduler_kwargs: Dict,
                 compile_fn: Callable[[DeviceGroup], Callable],
                 pool: WorkerPool,
                 registered_buffers: bool = True,
                 parallel_init: bool = True,
                 reset_device_stats: bool = True,
                 powers: Optional[List[float]] = None,
                 collect: Optional[Callable] = None):
        self.program = program
        self.devices = list(devices)
        if not self.devices:
            raise RuntimeError(f"{program.name}: no devices to dispatch to")
        self.scheduler_name = scheduler
        self.scheduler_kwargs = dict(scheduler_kwargs)
        self.compile_fn = compile_fn
        self.pool = pool
        self.registered_buffers = registered_buffers
        self.parallel_init = parallel_init
        self.reset_device_stats = reset_device_stats
        self.powers = list(powers) if powers is not None else None
        self.collect = collect

    def execute(self) -> RunResult:
        t_bin0 = time.perf_counter()
        prog = self.program
        n = len(self.devices)
        if self.reset_device_stats:
            for d in self.devices:
                d.packets_done = 0
                d.busy_time = 0.0
                d.finish_time = 0.0
                d.dead = False

        output = None
        if self.collect is None:
            out_rows = prog.total_work * prog.out_rows_per_wg
            output = np.zeros((out_rows, prog.out_cols), prog.out_dtype)
        profiles = [DeviceProfile(d.name,
                                  (self.powers[i] if self.powers else
                                   (d.throughput or 1.0 / d.throttle)))
                    for i, d in enumerate(self.devices)]
        executed: List = []
        errors: List[BaseException] = []
        exec_lock = threading.Lock()
        state: Dict[str, Any] = {"sched": None, "roi0": None, "inflight": 0}
        ready = threading.Barrier(n + 1)
        fns: List[Optional[Callable]] = [None] * n
        t0_busy = [d.busy_time for d in self.devices]

        def device_thread(i: int):
            dev = self.devices[i]
            if self.parallel_init:
                # parallel AOT compile, overlapped with Runtime's prep
                try:
                    fns[i] = self.compile_fn(dev)
                except Exception as e:      # compile failure = dead device
                    dev.dead = True
                    with exec_lock:
                        errors.append(e)
            ready.wait()
            sched: SchedulerBase = state["sched"]
            if sched is None:
                return                        # scheduler construction failed
            fn = fns[i]
            if fn is None:
                sched.mark_dead(i)            # compile failed: release work
                return
            while True:
                with exec_lock:
                    pkt = sched.next_packet(i)
                    if pkt is not None:
                        state["inflight"] += 1
                if pkt is None:
                    # another device may still fail and requeue its packet:
                    # only exit once nothing is in flight anywhere
                    with exec_lock:
                        drained = (state["inflight"] == 0
                                   and sched.remaining() == 0)
                        alive_others = any(not d.dead for j, d in
                                           enumerate(self.devices) if j != i)
                    if drained or not alive_others:
                        break
                    time.sleep(1e-3)
                    continue
                try:
                    res, wg_s = dev.run_packet(fn, pkt.offset, pkt.size)
                except DeviceFailure:
                    with exec_lock:
                        sched.requeue(pkt)
                        sched.mark_dead(i)
                        state["inflight"] -= 1
                    break
                except Exception as e:
                    # unexpected executor error: same fault-tolerance path as
                    # a device failure, but the error is surfaced if the run
                    # cannot complete without this device
                    dev.dead = True
                    with exec_lock:
                        errors.append(e)
                        sched.requeue(pkt)
                        sched.mark_dead(i)
                        state["inflight"] -= 1
                    break
                try:
                    if hasattr(sched, "observe"):
                        sched.observe(i, wg_s)
                    if self.collect is not None:
                        with exec_lock:
                            self.collect(pkt, res, dev)
                            executed.append(("pkt", pkt))
                            state["inflight"] -= 1
                        continue
                    r0 = pkt.offset * prog.out_rows_per_wg
                    r1 = (pkt.offset + pkt.size) * prog.out_rows_per_wg
                    res = np.asarray(res).reshape(r1 - r0, prog.out_cols)
                    if self.registered_buffers:
                        output[r0:r1] = res           # in-place commit
                    else:
                        with exec_lock:
                            executed.append(("copy", r0, r1,
                                             np.array(res, copy=True)))
                    with exec_lock:
                        executed.append(("pkt", pkt))
                        state["inflight"] -= 1
                except Exception as e:
                    # commit-path failure (mis-shaped result, collect hook,
                    # observe): must release the in-flight packet and mark
                    # the device dead, or the surviving devices spin forever
                    dev.dead = True
                    with exec_lock:
                        errors.append(e)
                        sched.requeue(pkt)
                        sched.mark_dead(i)
                        state["inflight"] -= 1
                    break
            dev.finish_time = time.perf_counter() - state["roi0"] \
                if state["roi0"] else 0.0

        def start_threads() -> List[threading.Event]:
            return [self.pool.submit(_bind(device_thread, i))
                    for i in range(n)]

        if self.parallel_init:
            done_events = start_threads()
            # Runtime prepares the scheduler concurrently with device compiles
            try:
                state["sched"] = make_scheduler(self.scheduler_name,
                                                prog.total_work, prog.lws,
                                                profiles,
                                                **self.scheduler_kwargs)
            except BaseException:
                # release the pooled threads parked at the barrier (they see
                # sched=None and exit) before surfacing the error — a raise
                # here must not wedge n workers forever
                ready.wait()
                for ev in done_events:
                    ev.wait()
                raise
            state["roi0"] = time.perf_counter()
            ready.wait()
        else:
            # sequential: discovery+compile each device, then scheduler
            for i, d in enumerate(self.devices):
                try:
                    fns[i] = self.compile_fn(d)
                except Exception as e:
                    d.dead = True
                    errors.append(e)
            state["sched"] = make_scheduler(self.scheduler_name,
                                            prog.total_work, prog.lws,
                                            profiles, **self.scheduler_kwargs)
            state["roi0"] = time.perf_counter()
            done_events = start_threads()
            ready.wait()
        for ev in done_events:
            ev.wait()
        roi_time = time.perf_counter() - state["roi0"]
        if state["sched"].remaining() > 0:
            err = RuntimeError(
                f"{prog.name}: {state['sched'].remaining()} work-groups "
                "unprocessed — all devices failed")
            if errors:
                raise err from errors[0]
            raise err
        if self.collect is None and not self.registered_buffers:
            # assemble results from per-packet copies (bulk copy at the end)
            for item in executed:
                if item[0] == "copy":
                    _, r0, r1, arr = item
                    output[r0:r1] = arr
        binary_time = time.perf_counter() - t_bin0
        packets = [it[1] for it in executed if it[0] == "pkt"]
        result = RunResult(
            total_time=roi_time,
            device_busy=[d.busy_time - b0 for d, b0 in
                         zip(self.devices, t0_busy)],
            device_finish=[d.finish_time for d in self.devices],
            packets=packets,
            binary_time=binary_time,
            aborted_devices=sum(1 for d in self.devices if d.dead),
        )
        result.output = output  # type: ignore[attr-defined]
        return result


def _bind(fn: Callable, i: int) -> Callable[[], None]:
    """Bind the device index without a late-binding closure bug."""
    def bound():
        fn(i)
    return bound


class Engine:
    """DEPRECATED one-PR compatibility shim over ``repro.api.EngineSession``.

    ``Engine(program, devices, ...)`` owns a private single-program session;
    ``run()`` is ``session.submit(program).result()``.  Migrate:

        Engine(prog, devs, scheduler=s).run()         # old
        coexec(prog, devs, scheduler=s)               # new Tier-1
        EngineSession(devs, scheduler=s).run(prog)    # new Tier-2

    See docs/api.md for the full migration guide.  This shim will be
    removed next PR.
    """

    def __init__(self, program: Program, devices: Sequence[DeviceGroup], *,
                 scheduler: str = "hguided_opt",
                 scheduler_kwargs: Optional[Dict] = None,
                 opt_init: bool = True, opt_buffers: bool = True,
                 init_cost_s: float = 0.0):
        warnings.warn(
            "Engine is deprecated; use repro.api.coexec (Tier-1) or "
            "repro.api.EngineSession (Tier-2).  See docs/api.md.",
            DeprecationWarning, stacklevel=2)
        from repro.api.policies import BufferPolicy
        from repro.api.session import EngineSession
        self.program = program.validate()
        self._session = EngineSession(
            devices, scheduler=scheduler, scheduler_kwargs=scheduler_kwargs,
            buffer_policy=BufferPolicy.from_flag(opt_buffers),
            parallel_init=opt_init, cache_executables=opt_init,
            init_cost_s=init_cost_s)

    # -- old surface, delegated -------------------------------------------
    @property
    def devices(self) -> List[DeviceGroup]:
        return self._session.devices

    @property
    def _compiled(self) -> Dict:
        """Old tests/tools poked the cache; expose the session's view keyed
        by device name (this shim serves exactly one program)."""
        return {dev: fn for (_, dev), fn
                in self._session.executables.items()}

    def add_device(self, dev: DeviceGroup) -> None:
        self._session.add_device(dev)

    def remove_device(self, name: str) -> None:
        self._session.remove_device(name)

    def run(self, *, powers: Optional[List[float]] = None) -> RunResult:
        return self._session.submit(self.program, powers=powers).result()

    def close(self) -> None:
        self._session.close()

    def __del__(self):
        # the old Engine held no threads; don't let the shim leak a
        # dispatcher + worker pool per instance in out-of-tree loops
        try:
            self._session.close()
        except Exception:
            pass
