"""The Engine: EngineCL's Runtime / Scheduler / Device threads in JAX.

Mirrors the paper's Fig. 2 architecture:

  * the **Runtime** (this thread) discovers executors, owns buffers and
    orchestrates the run;
  * the **Scheduler** is the atomic packet queue (core/scheduler.py);
  * one **Device thread** per device group pulls packets, executes the
    program's range function and commits results.

The paper's two runtime optimizations are implemented as real code paths,
toggled independently so their contribution can be measured (fig6 bench):

  * ``opt_init``   — device threads start immediately and AOT-compile their
    executables *in parallel*, overlapped with input preparation; compiled
    executables are cached on the Engine and *reused* across runs (the
    paper's "reuse of costly OpenCL primitives").  Without the flag,
    discovery -> compile(dev0..devN) -> buffer setup -> scheduler start run
    strictly sequentially and caches are dropped.
  * ``opt_buffers`` — inputs are registered once per device as read-only
    buffers (zero-copy slice views feed each packet; device_put happens
    once), outputs are committed in place into a preallocated result.
    Without the flag every packet bulk-copies the full input set and
    results are assembled from per-packet copies at the end (the worst
    practice the paper's drivers exhibited).

Timing modes per the paper: ``binary`` (engine construction -> teardown)
and ``roi`` (transfer + compute only).

Fault tolerance: a device thread that raises (or whose DeviceGroup is marked
dead) has its in-flight packet requeued; remaining devices absorb the work.
Elastic scaling: ``add_device`` / ``remove_device`` between runs renormalize
the scheduler's computing powers.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.device import DeviceFailure, DeviceGroup
from repro.core.metrics import RunResult
from repro.core.scheduler import DeviceProfile, SchedulerBase, make_scheduler


@dataclass
class Program:
    """A single massively data-parallel task (the paper's redefined
    'program'): inputs, an output pattern, and a range kernel."""
    name: str
    total_work: int                       # in work-groups
    lws: int                              # work-group size (alignment unit)
    # build(device_group) -> fn(offset, size) -> np.ndarray (the range result)
    build: Callable[[DeviceGroup], Callable[[int, int], Any]] = None
    # output row-width: result rows per work-group (paper's "out pattern")
    out_rows_per_wg: int = 1
    out_cols: int = 1
    out_dtype: Any = np.float32


class Engine:
    def __init__(self, program: Program, devices: Sequence[DeviceGroup], *,
                 scheduler: str = "hguided_opt",
                 scheduler_kwargs: Optional[Dict] = None,
                 opt_init: bool = True, opt_buffers: bool = True,
                 init_cost_s: float = 0.0):
        self.program = program
        self.devices = list(devices)
        self.scheduler_name = scheduler
        self.scheduler_kwargs = dict(scheduler_kwargs or {})
        self.opt_init = opt_init
        self.opt_buffers = opt_buffers
        # emulated fixed driver-primitive cost paid per (re)initialization;
        # with opt_init it is paid once and amortized by the executable cache
        self.init_cost_s = init_cost_s
        self._compiled: Dict[str, Callable] = {}   # executable cache
        self._lock = threading.Lock()

    # -- elastic membership -------------------------------------------------
    def add_device(self, dev: DeviceGroup) -> None:
        self.devices.append(dev)

    def remove_device(self, name: str) -> None:
        self.devices = [d for d in self.devices if d.name != name]
        self._compiled.pop(name, None)

    # -- init paths ----------------------------------------------------------
    def _compile_for(self, dev: DeviceGroup) -> Callable:
        key = dev.name
        if self.opt_init and key in self._compiled:
            return self._compiled[key]
        if self.init_cost_s:
            time.sleep(self.init_cost_s)          # driver primitive cost
        fn = self.program.build(dev)
        if self.opt_init:
            self._compiled[key] = fn
        return fn

    # -- main entry ----------------------------------------------------------
    def run(self, *, powers: Optional[List[float]] = None) -> RunResult:
        t_bin0 = time.perf_counter()
        prog = self.program
        n = len(self.devices)
        for d in self.devices:
            d.packets_done = 0
            d.busy_time = 0.0
            d.finish_time = 0.0
            d.dead = False

        out_rows = prog.total_work * prog.out_rows_per_wg
        output = np.zeros((out_rows, prog.out_cols), prog.out_dtype)
        profiles = [DeviceProfile(d.name,
                                  (powers[i] if powers else
                                   (d.throughput or 1.0 / d.throttle)))
                    for i, d in enumerate(self.devices)]
        executed: List = []
        exec_lock = threading.Lock()
        state: Dict[str, Any] = {"sched": None, "roi0": None, "inflight": 0}
        ready = threading.Barrier(n + 1)
        fns: List[Optional[Callable]] = [None] * n

        def device_thread(i: int):
            dev = self.devices[i]
            if self.opt_init:
                # parallel AOT compile, overlapped with Runtime's buffer prep
                fns[i] = self._compile_for(dev)
            ready.wait()
            sched: SchedulerBase = state["sched"]
            fn = fns[i]
            while True:
                with exec_lock:
                    pkt = sched.next_packet(i)
                    if pkt is not None:
                        state["inflight"] += 1
                if pkt is None:
                    # another device may still fail and requeue its packet:
                    # only exit once nothing is in flight anywhere
                    with exec_lock:
                        drained = (state["inflight"] == 0
                                   and sched.remaining() == 0)
                        alive_others = any(not d.dead for j, d in
                                           enumerate(self.devices) if j != i)
                    if drained or not alive_others:
                        break
                    time.sleep(1e-3)
                    continue
                try:
                    res, wg_s = dev.run_packet(fn, pkt.offset, pkt.size)
                except DeviceFailure:
                    with exec_lock:
                        sched.requeue(pkt)
                        state["inflight"] -= 1
                    break
                if hasattr(sched, "observe"):
                    sched.observe(i, wg_s)
                r0 = pkt.offset * prog.out_rows_per_wg
                r1 = (pkt.offset + pkt.size) * prog.out_rows_per_wg
                res = np.asarray(res).reshape(r1 - r0, prog.out_cols)
                if self.opt_buffers:
                    output[r0:r1] = res           # in-place commit
                else:
                    with exec_lock:
                        executed.append(("copy", r0, r1, np.array(res, copy=True)))
                with exec_lock:
                    executed.append(("pkt", pkt))
                    state["inflight"] -= 1
            dev.finish_time = time.perf_counter() - state["roi0"] \
                if state["roi0"] else 0.0

        threads = [threading.Thread(target=device_thread, args=(i,))
                   for i in range(n)]
        if self.opt_init:
            for t in threads:
                t.start()
            # Runtime prepares the scheduler concurrently with device compiles
            state["sched"] = make_scheduler(self.scheduler_name,
                                            prog.total_work, prog.lws,
                                            profiles, **self.scheduler_kwargs)
            state["roi0"] = time.perf_counter()
            ready.wait()
        else:
            # sequential: discovery+compile each device, then scheduler
            for i, d in enumerate(self.devices):
                fns[i] = self._compile_for(d)
            state["sched"] = make_scheduler(self.scheduler_name,
                                            prog.total_work, prog.lws,
                                            profiles, **self.scheduler_kwargs)
            state["roi0"] = time.perf_counter()
            for t in threads:
                t.start()
            ready.wait()
        for t in threads:
            t.join()
        roi_time = time.perf_counter() - state["roi0"]
        if state["sched"].remaining() > 0:
            raise RuntimeError(
                f"{prog.name}: {state['sched'].remaining()} work-groups "
                "unprocessed — all devices failed")
        if not self.opt_buffers:
            # assemble results from per-packet copies (bulk copy at the end)
            for item in executed:
                if item[0] == "copy":
                    _, r0, r1, arr = item
                    output[r0:r1] = arr
        binary_time = time.perf_counter() - t_bin0
        packets = [it[1] for it in executed if it[0] == "pkt"]
        result = RunResult(
            total_time=roi_time,
            device_busy=[d.busy_time for d in self.devices],
            device_finish=[d.finish_time for d in self.devices],
            packets=packets,
            binary_time=binary_time,
            aborted_devices=sum(1 for d in self.devices if d.dead),
        )
        result.output = output  # type: ignore[attr-defined]
        return result
