"""Dispatch engine: EngineCL's Runtime / Scheduler / Device threads in JAX.

Mirrors the paper's Fig. 2 architecture:

  * the **Runtime** (the session's dispatcher, repro.api.session) discovers
    executors, owns buffers/executable caches and orchestrates runs;
  * the **Scheduler** is the atomic packet queue (core/scheduler.py);
  * one **Device thread** per device group pulls packets, executes the
    program's range function and commits results.

This module is the *internal* layer of that stack: ``Program`` (the work
description), ``WorkerPool`` (session-scoped reusable device threads) and
``_RunContext`` (the per-submitted-program dispatch state).  The public
surface is the tiered API in ``repro.api``:

  * Tier-1 ``coexec(program, devices=...)`` — one call, paper-tuned
    defaults;
  * Tier-2 ``EngineSession`` — executable cache + buffer registry + elastic
    membership shared across *many* programs, ``submit() -> RunHandle``;
  * Tier-3 ``register_scheduler`` / ``DevicePolicy`` / ``BufferPolicy``
    extension points.

The paper's two runtime optimizations remain real, independent code paths:

  * parallel init (the old ``opt_init``) — device threads AOT-compile their
    executables *in parallel*, overlapped with the Runtime's scheduler
    preparation; compiled executables are cached on the session and reused
    across submits (the paper's "reuse of costly OpenCL primitives").
  * registered buffers (the old ``opt_buffers``, now
    ``BufferPolicy.REGISTERED``) — inputs are registered once per device
    (zero-copy slice views feed each packet), outputs are committed in
    place.  ``BufferPolicy.PER_PACKET`` reproduces the worst practice the
    paper's drivers exhibited: every packet copies, results are assembled
    from per-packet copies at the end.

Timing modes per the paper: ``binary`` (init -> teardown) and ``roi``
(transfer + compute only) — both are measured per run as a
:class:`repro.core.metrics.PhaseBreakdown` stamped by the run's
:class:`PhaseClock` (one timing implementation for all phases).

Work geometry: a Program's work is a :class:`repro.core.region.Region`
(1-D or 2-D NDRange).  1-D range kernels keep the classic
``fn(offset, size)`` contract; 2-D programs build
``fn(row0, n_rows, col0, n_cols)`` tile kernels, and schedulers carve
their regions as row panels.  A run may cover a *sub-region* of the
program (the paper's ROI offloading) — the session validates containment
and per-dimension lws alignment before dispatch.

Fault tolerance: a device thread that raises (or whose DeviceGroup is
marked dead) has its in-flight packet requeued with provenance preserved
(same ``seq``, ``retried=True``); remaining devices absorb the work.

Dispatch modes: ``dispatch="leased"`` (default) pulls packets through the
scheduler's lease API — one global lock crossing buys a whole per-device
packet plan, and device threads pop their local lease uncontended.
``dispatch="per_packet"`` is the classic one-lock-per-packet hand-off,
kept as the measurable baseline (``benchmarks/sched_overhead.py``).
Either way the run stamps ``RunResult.sched_wait_s`` — per-device wall
time blocked on the scheduler hand-off (lock waits, carves, steals).
The exactly-once drain test is the scheduler's own ``drained()``
protocol (acquire/release claims + a retry-epoch check), so the engine
no longer serializes every pull through a run-global lock.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.device import DeviceFailure, DeviceGroup
from repro.core.membuf import BufferArena, BufferPolicy, TransferPipeline
from repro.core.metrics import PhaseBreakdown, RunResult
from repro.core.region import Region
from repro.core.scheduler import DeviceProfile, SchedulerBase, make_scheduler
from repro.energy.meter import EnergyMeter


class PhaseClock:
    """Named wall-clock marks for one run's phase accounting.

    The runtime's single timing implementation: every phase boundary is a
    ``mark``; durations are read back with ``between``/``since``.  Unset
    marks read as 0.0 so partial runs (e.g. scheduler construction
    failures) never crash the accounting path.
    """

    def __init__(self):
        self._t: Dict[str, float] = {}
        self._once = threading.Lock()

    def mark(self, name: str) -> float:
        t = time.perf_counter()
        self._t[name] = t
        return t

    def mark_once(self, name: str) -> float:
        """Set ``name`` only if unset (first caller wins; thread-safe) —
        e.g. the ROI mark stamped by whichever device computes first."""
        with self._once:
            t = self._t.get(name)
            if t is None:
                t = self.mark(name)
            return t

    def at(self, name: str) -> Optional[float]:
        return self._t.get(name)

    def since(self, name: str) -> float:
        t = self._t.get(name)
        return 0.0 if t is None else time.perf_counter() - t

    def between(self, a: str, b: str) -> float:
        ta, tb = self._t.get(a), self._t.get(b)
        if ta is None or tb is None:
            return 0.0
        return max(0.0, tb - ta)


@dataclass
class Program:
    """A single massively data-parallel task (the paper's redefined
    'program'): inputs, an output pattern, and a range kernel over a
    1-D or 2-D work Region."""
    name: str
    total_work: int = 0                   # dim-0 work-groups (mirrors region)
    lws: int = 1                          # dim-0 alignment unit (mirrors)
    # build(device_group) -> range executable:
    #   1-D: fn(offset, size)                   -> np.ndarray
    #   2-D: fn(row0, n_rows, col0, n_cols)     -> np.ndarray tile
    build: Optional[Callable[[DeviceGroup], Callable[..., Any]]] = None
    # output row-width: result rows per dim-0 work-group (paper's "out
    # pattern"); for 2-D programs out_cols is per dim-1 work-item
    out_rows_per_wg: int = 1
    out_cols: int = 1
    out_dtype: Any = np.float32
    region: Optional[Region] = None       # full NDRange (None = legacy 1-D)
    # read-only input footprint (bytes).  Registered/pooled buffers stage
    # it once per device; BufferPolicy.PER_PACKET re-stages it on every
    # packet (a real host copy of this size — the paper's "unnecessary
    # complete bulk copies of memory regions", the sim's BULK_COPY term).
    in_bytes: int = 0

    def __post_init__(self):
        if self.region is not None:
            # keep the legacy flat fields in lockstep with dim 0 so every
            # total_work/lws consumer sees the carved axis
            self.total_work = self.region.dims[0].size
            self.lws = self.region.dims[0].lws

    @property
    def work_region(self) -> Region:
        """The program's full NDRange (legacy programs: 1-D at offset 0)."""
        if self.region is not None:
            return self.region
        return Region.line(self.total_work, lws=self.lws)

    @property
    def ndim(self) -> int:
        return 1 if self.region is None else self.region.ndim

    def validate(self) -> "Program":
        """Raise a clear ValueError now instead of a TypeError deep inside a
        device thread.  Called at session submit / workload registration."""
        if self.build is None or not callable(self.build):
            raise ValueError(
                f"Program {self.name!r}: 'build' must be a callable "
                "build(device) -> fn(offset, size); got "
                f"{self.build!r}.  Construct Programs via "
                "repro.core.programs or pass build= explicitly.")
        if self.total_work <= 0:
            raise ValueError(f"Program {self.name!r}: total_work must be "
                             f"positive, got {self.total_work}")
        if self.lws <= 0:
            raise ValueError(f"Program {self.name!r}: lws must be positive, "
                             f"got {self.lws}")
        return self


class WorkerPool:
    """Session-scoped pool of reusable device threads.

    Device threads are *pulled from the pool* per run instead of created per
    run: a session serving many back-to-back submits reuses the same OS
    threads (the thread-management analogue of the paper's primitive reuse).

    Deliberately NOT concurrent.futures.ThreadPoolExecutor: every run parks
    all n device threads on one Barrier, so the pool must grow unboundedly
    with the fleet — a bounded executor whose max_workers falls below the
    device count would deadlock the barrier.
    """

    def __init__(self, name: str = "coexec"):
        self._name = name
        self._lock = threading.Lock()
        self._idle: List["_Worker"] = []
        self._spawned = 0
        self._closed = False

    def submit(self, fn: Callable[[], None]) -> threading.Event:
        """Run ``fn`` on a pooled thread; returns its completion event."""
        with self._lock:
            if self._closed:
                raise RuntimeError("WorkerPool is closed")
            worker = self._idle.pop() if self._idle else None
            if worker is None:
                self._spawned += 1
                worker = _Worker(self, f"{self._name}-dev-{self._spawned}")
        return worker.run(fn)

    def _recycle(self, worker: "_Worker") -> None:
        with self._lock:
            if self._closed:
                worker.stop()
            else:
                self._idle.append(worker)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            idle, self._idle = self._idle, []
        for w in idle:
            w.stop()

    @property
    def size(self) -> int:
        return self._spawned


class _Worker:
    """One reusable pool thread: blocks on a job box, runs, recycles."""

    def __init__(self, pool: WorkerPool, name: str):
        self._pool = pool
        self._job: Optional[Tuple[Callable[[], None], threading.Event]] = None
        self._wake = threading.Semaphore(0)
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)
        self._thread.start()

    def run(self, fn: Callable[[], None]) -> threading.Event:
        done = threading.Event()
        self._job = (fn, done)
        self._wake.release()
        return done

    def stop(self) -> None:
        self._job = None
        self._wake.release()

    def _loop(self) -> None:
        while True:
            self._wake.acquire()
            job, self._job = self._job, None
            if job is None:
                return
            fn, done = job
            try:
                fn()
            except BaseException:
                # a job must never corpse the pool thread: device_thread
                # handles its own errors; this is the last-resort guard that
                # keeps a recycled worker alive for the next submit
                pass
            finally:
                done.set()
                self._pool._recycle(self)


class _RunContext:
    """Dispatch state for ONE submitted program (the session's inner engine).

    Owns the scheduler instance, the output buffer (or a caller-supplied
    ``collect`` hook for non-array reductions, e.g. gradient accumulation),
    and the per-run device bookkeeping.  Device threads are pulled from the
    session's WorkerPool; compiled executables come from ``compile_fn``
    (the session's cache).
    """

    def __init__(self, program: Program, devices: Sequence[DeviceGroup], *,
                 scheduler: str, scheduler_kwargs: Dict,
                 compile_fn: Callable[[DeviceGroup], Callable],
                 pool: WorkerPool,
                 registered_buffers: bool = True,
                 buffer_policy: Optional[BufferPolicy] = None,
                 arena: Optional[BufferArena] = None,
                 parallel_init: bool = True,
                 reset_device_stats: bool = True,
                 powers: Optional[List[float]] = None,
                 collect: Optional[Callable] = None,
                 region: Optional[Region] = None,
                 dispatch: str = "leased",
                 journal=None,
                 journal_key: Optional[str] = None,
                 progress=None,
                 progress_key: Optional[object] = None,
                 tenant=None,
                 lease_params: Optional[Dict] = None,
                 async_threshold_bytes: Optional[int] = None):
        self.program = program
        self.devices = list(devices)
        if not self.devices:
            raise RuntimeError(f"{program.name}: no devices to dispatch to")
        if dispatch not in ("leased", "per_packet"):
            raise ValueError(
                f"{program.name}: dispatch must be 'leased' or "
                f"'per_packet', got {dispatch!r}")
        self.dispatch = dispatch
        self.scheduler_name = scheduler
        self.scheduler_kwargs = dict(scheduler_kwargs)
        self.compile_fn = compile_fn
        self.pool = pool
        # buffer_policy supersedes the legacy registered_buffers bool (kept
        # for callers that predate the memory subsystem)
        self.buffer_policy = buffer_policy if buffer_policy is not None \
            else BufferPolicy.from_flag(registered_buffers)
        self.registered_buffers = self.buffer_policy.registered
        self.arena = arena
        self.parallel_init = parallel_init
        self.reset_device_stats = reset_device_stats
        self.powers = list(powers) if powers is not None else None
        self.collect = collect
        # the run's work: a sub-region (the paper's ROI) or the program's
        # full NDRange; containment/alignment is validated at submit time
        self.run_region = region if region is not None \
            else program.work_region
        # persistent run state: every committed packet appends (node key,
        # absolute dim-0 span, output rows) to the journal — the basis of
        # checkpoint/resume (repro.ckpt.checkpoint.RunJournal).  Offsets
        # are journaled relative to the PROGRAM's region start, so a
        # resumed gap sub-run composes with the original run's records.
        self.journal = journal
        self.journal_key = journal_key or program.name
        # per-graph work accounting: the session's GraphProgress learns
        # this run's live scheduler so graph-wide remaining() is exact
        self.progress = progress
        self.progress_key = progress_key
        # multi-tenant arbitration: a TenantHandle whose begin_packet /
        # end_packet bracket every device pull (repro.tenancy).  None =
        # the session owns the fleet (the pre-tenancy fast path, zero
        # overhead: solo runs stay bit-identical).
        self.tenant = tenant
        # calibrated constants (session kwargs / TunedConfig): lease
        # growth-law overrides applied onto the fresh scheduler instance,
        # and the transfer pipeline's inline/async commit crossover
        self.lease_params = dict(lease_params) if lease_params else None
        self.async_threshold_bytes = async_threshold_bytes

    def _invoke(self, fn: Callable, region: Region) -> Callable:
        """Adapt a packet's absolute row panel to the range-fn contract
        (1-D: fn(offset, size); 2-D: fn(row0, n_rows, col0, n_cols))."""
        if region.ndim == 2:
            d0, d1 = region.dims

            def call(_offset, _size):
                return fn(d0.offset, d0.size, d1.offset, d1.size)
        else:
            d0 = region.dims[0]

            def call(_offset, _size):
                return fn(d0.offset, d0.size)
        return call

    def execute(self) -> RunResult:
        clock = PhaseClock()
        clock.mark("start")
        prog = self.program
        run_region = self.run_region
        n = len(self.devices)
        if self.reset_device_stats:
            for d in self.devices:
                d.packets_done = 0
                d.busy_time = 0.0
                d.finish_time = 0.0
                d.dead = False

        output = None
        # output geometry follows the RUN's region (an ROI submit returns
        # just its sub-region, rows relative to the region start)
        out_cols = prog.out_cols if run_region.ndim == 1 \
            else run_region.dims[1].size * prog.out_cols
        pipe: Optional[TransferPipeline] = None
        use_pipeline = self.buffer_policy.pooled and self.collect is None
        if self.collect is None:
            out_rows = run_region.dims[0].size * prog.out_rows_per_wg
            if self.buffer_policy.pooled and self.arena is not None:
                # pooled: the run output is a recycled arena buffer, not a
                # fresh allocation.  No zeroing needed — packets tile the
                # run region exactly, and a commit failure fails the run.
                output = self.arena.acquire(prog.name, "host",
                                            (out_rows, out_cols),
                                            prog.out_dtype).array
            else:
                output = np.zeros((out_rows, out_cols), prog.out_dtype)
        profiles = [DeviceProfile(d.name,
                                  (self.powers[i] if self.powers else
                                   (d.throughput or 1.0 / d.throttle)),
                                  power_model=d.power_model)
                    for i, d in enumerate(self.devices)]
        # per-device commit logs: appended only by the owning device
        # thread (or the committer draining that device's stage-outs), so
        # the dispatch hot path never crosses a run-global lock
        executed_by: List[List] = [[] for _ in range(n)]
        # per-device host<->device traffic (bytes) for the energy meter's
        # transfer term; written only by the owning device thread
        bytes_io: List[float] = [0.0] * n
        errors: List[BaseException] = []
        exec_lock = threading.Lock()      # rare paths: errors, collect
        state: Dict[str, Any] = {"sched": None, "commit_failed": 0}
        ready = threading.Barrier(n + 1)
        compiled_ev = threading.Event()
        fns: List[Optional[Callable]] = [None] * n
        t0_busy = [d.busy_time for d in self.devices]
        if use_pipeline:
            pipe = TransferPipeline(self.pool, self.async_threshold_bytes)
            pipe.start()

        def mark_roi():
            # the ROI window opens when the first packet is ready to
            # compute; ordering after the "compiled" mark keeps the five
            # phase windows disjoint (exact wall-clock identity)
            if clock.at("roi") is None:
                compiled_ev.wait()
                clock.mark_once("roi")

        # multi-tenant arbitration: tb[i] is the begin_packet timestamp
        # that brackets device i's current packet window (written/read
        # only by device i's thread)
        tenant = self.tenant
        tb: List[float] = [0.0] * n

        def pull(i: int) -> Any:
            """The dispatch hot path: leased (local-lease pop, amortized
            lock) or per-packet (the classic hand-off baseline).  Under a
            tenant, every pull first asks the arbiter; a denial reclaims
            the device's lease back to the retry pool (the packet-boundary
            preemption) and reads as an empty pull — the loop's drained()
            protocol keeps the thread polling while work remains."""
            sched = sched_of(i)
            if tenant is not None:
                if not tenant.begin_packet(i):
                    sched.reclaim_lease(i)
                    return None
                tb[i] = time.perf_counter()
                pkt = (sched.acquire(i) if self.dispatch == "leased"
                       else sched.next_packet(i))
                if pkt is None:
                    tenant.end_packet(i, 0, tb[i])
                return pkt
            if self.dispatch == "leased":
                return sched.acquire(i)
            return sched.next_packet(i)

        def tenant_end(i: int, wg: int) -> None:
            """Close device i's tenant packet window (wg=0: the packet was
            requeued, charge nothing).  Must be called exactly once per
            successful begin_packet, on every exit path."""
            if tenant is not None:
                tenant.end_packet(i, wg, tb[i])

        def fetch_and_stage(i: int, fn: Callable):
            """Stage-in for device ``i``: pull the next packet and bind its
            launch (the H2D window's host work)."""
            t0 = time.perf_counter()
            pkt = pull(i)
            if pkt is None:
                return None
            try:
                pkt_region = pkt.region if pkt.region is not None \
                    else run_region.row_panel(pkt.offset, pkt.size)
                call = self._invoke(fn, pkt_region)
            except BaseException:
                # requeue BEFORE release: the packet must never be
                # invisible to the drained() protocol
                sched_of(i).requeue(pkt)
                sched_of(i).release(i)
                tenant_end(i, 0)
                raise
            if pipe is not None:
                pipe.note_h2d(time.perf_counter() - t0)
            return pkt, call

        def sched_of(i: int) -> SchedulerBase:
            return state["sched"]

        # journal offsets are node-relative (program-region dim-0 units),
        # so a resumed gap sub-run's records land in node coordinates
        jbase = (run_region.dims[0].offset
                 - prog.work_region.dims[0].offset)

        def journal_commit(pkt, rows) -> None:
            """Append one committed packet to the run journal (called
            under the packet's commit, before its scheduler release)."""
            if self.journal is not None:
                self.journal.append_packet(self.journal_key,
                                           jbase + pkt.offset, pkt.size,
                                           rows)

        def make_commit(i, pkt, res):
            def commit():
                try:
                    r0 = pkt.offset * prog.out_rows_per_wg
                    r1 = (pkt.offset + pkt.size) * prog.out_rows_per_wg
                    rows = np.asarray(res).reshape(r1 - r0, out_cols)
                    output[r0:r1] = rows
                    journal_commit(pkt, rows)
                    executed_by[i].append(("pkt", pkt))
                except Exception as e:
                    # host-side commit failure is fatal for the run: the
                    # packet was accounted done at stage-out, so the drain
                    # check cannot catch it — fail the run explicitly
                    with exec_lock:
                        errors.append(e)
                        state["commit_failed"] += 1
            return commit

        def abort_pipelined(i, pkt, err):
            """Requeue the in-flight packet and release the device (same
            provenance rules as the sync path)."""
            if err is not None:
                with exec_lock:
                    errors.append(err)
            sched = sched_of(i)
            sched.requeue(pkt)
            sched.mark_dead(i)
            sched.release(i)
            tenant_end(i, 0)

        def device_loop_sync(i: int, dev: DeviceGroup, fn: Callable,
                             sched: SchedulerBase):
            # the unregistered-buffer pathology: every packet re-syncs the
            # program's full memory regions — read-only inputs AND the
            # whole output region — on the device thread (real host copies
            # sized by the actual footprints; the sim's BULK_COPY term)
            in_src = in_scratch = None
            stage_bytes = 0
            if not self.registered_buffers:
                stage_bytes = prog.in_bytes + (output.nbytes
                                               if output is not None else 0)
            if stage_bytes > 0:
                in_src = np.empty(stage_bytes, np.uint8)
                in_scratch = np.empty(stage_bytes, np.uint8)
            my_done = executed_by[i]
            staged_in = False
            while True:
                mark_roi()
                pkt = pull(i)
                if pkt is None:
                    # another device may still fail and requeue its
                    # packet: only exit once the scheduler's drain
                    # protocol says nothing is in flight anywhere
                    # (remaining + acquired-but-unreleased claims + the
                    # retry-epoch re-check).  A dying peer keeps its
                    # claim until after it has requeued its packet and
                    # mark_dead has reclaimed its lease, so drained()
                    # stays False for exactly as long as recoverable
                    # work can still appear.
                    if sched.drained():
                        break
                    time.sleep(1e-3)
                    continue
                pkt_region = pkt.region if pkt.region is not None \
                    else run_region.row_panel(pkt.offset, pkt.size)
                if in_src is not None:
                    np.copyto(in_scratch, in_src)     # per-packet bulk copy
                    bytes_io[i] += stage_bytes        # bulk re-stage per pkt
                elif not staged_in:
                    bytes_io[i] += prog.in_bytes      # registered: once/dev
                    staged_in = True
                try:
                    res, wg_s = dev.run_packet(self._invoke(fn, pkt_region),
                                               pkt.offset, pkt.size)
                except DeviceFailure:
                    sched.requeue(pkt)
                    sched.mark_dead(i)
                    sched.release(i)
                    tenant_end(i, 0)
                    break
                except Exception as e:
                    # unexpected executor error: same fault-tolerance path as
                    # a device failure, but the error is surfaced if the run
                    # cannot complete without this device
                    dev.dead = True
                    with exec_lock:
                        errors.append(e)
                    sched.requeue(pkt)
                    sched.mark_dead(i)
                    sched.release(i)
                    tenant_end(i, 0)
                    break
                try:
                    sched.note_packet_latency(i, pkt.size / max(wg_s, 1e-9))
                    if hasattr(sched, "observe"):
                        sched.observe(i, wg_s)
                    if self.collect is not None:
                        with exec_lock:
                            self.collect(pkt, res, dev)
                        my_done.append(("pkt", pkt))
                        sched.release(i)
                        tenant_end(i, pkt.size)
                        continue
                    r0 = pkt.offset * prog.out_rows_per_wg
                    r1 = (pkt.offset + pkt.size) * prog.out_rows_per_wg
                    res = np.asarray(res).reshape(r1 - r0, out_cols)
                    bytes_io[i] += res.nbytes         # result readback
                    if self.registered_buffers:
                        output[r0:r1] = res           # in-place commit
                    else:
                        my_done.append(("copy", r0, r1,
                                        np.array(res, copy=True)))
                    journal_commit(pkt, res)
                    my_done.append(("pkt", pkt))
                    sched.release(i)
                    tenant_end(i, pkt.size)
                except Exception as e:
                    # commit-path failure (mis-shaped result, collect hook,
                    # observe): must release the in-flight packet and mark
                    # the device dead, or the surviving devices spin forever
                    dev.dead = True
                    with exec_lock:
                        errors.append(e)
                    sched.requeue(pkt)
                    sched.mark_dead(i)
                    sched.release(i)
                    tenant_end(i, 0)
                    break

        def device_loop_pipelined(i: int, dev: DeviceGroup, fn: Callable,
                                  sched: SchedulerBase):
            """stage-in -> compute -> stage-out, double-buffered: packet
            k's stage-out is handed to the committer and packet k+1's
            stage-in is issued immediately — the device thread moves on to
            the next compute while the committer drains k's D2H, and never
            blocks on host staging.  (On hosts where stage-in itself is
            heavy, ``TransferPipeline.prefetch`` runs it on a stager
            thread concurrently with compute; the bound launches here are
            host-cheap, so the runtime issues them inline and the
            simulator carries the calibrated H2D-overlap model.)"""
            itemsize = np.dtype(prog.out_dtype).itemsize

            def abort_stage_in(e: BaseException) -> None:
                # a stage-in failure must release the device like any other
                # fatal error — swallowing it would strand a pre-assigned
                # static chunk and livelock the surviving devices
                dev.dead = True
                with exec_lock:
                    errors.append(e)
                    sched.mark_dead(i)

            staged_in = False
            try:
                staged = fetch_and_stage(i, fn)
            except Exception as e:
                abort_stage_in(e)
                return
            while True:
                if staged is None:
                    # same exit protocol as the sync loop
                    if sched.drained():
                        break
                    time.sleep(1e-3)
                    try:
                        staged = fetch_and_stage(i, fn)
                    except Exception as e:
                        abort_stage_in(e)
                        return
                    continue
                pkt, call = staged
                if not staged_in:
                    bytes_io[i] += prog.in_bytes      # arena stage-in, once
                    staged_in = True
                mark_roi()
                try:
                    res, wg_s = dev.run_packet(call, pkt.offset, pkt.size)
                except DeviceFailure:
                    abort_pipelined(i, pkt, None)
                    break
                except Exception as e:
                    dev.dead = True
                    abort_pipelined(i, pkt, e)
                    break
                try:
                    sched.note_packet_latency(i, pkt.size / max(wg_s, 1e-9))
                    if hasattr(sched, "observe"):
                        sched.observe(i, wg_s)
                    nbytes = (pkt.size * prog.out_rows_per_wg * out_cols
                              * itemsize)
                    bytes_io[i] += nbytes             # result readback
                    pipe.stage_out(make_commit(i, pkt, res), nbytes)
                    sched.release(i)
                    tenant_end(i, pkt.size)
                except Exception as e:
                    dev.dead = True
                    abort_pipelined(i, pkt, e)
                    break
                try:
                    staged = fetch_and_stage(i, fn)
                except Exception as e:
                    # stage-in failure (bad geometry): the fetch released
                    # its own accounting; release the device and surface
                    abort_stage_in(e)
                    break

        def device_thread(i: int):
            dev = self.devices[i]
            if self.parallel_init:
                # parallel AOT compile, overlapped with Runtime's prep
                try:
                    fns[i] = self.compile_fn(dev)
                except Exception as e:      # compile failure = dead device
                    dev.dead = True
                    with exec_lock:
                        errors.append(e)
            ready.wait()
            sched: SchedulerBase = state["sched"]
            if sched is None:
                return                        # scheduler construction failed
            fn = fns[i]
            if fn is None:
                sched.mark_dead(i)            # compile failed: release work
                return
            if use_pipeline:
                device_loop_pipelined(i, dev, fn, sched)
            else:
                device_loop_sync(i, dev, fn, sched)
            dev.finish_time = clock.since("roi") if clock.at("roi") else 0.0

        def start_threads() -> List[threading.Event]:
            return [self.pool.submit(_bind(device_thread, i))
                    for i in range(n)]

        def build_scheduler() -> SchedulerBase:
            sched = make_scheduler(self.scheduler_name, run_region,
                                   run_region.dims[0].lws, profiles,
                                   **self.scheduler_kwargs)
            if self.lease_params:
                sched.set_lease_params(**self.lease_params)
            if self.progress is not None:
                # graph-wide remaining() now reads this run's live
                # lease/exact-cover bookkeeping instead of its static G
                self.progress.attach(self.progress_key, sched)
            return sched

        try:
            if self.parallel_init:
                done_events = start_threads()
                # Runtime prepares the scheduler concurrently with compiles
                try:
                    state["sched"] = build_scheduler()
                except BaseException:
                    # release the pooled threads parked at the barrier (they
                    # see sched=None and exit) before surfacing the error —
                    # a raise here must not wedge n workers forever
                    ready.wait()
                    for ev in done_events:
                        ev.wait()
                    raise
                # the barrier releases once every device finished compiling:
                # everything before it is the init phase (compiles
                # overlapped with scheduler prep); the staging (h2d) and
                # ROI windows follow
                ready.wait()
            else:
                # sequential: discovery+compile each device, then scheduler
                for i, d in enumerate(self.devices):
                    try:
                        fns[i] = self.compile_fn(d)
                    except Exception as e:
                        d.dead = True
                        errors.append(e)
                state["sched"] = build_scheduler()
                done_events = start_threads()
                ready.wait()
            clock.mark("compiled")
            compiled_ev.set()
            for ev in done_events:
                ev.wait()
            clock.mark("drained")
            roi_time = clock.between("roi", "drained")
            if pipe is not None:
                # drain the commit tail: everything still on the committer
                # after the queue drained is the run's D2H window
                pipe.flush()
            if state["sched"].remaining() > 0:
                err = RuntimeError(
                    f"{prog.name}: {state['sched'].remaining()} work-groups "
                    "unprocessed — all devices failed")
                if errors:
                    raise err from errors[0]
                raise err
            if state["commit_failed"]:
                err = RuntimeError(
                    f"{prog.name}: {state['commit_failed']} packet "
                    "commit(s) failed on the transfer pipeline")
                if errors:
                    raise err from errors[0]
                raise err
            if self.collect is None and not self.registered_buffers:
                # assemble results from per-packet copies (bulk copy at end)
                for done in executed_by:
                    for item in done:
                        if item[0] == "copy":
                            _, r0, r1, arr = item
                            output[r0:r1] = arr
            clock.mark("assembled")
            packets = [it[1] for done in executed_by for it in done
                       if it[0] == "pkt"]
            clock.mark("end")
        finally:
            if pipe is not None:
                pipe.close()
            if tenant is not None and state["sched"] is not None:
                # per-tenant SchedStats rollup across all of the tenant's
                # runs (carves, steals, reclaims, lock crossings)
                tenant.merge_stats(state["sched"].stats)
        phases = PhaseBreakdown(
            init_s=clock.between("start", "compiled"),
            offload_s=clock.between("compiled", "assembled"),
            roi_s=roi_time,
            teardown_s=clock.between("assembled", "end"),
            h2d_s=clock.between("compiled", "roi"),
            d2h_s=clock.between("drained", "assembled"),
        )
        run_busy = [d.busy_time - b0 for d, b0 in
                    zip(self.devices, t0_busy)]
        # energy: each device is powered for the whole ROI window (idle
        # watts bridge its stalls); a dead device only until it exited.
        # Crossings come from the scheduler's per-device counters — the
        # exact dispatch-path hand-offs this run paid for.
        crossings = state["sched"].lock_crossings_by_device()
        meter = EnergyMeter()
        for i, d in enumerate(self.devices):
            window = d.finish_time if d.dead else roi_time
            meter.add(d.name, d.power_model,
                      busy_s=min(max(run_busy[i], 0.0), window),
                      window_s=window, crossings=crossings[i],
                      bytes_moved=bytes_io[i])
        result = RunResult(
            total_time=roi_time,
            device_busy=run_busy,
            device_finish=[d.finish_time for d in self.devices],
            packets=packets,
            binary_time=clock.between("start", "end"),
            aborted_devices=sum(1 for d in self.devices if d.dead),
            phases=phases,
            sched_wait_s=state["sched"].sched_wait_s(),
            energy=meter.report(),
        )
        result.output = output  # type: ignore[attr-defined]
        return result


def _bind(fn: Callable, i: int) -> Callable[[], None]:
    """Bind the device index without a late-binding closure bug."""
    def bound():
        fn(i)
    return bound
