"""Heterogeneity-aware data-parallel training (the paper's co-execution as
a first-class training-framework feature).

Each training step is a co-execution of one global batch submitted to an
``EngineSession``: the batch's row range is the work queue (1 work-group =
``lws`` rows = the minimum microbatch), device groups pull row-range packets
HGuided-style in proportion to their EWMA-measured throughput, and gradients
are combined weighted by the tokens each group actually processed (the
session's ``collect`` hook replaces array output assembly).  Consequences,
by construction:

  * straggler mitigation — a slow/throttled group takes fewer packets and
    everyone finishes the step together (the paper's balance ~= 1);
  * fault tolerance — a group that dies mid-step has its in-flight packet
    requeued; surviving groups absorb it; the step completes with the FULL
    global batch (exactly-once semantics per row range);
  * elastic scaling — groups can be added/removed between steps; powers
    renormalize automatically (HGuidedOpt's online estimation);
  * optional int8 error-feedback compression on the gradient combine
    (the cross-pod hop at datacenter scale).

The trainer's session keeps per-group state across steps
(``reset_device_stats=False``): throughput EWMAs carry into the next step's
profiles and a failed group stays excluded until removed/replaced.  On a
real multi-pod deployment each DeviceGroup is a pod sub-slice and the
combine is a weighted all-reduce over the ``pod`` axis; in this container
groups are CPU executors (optionally throttled) and the combine is local.
The DES twin (core/simulate.py + benchmarks/scale1000.py) runs the same
scheduler logic at 1024-group scale.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.api.session import EngineSession
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.device import DeviceGroup
from repro.core.runtime import Program
from repro.data.pipeline import SyntheticPipeline
from repro.optim import adamw, compress as C
from repro.optim.adamw import OptConfig, TrainState
from repro.training.step import make_loss_fn


@dataclass
class StepReport:
    loss: float
    tokens: int
    step_time_s: float
    balance: float
    packets: int
    device_rows: Dict[str, int]
    failures: int


class HeteroDPTrainer:
    def __init__(self, cfg: ModelConfig, opt: OptConfig, shape: ShapeConfig,
                 devices: List[DeviceGroup], pipeline: SyntheticPipeline, *,
                 scheduler: str = "hguided_opt", lws: int = 1,
                 compress: bool = False):
        self.cfg = cfg
        self.opt = opt
        self.shape = shape
        self.pipeline = pipeline
        self.lws = lws
        self.compress = compress
        # the session keeps cross-step device state: throughput EWMAs feed
        # the next step's profiles, dead groups stay excluded
        self.session = EngineSession(devices, scheduler=scheduler,
                                     reset_device_stats=False,
                                     name="hetero_dp")
        loss_fn = make_loss_fn(cfg)

        def grad_fn(params, batch):
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                     batch)
            return loss, g

        self._grad = jax.jit(grad_fn)
        self._err = None      # compression error-feedback buffers

    # -- elastic membership -------------------------------------------------
    @property
    def devices(self) -> List[DeviceGroup]:
        return self.session.devices

    def add_device(self, dev: DeviceGroup) -> None:
        self.session.add_device(dev)

    def remove_device(self, name: str) -> None:
        self.session.remove_device(name)

    def close(self) -> None:
        """Release the dispatch session (its dispatcher + device threads)."""
        self.session.close()

    # -- one co-executed step ------------------------------------------------
    def step(self, state: TrainState,
             step_idx: int) -> Tuple[TrainState, StepReport]:
        B = self.shape.global_batch
        assert B % self.lws == 0
        G = B // self.lws
        alive = [d for d in self.session.devices if not d.dead]
        acc = {"g": None, "loss": 0.0, "rows": 0}
        rows_by_dev: Dict[str, int] = {d.name: 0 for d in alive}
        lws = self.lws

        def build(dev: DeviceGroup):
            def fn(offset: int, size: int):
                rows = slice(offset * lws, (offset + size) * lws)
                batch = self.pipeline.batch_at(step_idx, rows=rows)
                batch = {k: dev.put(jnp.asarray(v)) for k, v in batch.items()}
                return self._grad(state.params, batch)
            return fn

        def collect(pkt, res, dev):
            # runs under the run's commit lock: plain accumulation is safe
            loss, g = res
            n_rows = pkt.size * lws
            w = float(n_rows)
            if acc["g"] is None:
                acc["g"] = jax.tree.map(lambda x: x * w, g)
            else:
                acc["g"] = jax.tree.map(lambda a, x: a + x * w, acc["g"], g)
            acc["loss"] += float(loss) * n_rows
            acc["rows"] += n_rows
            rows_by_dev[dev.name] = rows_by_dev.get(dev.name, 0) + n_rows

        prog = Program(f"hdp_step{step_idx}", G, 1, build)
        t0 = time.perf_counter()
        # ephemeral program: the executable closes over this step's params
        result = self.session.submit(prog, collect=collect,
                                     cache=False).result()
        if acc["rows"] != B:
            raise RuntimeError(
                f"step {step_idx}: incomplete batch ({acc['rows']}/{B})")
        grads = jax.tree.map(lambda x: x / acc["rows"], acc["g"])
        if self.compress:
            if self._err is None:
                self._err = C.init_error(state.params)
            grads, self._err = C.compress_decompress(grads, self._err)
        new_state, opt_metrics = adamw.apply_updates(state, grads, self.opt)
        dt = time.perf_counter() - t0
        fins = [b for b in result.device_busy if b > 0]
        report = StepReport(
            loss=acc["loss"] / acc["rows"],
            tokens=acc["rows"] * self.shape.seq_len,
            step_time_s=dt,
            balance=(min(fins) / max(fins)) if len(fins) > 1 else 1.0,
            packets=len(result.packets),
            device_rows=dict(rows_by_dev),
            failures=result.aborted_devices,
        )
        return new_state, report
