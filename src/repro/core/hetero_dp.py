"""Heterogeneity-aware data-parallel training (the paper's co-execution as
a first-class training-framework feature).

Each training step is a co-execution of one global batch: the batch's row
range is the work queue (1 work-group = ``lws`` rows = the minimum
microbatch), device groups pull row-range packets HGuided-style in
proportion to their EWMA-measured throughput, and gradients are combined
weighted by the tokens each group actually processed.  Consequences, by
construction:

  * straggler mitigation — a slow/throttled group takes fewer packets and
    everyone finishes the step together (the paper's balance ~= 1);
  * fault tolerance — a group that dies mid-step has its in-flight packet
    requeued; surviving groups absorb it; the step completes with the FULL
    global batch (exactly-once semantics per row range);
  * elastic scaling — groups can be added/removed between steps; powers
    renormalize automatically (HGuidedOpt's online estimation);
  * optional int8 error-feedback compression on the gradient combine
    (the cross-pod hop at datacenter scale).

On a real multi-pod deployment each DeviceGroup is a pod sub-slice and the
combine is a weighted all-reduce over the ``pod`` axis; in this container
groups are CPU executors (optionally throttled) and the combine is local.
The DES twin (core/simulate.py + benchmarks/scale1000.py) runs the same
scheduler logic at 1024-group scale.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.device import DeviceFailure, DeviceGroup
from repro.core.scheduler import DeviceProfile, make_scheduler
from repro.data.pipeline import SyntheticPipeline
from repro.optim import adamw, compress as C
from repro.optim.adamw import OptConfig, TrainState
from repro.training.step import make_loss_fn


@dataclass
class StepReport:
    loss: float
    tokens: int
    step_time_s: float
    balance: float
    packets: int
    device_rows: Dict[str, int]
    failures: int


class HeteroDPTrainer:
    def __init__(self, cfg: ModelConfig, opt: OptConfig, shape: ShapeConfig,
                 devices: List[DeviceGroup], pipeline: SyntheticPipeline, *,
                 scheduler: str = "hguided_opt", lws: int = 1,
                 compress: bool = False):
        self.cfg = cfg
        self.opt = opt
        self.shape = shape
        self.devices = list(devices)
        self.pipeline = pipeline
        self.scheduler_name = scheduler
        self.lws = lws
        self.compress = compress
        loss_fn = make_loss_fn(cfg)

        def grad_fn(params, batch):
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                     batch)
            return loss, g

        self._grad = jax.jit(grad_fn)
        self._err = None      # compression error-feedback buffers

    # -- elastic membership -------------------------------------------------
    def add_device(self, dev: DeviceGroup) -> None:
        self.devices.append(dev)

    def remove_device(self, name: str) -> None:
        self.devices = [d for d in self.devices if d.name != name]

    # -- one co-executed step ------------------------------------------------
    def step(self, state: TrainState, step_idx: int) -> Tuple[TrainState, StepReport]:
        B = self.shape.global_batch
        assert B % self.lws == 0
        G = B // self.lws
        alive = [d for d in self.devices if not d.dead]
        profiles = [DeviceProfile(d.name, d.throughput or 1.0 / d.throttle)
                    for d in alive]
        sched = make_scheduler(self.scheduler_name, G, 1, profiles)
        lock = threading.Lock()
        acc = {"g": None, "loss": 0.0, "rows": 0, "packets": 0}
        rows_by_dev: Dict[str, int] = {d.name: 0 for d in alive}
        state_inflight = {"n": 0}
        t0 = time.perf_counter()

        def worker(i: int):
            dev = alive[i]
            while True:
                with lock:
                    pkt = sched.next_packet(i)
                    if pkt is not None:
                        state_inflight["n"] += 1
                if pkt is None:
                    with lock:
                        done = state_inflight["n"] == 0 and sched.remaining() == 0
                        others = any(not d.dead for j, d in enumerate(alive)
                                     if j != i)
                    if done or not others:
                        return
                    time.sleep(1e-3)
                    continue
                rows = slice(pkt.offset * self.lws,
                             (pkt.offset + pkt.size) * self.lws)
                batch = self.pipeline.batch_at(step_idx, rows=rows)
                batch = {k: dev.put(jnp.asarray(v)) for k, v in batch.items()}
                try:
                    (loss, g), wg_s = dev.run_packet(
                        lambda off, size: self._grad(state.params, batch),
                        pkt.offset, pkt.size)
                except DeviceFailure:
                    with lock:
                        sched.requeue(pkt)
                        state_inflight["n"] -= 1
                    return
                if hasattr(sched, "observe"):
                    sched.observe(i, wg_s)
                n_rows = pkt.size * self.lws
                with lock:
                    w = float(n_rows)
                    if acc["g"] is None:
                        acc["g"] = jax.tree.map(lambda x: x * w, g)
                    else:
                        acc["g"] = jax.tree.map(lambda a, x: a + x * w,
                                                acc["g"], g)
                    acc["loss"] += float(loss) * n_rows
                    acc["rows"] += n_rows
                    acc["packets"] += 1
                    rows_by_dev[dev.name] += n_rows
                    state_inflight["n"] -= 1

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(alive))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if sched.remaining() > 0 or acc["rows"] != B:
            raise RuntimeError(
                f"step {step_idx}: incomplete batch ({acc['rows']}/{B})")
        grads = jax.tree.map(lambda x: x / acc["rows"], acc["g"])
        if self.compress:
            if self._err is None:
                self._err = C.init_error(state.params)
            grads, self._err = C.compress_decompress(grads, self._err)
        new_state, opt_metrics = adamw.apply_updates(state, grads, self.opt)
        dt = time.perf_counter() - t0
        busy = [d.busy_time for d in alive]
        fins = [b for b in busy if b > 0]
        report = StepReport(
            loss=acc["loss"] / acc["rows"],
            tokens=acc["rows"] * self.shape.seq_len,
            step_time_s=dt,
            balance=(min(fins) / max(fins)) if len(fins) > 1 else 1.0,
            packets=acc["packets"],
            device_rows=dict(rows_by_dev),
            failures=sum(1 for d in alive if d.dead),
        )
        for d in alive:   # reset per-step busy accounting
            d.busy_time = 0.0
        return new_state, report
