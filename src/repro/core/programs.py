"""Program adapters: wrap the kernel suite's range entry points as
co-execution Programs (real execution on JAX devices).

Two geometries per the Region redesign:

* the classic 1-D adapters (``run_range``) — a flat work-group line, one
  work-group = ``LWS`` rows/options/bodies;
* 2-D NDRange adapters (``*_program_2d``, image kernels only) — the
  Program's region is ``rows x cols`` with per-dimension lws, the build
  produces a ``fn(row0, n_rows, col0, n_cols)`` tile kernel, and
  schedulers carve row panels.  These are the ROI-offloading targets
  (register once, re-submit sub-regions warm).

Sizes are scaled down from the paper's (which target a ~2 s GTX 950 run)
so the real-execution benches stay fast on one CPU; the simulator
(configs/paper_suite.py) carries the full calibrated sizes."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.region import Region
from repro.core.runtime import Program
from repro.kernels.binomial import ops as binomial_ops
from repro.kernels.gaussian import ops as gaussian_ops
from repro.kernels.mandelbrot import ops as mandelbrot_ops
from repro.kernels.nbody import ops as nbody_ops
from repro.kernels.ray import ops as ray_ops
from repro.kernels.ray import ref as ray_ref


def gaussian_program(h: int = 1024, w: int = 512, seed: int = 0,
                     use_pallas: bool = False) -> Program:
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((h, w)).astype(np.float32)
    ip, wts = gaussian_ops.prepare(img)
    G = gaussian_ops.total_work(img)

    def build(dev):
        ipd = dev.put(jnp.asarray(ip))
        wd = dev.put(jnp.asarray(wts))

        def fn(offset, size):
            return gaussian_ops.run_range(ipd, wd, offset, size,
                                          use_pallas=use_pallas)
        return fn

    return Program("gaussian", G, 1, build,
                   out_rows_per_wg=gaussian_ops.LWS, out_cols=w,
                   in_bytes=ip.nbytes + wts.nbytes)


def gaussian_program_2d(h: int = 512, w: int = 512, seed: int = 0,
                        lws: Tuple[int, int] = (32, 32)) -> Program:
    """Gaussian blur as a 2-D NDRange (rows x cols, row-panel carving)."""
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((h, w)).astype(np.float32)
    ip, wts = gaussian_ops.prepare(img)

    def build(dev):
        ipd = dev.put(jnp.asarray(ip))
        wd = dev.put(jnp.asarray(wts))

        def fn(row0, n_rows, col0, n_cols):
            return gaussian_ops.run_region(ipd, wd, row0, n_rows,
                                           col0, n_cols)
        return fn

    return Program("gaussian2d", build=build,
                   region=Region.rect(h, w, lws=lws),
                   in_bytes=ip.nbytes + wts.nbytes)


def mandelbrot_program_2d(px: int = 256, max_iter: int = 256,
                          lws: Tuple[int, int] = (8, 8)) -> Program:
    def build(dev):
        def fn(row0, n_rows, col0, n_cols):
            return mandelbrot_ops.run_region(row0, n_rows, col0, n_cols,
                                             width=px, height=px,
                                             max_iter=max_iter)
        return fn

    return Program("mandelbrot2d", build=build,
                   region=Region.rect(px, px, lws=lws),
                   out_dtype=np.int32)


def ray_program_2d(which: int = 1, px: int = 256,
                   lws: Tuple[int, int] = (4, 4)) -> Program:
    scene = ray_ref.make_scene(which)

    def build(dev):
        sc = {k: dev.put(v) for k, v in scene.items()}

        def fn(row0, n_rows, col0, n_cols):
            return ray_ops.run_region(sc, row0, n_rows, col0, n_cols,
                                      width=px, height=px)
        return fn

    return Program(f"ray{which}_2d", build=build,
                   region=Region.rect(px, px, lws=lws), out_cols=3,
                   in_bytes=sum(v.nbytes for v in scene.values()))


def binomial_program(n_options: int = 65536, seed: int = 0,
                     use_pallas: bool = False) -> Program:
    s0, k0, ty = binomial_ops.make_inputs(n_options, seed)
    G = binomial_ops.total_work(n_options)

    def build(dev):
        a, b, c = (dev.put(jnp.asarray(x)) for x in (s0, k0, ty))

        def fn(offset, size):
            return binomial_ops.run_range(a, b, c, offset, size,
                                          use_pallas=use_pallas)
        return fn

    return Program("binomial", G, 1, build,
                   out_rows_per_wg=binomial_ops.LWS, out_cols=1,
                   in_bytes=s0.nbytes + k0.nbytes + ty.nbytes)


def mandelbrot_program(px: int = 512, max_iter: int = 256,
                       use_pallas: bool = False) -> Program:
    G = mandelbrot_ops.total_work(px)

    def build(dev):
        def fn(offset, size):
            return mandelbrot_ops.run_range(
                offset, size, width=px, height=px, max_iter=max_iter,
                use_pallas=use_pallas)
        return fn

    return Program("mandelbrot", G, 1, build,
                   out_rows_per_wg=mandelbrot_ops.LWS * px, out_cols=1,
                   out_dtype=np.int32)


def nbody_program(n_bodies: int = 8192, seed: int = 0,
                  use_pallas: bool = False) -> Program:
    pm, vel = nbody_ops.make_inputs(n_bodies, seed)
    G = nbody_ops.total_work(n_bodies)

    def build(dev):
        pmd = dev.put(jnp.asarray(pm))
        vd = dev.put(jnp.asarray(vel))

        def fn(offset, size):
            return nbody_ops.run_range(pmd, vd, offset, size,
                                       use_pallas=use_pallas)
        return fn

    return Program("nbody", G, 1, build,
                   out_rows_per_wg=nbody_ops.LWS, out_cols=7,
                   in_bytes=pm.nbytes + vel.nbytes)


def ray_program(which: int = 1, px: int = 256) -> Program:
    scene = ray_ref.make_scene(which)
    G = ray_ops.total_work(px)

    def build(dev):
        sc = {k: dev.put(v) for k, v in scene.items()}

        def fn(offset, size):
            img = ray_ops.run_range(sc, offset, size, width=px, height=px)
            return img.reshape(-1, 3)
        return fn

    return Program(f"ray{which}", G, 1, build,
                   out_rows_per_wg=ray_ops.LWS * px, out_cols=3,
                   in_bytes=sum(v.nbytes for v in scene.values()))


PROGRAMS = {
    "gaussian": gaussian_program,
    "binomial": binomial_program,
    "mandelbrot": mandelbrot_program,
    "nbody": nbody_program,
    "ray1": lambda **kw: ray_program(1, **kw),
    "ray2": lambda **kw: ray_program(2, **kw),
    # 2-D NDRange variants (ROI-offloading targets, row-panel carving)
    "gaussian2d": gaussian_program_2d,
    "mandelbrot2d": mandelbrot_program_2d,
    "ray1_2d": lambda **kw: ray_program_2d(1, **kw),
    "ray2_2d": lambda **kw: ray_program_2d(2, **kw),
}


class _HostDev:
    def put(self, x):
        return x


def reference_output(program_name: str, **kwargs) -> np.ndarray:
    """Single-device single-packet execution (the correctness oracle for
    co-executed outputs).  2-D programs return (rows, cols*out_cols)."""
    prog = PROGRAMS[program_name](**kwargs)
    fn = prog.build(_HostDev())
    region = prog.work_region
    if region.ndim == 2:
        d0, d1 = region.dims
        out = np.asarray(fn(d0.offset, d0.size, d1.offset, d1.size))
        return out.reshape(d0.size * prog.out_rows_per_wg,
                           d1.size * prog.out_cols)
    out = np.asarray(fn(0, prog.total_work))
    return out.reshape(prog.total_work * prog.out_rows_per_wg, prog.out_cols)
