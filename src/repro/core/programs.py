"""Program adapters: wrap the kernel suite's ``run_range`` entry points as
co-execution Programs for the threaded Engine (real execution on JAX
devices).  Sizes are scaled down from the paper's (which target a ~2 s GTX
950 run) so the real-execution benches stay fast on one CPU; the simulator
(configs/paper_suite.py) carries the full calibrated sizes."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime import Program
from repro.kernels.binomial import ops as binomial_ops
from repro.kernels.gaussian import ops as gaussian_ops
from repro.kernels.mandelbrot import ops as mandelbrot_ops
from repro.kernels.nbody import ops as nbody_ops
from repro.kernels.ray import ops as ray_ops
from repro.kernels.ray import ref as ray_ref


def gaussian_program(h: int = 1024, w: int = 512, seed: int = 0,
                     use_pallas: bool = False) -> Program:
    rng = np.random.default_rng(seed)
    img = rng.standard_normal((h, w)).astype(np.float32)
    ip, wts = gaussian_ops.prepare(img)
    G = gaussian_ops.total_work(img)

    def build(dev):
        ipd = dev.put(jnp.asarray(ip))
        wd = dev.put(jnp.asarray(wts))

        def fn(offset, size):
            return gaussian_ops.run_range(ipd, wd, offset, size,
                                          use_pallas=use_pallas)
        return fn

    return Program("gaussian", G, 1, build,
                   out_rows_per_wg=gaussian_ops.LWS, out_cols=w)


def binomial_program(n_options: int = 65536, seed: int = 0,
                     use_pallas: bool = False) -> Program:
    s0, k0, ty = binomial_ops.make_inputs(n_options, seed)
    G = binomial_ops.total_work(n_options)

    def build(dev):
        a, b, c = (dev.put(jnp.asarray(x)) for x in (s0, k0, ty))

        def fn(offset, size):
            return binomial_ops.run_range(a, b, c, offset, size,
                                          use_pallas=use_pallas)
        return fn

    return Program("binomial", G, 1, build,
                   out_rows_per_wg=binomial_ops.LWS, out_cols=1)


def mandelbrot_program(px: int = 512, max_iter: int = 256,
                       use_pallas: bool = False) -> Program:
    G = mandelbrot_ops.total_work(px)

    def build(dev):
        def fn(offset, size):
            return mandelbrot_ops.run_range(
                offset, size, width=px, height=px, max_iter=max_iter,
                use_pallas=use_pallas)
        return fn

    return Program("mandelbrot", G, 1, build,
                   out_rows_per_wg=mandelbrot_ops.LWS * px, out_cols=1,
                   out_dtype=np.int32)


def nbody_program(n_bodies: int = 8192, seed: int = 0,
                  use_pallas: bool = False) -> Program:
    pm, vel = nbody_ops.make_inputs(n_bodies, seed)
    G = nbody_ops.total_work(n_bodies)

    def build(dev):
        pmd = dev.put(jnp.asarray(pm))
        vd = dev.put(jnp.asarray(vel))

        def fn(offset, size):
            return nbody_ops.run_range(pmd, vd, offset, size,
                                       use_pallas=use_pallas)
        return fn

    return Program("nbody", G, 1, build,
                   out_rows_per_wg=nbody_ops.LWS, out_cols=7)


def ray_program(which: int = 1, px: int = 256) -> Program:
    scene = ray_ref.make_scene(which)
    G = ray_ops.total_work(px)

    def build(dev):
        sc = {k: dev.put(v) for k, v in scene.items()}

        def fn(offset, size):
            img = ray_ops.run_range(sc, offset, size, width=px, height=px)
            return img.reshape(-1, 3)
        return fn

    return Program(f"ray{which}", G, 1, build,
                   out_rows_per_wg=ray_ops.LWS * px, out_cols=3)


PROGRAMS = {
    "gaussian": gaussian_program,
    "binomial": binomial_program,
    "mandelbrot": mandelbrot_program,
    "nbody": nbody_program,
    "ray1": lambda **kw: ray_program(1, **kw),
    "ray2": lambda **kw: ray_program(2, **kw),
}


def reference_output(program_name: str, **kwargs) -> np.ndarray:
    """Single-device single-packet execution (the correctness oracle for
    co-executed outputs)."""
    prog = PROGRAMS[program_name](**kwargs)

    class _Dev:
        def put(self, x):
            return x

    fn = prog.build(_Dev())
    out = np.asarray(fn(0, prog.total_work))
    return out.reshape(prog.total_work * prog.out_rows_per_wg, prog.out_cols)
