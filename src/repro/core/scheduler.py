"""Load-balancing schedulers (the paper's §II-B, faithful formulas).

Work model: a data-parallel task described by a :class:`repro.core.region.
Region` — a 1-D or 2-D NDRange with per-dimension offset/size/lws.  The
carved axis is dim 0 (the paper's NDRange work-groups; here: image rows,
pixel blocks, options, bodies, microbatches, requests).  Packets are
contiguous ``[offset, offset+size)`` runs of dim-0 units, ``lws``-aligned
except for the final remainder; 2-D regions are carved as **row panels**
(each packet spans the full dim-1 extent), and every packet carries its
absolute geometry as ``Packet.region``.  A bare ``total_work`` integer is
still accepted everywhere and means the legacy 1-D region at offset 0.

* ``Static``      — one packet per device, sized proportionally to its
                    computing power; delivery order configurable
                    (``Static`` = CPU,iGPU,GPU / ``Static rev`` = reversed).
* ``Dynamic(n)``  — n equal packets pulled from an atomic queue.
* ``HGuided``     — the paper's heterogeneity-aware guided self-scheduling:

      packet_size_i = max( m_i * lws,
                           ceil( G_r * P_i / (k_i * n * sum_j P_j) ) )

  with G_r = remaining work-groups (updated per launch), k_i in [1, 4],
  m_i the minimum-packet multiplier of lws.
* ``HGuidedOpt``  — the paper's optimized HGuided: the (m_i, k_i) pairs are
  derived from the device power *ranking* per the paper's tuning laws
  (more powerful => larger m, smaller k; best combo m={1,15,30},
  k={3.5,1.5,1} for a weak/mid/strong triple), plus optional online EWMA
  power re-estimation (beyond-paper, used by the hetero-DP trainer).
* ``HGuidedDeadline`` — beyond-paper serving variant of HGuidedOpt: packet
  sizes are additionally capped by the tightest remaining deadline slack
  (``update_slack``), shrinking toward ``lws`` as deadlines close in.

* ``HGuidedEnergy`` — beyond-paper energy-capped variant: the deadline
  scheduler's cap shape applied to joules — packets are carved so the
  run's *predicted* energy (from the profiles'
  :class:`repro.energy.model.PowerModel`) stays under a per-run
  ``energy_budget_j``, degrading toward the most-efficient device when
  the budget binds.

* ``HGuidedSteal``   — beyond-paper "new load balancing algorithm": a
  deadline-capable HGuided that dispatches through *leased packet plans*
  (see below) and lets an idle device steal half the largest victim lease
  before falling back to the global carve, so the run tail stays balanced
  without per-packet lock traffic.

All schedulers are thread-safe (the paper's "atomic queue") and support
``requeue`` of in-flight packets for fault tolerance.

Dispatch hot path — leases vs per-packet locking
------------------------------------------------

``next_packet`` is the paper's hand-off: one global lock acquisition per
packet.  On an oversubscribed host every contended acquisition costs a
thread wake (~200µs on the 2-core reference container) — for small tail
packets that overhead rivals the compute itself.  The lease API amortizes
it:

* ``lease(device, k)`` carves up to ``k`` packets under ONE lock
  acquisition into a per-device :class:`PacketLease` (a local deque owned
  by the device thread; pops touch only the lease's own uncontended
  lock).  ``k`` adapts per device: it starts at 1 and grows
  geometrically while the device's observed packet latency (fed via
  ``note_packet_latency``) is small against ``lease_overhead_s``, and
  every lease is capped to half the device's fair share of the remaining
  work so the tail stays balanced as ``remaining()`` falls.
* ``acquire(device)`` is the device thread's hot path: pop the local
  lease; when empty, refill via the scheduler's ``_refill`` hook
  (``HGuidedSteal``: steal half the largest victim lease first, then the
  global carve; everything else: global carve).
* ``release(device)`` must be called once per acquired packet (after its
  commit, or after its ``requeue``) — together with ``drained()`` this
  gives engines a lock-free exactly-once drain test: work is continuously
  visible in ``remaining() + outstanding`` from carve to commit, and a
  retry epoch counter invalidates the check if a requeue raced it.
* leased-but-unexecuted packets still count as outstanding work:
  ``remaining()`` includes lease contents, and ``mark_dead`` drains a
  dead device's lease back into the retry queue (FIFO, oldest first) so
  the exact-cover invariant survives steals, leases and deaths.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import inspect
import math
import threading
import time
from dataclasses import dataclass, field
from typing import (Callable, Deque, Dict, Iterable, List, Mapping,
                    Optional, Sequence, Tuple, Union)

from repro.core.region import Region, as_region
from repro.energy.model import PowerModel


@dataclass(frozen=True)
class Packet:
    # offset/size are dim-0 units RELATIVE to the scheduled region's start
    # (so coverage invariants read [0, G) regardless of the ROI's origin);
    # ``region`` is the packet's ABSOLUTE geometry — the row panel the
    # executor runs
    offset: int
    size: int
    seq: int
    device: int
    # fault-tolerance provenance: a requeued packet keeps its original seq
    # and is re-issued with retried=True, so RunResult.packets never reports
    # more sequence numbers than packets actually carved
    retried: bool = False
    region: Optional[Region] = None


@dataclass
class DeviceProfile:
    name: str
    power: float                 # computing power P_i (work-groups / s)
    min_mult: int = 1            # m_i: min packet = m_i * lws
    k: float = 2.0               # k_i decay constant
    # energy model of the device behind this profile (None = joule-blind):
    # the energy-capped scheduler ranks devices by busy_w / power (J/wg)
    power_model: Optional[PowerModel] = None


@dataclass
class SchedStats:
    """Dispatch-path counters (exact in single-threaded use, e.g. the
    simulator; best-effort under threads, where they are only read for
    reporting)."""
    lock_crossings: int = 0      # global-lock acquisitions on the hot path
    next_packets: int = 0        # per-packet-lock hand-offs
    leases: int = 0              # lease refills granted
    leased_packets: int = 0      # packets handed out through leases
    local_pops: int = 0          # packets popped from a local lease
    steals: int = 0              # successful steal operations
    stolen_packets: int = 0      # packets moved by steals
    reclaims: int = 0            # leases drained back by preemption
    reclaimed_packets: int = 0   # packets returned by reclaim_lease

    def merge(self, other: "SchedStats") -> "SchedStats":
        """Accumulate ``other`` into this instance (per-tenant rollup:
        one run's scheduler dies with its _RunContext, so a tenant's
        cross-run dispatch accounting sums the per-run counters here).
        Returns self for chaining."""
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self


class PacketLease:
    """A device-local run of leased packets.

    The owning device thread pops from the front; a thief takes the back
    half.  All mutation is under the lease's own lock — uncontended on
    the hot path (only steals and the owner ever touch it), so a pop
    costs a few hundred nanoseconds instead of a contended global-lock
    hand-off."""

    __slots__ = ("device", "_dq", "_lock")

    def __init__(self, device: int):
        self.device = device
        self._dq: Deque[Packet] = collections.deque()
        self._lock = threading.Lock()

    def popleft(self) -> Optional[Packet]:
        with self._lock:
            return self._dq.popleft() if self._dq else None

    def extend(self, pkts: Iterable[Packet]) -> None:
        with self._lock:
            self._dq.extend(pkts)

    def steal_half(self) -> List[Packet]:
        """Remove and return the back half (newest-first order; empty if
        the lease holds fewer than two packets — the owner always keeps
        at least one)."""
        with self._lock:
            n = len(self._dq) // 2
            return [self._dq.pop() for _ in range(n)]

    def drain(self) -> List[Packet]:
        with self._lock:
            out = list(self._dq)
            self._dq.clear()
            return out

    def __len__(self) -> int:
        return len(self._dq)

    @property
    def work(self) -> int:
        """Total leased work-groups (locked: exact)."""
        with self._lock:
            return sum(p.size for p in self._dq)


class SchedulerBase:
    # lease tuning (class attrs so plugins/tests can override): one global
    # lock crossing is worth ~a contended thread wake on the reference
    # container; leases grow until that cost is ≤ lease_overhead_frac of
    # the lease's compute time (2%: over-leasing is cheap — the tail
    # budget bounds it and steals rebalance it), never past lease_k_max
    lease_overhead_s: float = 2e-4
    lease_overhead_frac: float = 0.02
    lease_k_max: int = 64
    def __init__(self, total_work: Union[int, Region], lws: int,
                 devices: Sequence[DeviceProfile]):
        """``total_work`` is a Region (NDRange) or a bare work-group count
        (legacy 1-D).  With a Region, the carved axis is dim 0 and ``lws``
        is taken from ``region.dims[0].lws`` (the argument is ignored)."""
        self.region = as_region(total_work, lws)
        self.G = self.region.dims[0].size
        self.lws = self.region.dims[0].lws
        assert self.G > 0 and self.lws > 0
        self.devices = list(devices)
        self._lock = threading.Lock()
        self._offset = 0
        self._seq = 0
        # retry pool: FIFO (oldest requeued packet re-issues first), so a
        # straggler's early packet cannot be starved behind later requeues
        self._retry: Deque[Packet] = collections.deque()
        n = len(self.devices)
        self._leases: List[PacketLease] = [PacketLease(i) for i in range(n)]
        self._lease_k: List[int] = [1] * n        # adaptive lease size
        self._lease_lat: List[Optional[float]] = [None] * n
        self._outstanding: List[int] = [0] * n    # acquired, not released
        self._wait_s: List[float] = [0.0] * n     # time in dispatch calls
        self._crossings: List[int] = [0] * n      # per-device lock crossings
        self._dead: set = set()                   # devices seen by mark_dead
        self._retry_epoch = 0                     # bumped on every requeue
        self.stats = SchedStats()

    # -- public ------------------------------------------------------------
    def set_lease_params(self, *, lease_overhead_s: Optional[float] = None,
                         lease_overhead_frac: Optional[float] = None,
                         lease_k_max: Optional[int] = None) -> "SchedulerBase":
        """Override the lease growth-law constants on THIS instance.

        The class-attribute defaults above are hand-picked for the
        reference container; sessions (``tuned=`` / lease kwargs) and the
        simulators inject calibrated values here instead of editing the
        module.  ``None`` leaves a constant untouched.  Returns ``self``
        so construction sites can chain."""
        if lease_overhead_s is not None:
            if lease_overhead_s <= 0:
                raise ValueError(f"lease_overhead_s must be > 0, "
                                 f"got {lease_overhead_s}")
            self.lease_overhead_s = float(lease_overhead_s)
        if lease_overhead_frac is not None:
            if not 0 < lease_overhead_frac <= 1:
                raise ValueError(f"lease_overhead_frac must be in (0, 1], "
                                 f"got {lease_overhead_frac}")
            self.lease_overhead_frac = float(lease_overhead_frac)
        if lease_k_max is not None:
            if int(lease_k_max) < 1:
                raise ValueError(f"lease_k_max must be >= 1, "
                                 f"got {lease_k_max}")
            self.lease_k_max = int(lease_k_max)
        return self

    def next_packet(self, device: int) -> Optional[Packet]:
        """Per-packet hand-off: ONE global lock acquisition per packet
        (the paper's atomic queue; the baseline the lease API beats)."""
        t0 = time.perf_counter()
        try:
            with self._lock:
                self.stats.lock_crossings += 1
                self._crossings[device] += 1
                self.stats.next_packets += 1
                self._outstanding[device] += 1
                pkt = self._pop_retry_locked(device)
                if pkt is None:
                    pkt = self._carve(device)
                if pkt is None:
                    self._outstanding[device] -= 1
                return pkt
        finally:
            self._wait_s[device] += time.perf_counter() - t0

    def acquire(self, device: int) -> Optional[Packet]:
        """Leased hot path: pop the device's local lease (uncontended);
        when empty, refill through ``_refill`` (one global crossing for a
        whole packet plan).  Pair every non-None return with one
        ``release(device)`` call after the packet commits or requeues."""
        while True:
            pkt = self._pop_local(device)
            if pkt is not None:
                return pkt
            if not self._refill(device):
                return None

    def release(self, device: int) -> None:
        """Account a previously acquired packet as done (committed or
        requeued).  Owner-thread only; pairs with next_packet/acquire."""
        self._outstanding[device] -= 1

    def lease(self, device: int, k: Optional[int] = None) -> int:
        """Refill ``device``'s local lease under ONE lock acquisition.

        Drains the retry pool FIFO first, then carves fresh packets, up
        to ``k`` packets (``None`` = adaptive) — but never more work than
        half the device's fair share of what remains, so leases shrink
        with the tail.  Returns the number of packets leased."""
        t0 = time.perf_counter()
        granted: List[Packet] = []
        try:
            with self._lock:
                self.stats.lock_crossings += 1
                self._crossings[device] += 1
                if k is None:
                    k = self._adaptive_k_locked(device)
                k = max(1, int(k))
                # tail budget: never lease more than HALF the device's
                # power-proportional fair share of remaining() (uncarved
                # pool + retries + work already leased anywhere) — a
                # slow device must not hoard packets the fast ones will
                # be idle for (steals recover the rest, where available)
                left = self._remaining_locked()
                d = self.devices[device]
                total_p = sum(x.power for x in self.devices) or 1.0
                budget = max(self.lws,
                             int(left * d.power / (2.0 * total_p)))
                work = 0
                while len(granted) < k and work < budget:
                    pkt = self._pop_retry_locked(device)
                    if pkt is None:
                        pkt = self._carve(device)
                    if pkt is None:
                        break
                    granted.append(pkt)
                    work += pkt.size
                if granted:
                    self.stats.leases += 1
                    self.stats.leased_packets += len(granted)
                    self._leases[device].extend(granted)
            return len(granted)
        finally:
            self._wait_s[device] += time.perf_counter() - t0

    def steal(self, thief: int) -> int:
        """Move the back half of the largest victim lease onto ``thief``'s
        lease (packets re-stamped to the thief, provenance preserved).
        Returns the number of packets stolen.  Available on every
        scheduler; ``HGuidedSteal`` wires it into its refill path."""
        t0 = time.perf_counter()
        try:
            with self._lock:
                self.stats.lock_crossings += 1
                self._crossings[thief] += 1
                victim = None
                best = 0
                for i, lease in enumerate(self._leases):
                    if i == thief:
                        continue
                    w = lease.work
                    if w > best:
                        best, victim = w, lease
                if victim is None:
                    return 0
                stolen = victim.steal_half()
                if not stolen:
                    return 0
                stolen.reverse()          # back half, restored to FIFO order
                self._leases[thief].extend(
                    dataclasses.replace(p, device=thief) for p in stolen)
                self.stats.steals += 1
                self.stats.stolen_packets += len(stolen)
                return len(stolen)
        finally:
            self._wait_s[thief] += time.perf_counter() - t0

    def note_packet_latency(self, device: int, seconds: float) -> None:
        """Feed the device's observed per-packet wall latency — this is
        what grows/shrinks its adaptive lease size.  Owner-thread only."""
        if seconds > 0:
            prev = self._lease_lat[device]
            self._lease_lat[device] = seconds if prev is None \
                else 0.5 * seconds + 0.5 * prev

    def requeue(self, pkt: Packet) -> None:
        """Return an in-flight packet to the queue (device failure)."""
        with self._lock:
            self._requeue_locked(pkt)

    def reclaim_lease(self, device: int) -> int:
        """Return ``device``'s leased-but-unexecuted packets to the retry
        pool WITHOUT marking the device dead (the multi-tenant preemption
        hook: a device denied at the grant boundary must not strand its
        planned packets — any still-granted device of the same run picks
        them up from the retry queue).  The device stays eligible for
        future leases; in-flight (acquired) packets are untouched, so the
        exact-cover and ``drained()`` protocols hold across preemptions.
        Returns the number of packets reclaimed."""
        with self._lock:
            pkts = self._leases[device].drain()
            for pkt in pkts:
                self._requeue_locked(pkt)
            if pkts:
                self.stats.reclaims += 1
                self.stats.reclaimed_packets += len(pkts)
            return len(pkts)

    def mark_dead(self, device: int) -> None:
        """Notify that a device died: its leased-but-unexecuted packets
        re-enter the retry pool (preserving the exact-cover invariant),
        and pre-assignment schedulers additionally release the dead
        device's unclaimed chunk via ``_release_dead_locked`` — otherwise
        that work is stranded and the run can never drain."""
        with self._lock:
            self._dead.add(device)
            for pkt in self._leases[device].drain():
                self._requeue_locked(pkt)
            self._release_dead_locked(device)

    def remaining(self) -> int:
        """Outstanding work-groups still owned by the scheduler: uncarved
        pool + retry queue + leased-but-unexecuted packets.  (Leases
        count: serving admission and deadline slack caps must see work a
        device has planned but not run.)"""
        with self._lock:
            return self._remaining_locked()

    def _remaining_locked(self) -> int:
        """remaining() under the held lock — subclasses with different
        pool accounting (Static*) override this ONE place; lease()'s
        tail budget uses it too."""
        return (self.G - self._offset
                + sum(p.size for p in self._retry)
                + sum(lease.work for lease in self._leases))

    def outstanding(self) -> int:
        """Packets handed out via next_packet/acquire and not yet
        released (approximate under concurrent mutation)."""
        return sum(self._outstanding)

    def drained(self) -> bool:
        """Lock-free exactly-once drain test for engines.

        Sound because (a) a packet is continuously visible in
        ``remaining()`` until it is popped, and in ``outstanding`` from
        *before* that pop until its ``release`` (which follows its commit
        or its requeue), and (b) the only transition that re-adds work —
        a requeue — bumps ``_retry_epoch``, so the re-read detects any
        race that could hide a packet between the two reads."""
        e0 = self._retry_epoch
        if self.remaining() != 0:
            return False
        if sum(self._outstanding) != 0:
            return False
        return self._retry_epoch == e0

    def sched_wait_s(self) -> List[float]:
        """Per-device wall time spent inside dispatch-path scheduler
        calls (next_packet / lease / steal): lock waits + carve work."""
        return list(self._wait_s)

    def lock_crossings_by_device(self) -> List[int]:
        """Per-device global-lock crossings on the dispatch hot path
        (next_packet / lease / steal).  Sums to
        ``stats.lock_crossings``; the energy meter charges each
        crossing at the crossing device's ``PowerModel.lock_j``."""
        return list(self._crossings)

    def update_power(self, device: int, power: float) -> None:
        """Online power re-estimation hook (HGuidedOpt uses it)."""
        with self._lock:
            self.devices[device].power = max(power, 1e-9)

    # -- internals ----------------------------------------------------------
    def _pop_retry_locked(self, device: int) -> Optional[Packet]:
        """FIFO retry re-issue: the OLDEST requeued packet goes out first
        (LIFO would re-issue a straggler's early packet last, extending
        the tail).  Provenance: original seq, retried=True."""
        if not self._retry:
            return None
        pkt = self._retry.popleft()
        return dataclasses.replace(pkt, device=device, retried=True)

    def _requeue_locked(self, pkt: Packet) -> None:
        self._retry.append(pkt)
        self._retry_epoch += 1

    def _pop_local(self, device: int) -> Optional[Packet]:
        """Pop the device's lease.  The outstanding claim is taken BEFORE
        the packet leaves the lease, so the packet is never invisible to
        ``drained()`` readers (remaining first, outstanding second)."""
        lease = self._leases[device]
        with lease._lock:
            if not lease._dq:
                return None
            self._outstanding[device] += 1
            pkt = lease._dq.popleft()
        self.stats.local_pops += 1
        return pkt

    def _refill(self, device: int) -> int:
        """Hook: pull new work into the device's lease; returns packets
        gained.  Base: global carve.  HGuidedSteal: steal first."""
        return self.lease(device)

    def _adaptive_k_locked(self, device: int) -> int:
        """Grow the lease geometrically while one lock crossing costs
        more than ``lease_overhead_frac`` of the lease's compute time;
        shrink it when packets are slow (balance beats amortization)."""
        k = self._lease_k[device]
        lat = self._lease_lat[device]
        if lat is not None and lat > 0:
            target = self.lease_overhead_s / (self.lease_overhead_frac * lat)
            if k < target:
                k = min(k * 2, self.lease_k_max)
            elif k > 2 * target:
                k = max(1, k // 2)
        self._lease_k[device] = k
        return k

    def _release_dead_locked(self, device: int) -> None:
        """Hook: pre-assignment schedulers (Static*) release a dead
        device's unclaimed chunk here.  Pool-carving schedulers need do
        nothing (survivors drain the shared queue)."""

    def _bump(self) -> int:
        self._seq += 1
        return self._seq - 1

    def _packet(self, offset: int, size: int, device: int) -> Packet:
        """Mint a packet: relative dim-0 carve + its absolute row panel."""
        return Packet(offset, size, self._bump(), device,
                      region=self.region.row_panel(offset, size))

    def _take(self, size: int, device: int) -> Optional[Packet]:
        left = self.G - self._offset
        if left <= 0:
            return None
        size = min(size, left)
        pkt = self._packet(self._offset, size, device)
        self._offset += size
        return pkt

    def _align(self, size: int) -> int:
        return max(self.lws, self.lws * math.ceil(size / self.lws))

    def _carve(self, device: int) -> Optional[Packet]:
        raise NotImplementedError


class StaticScheduler(SchedulerBase):
    """One power-proportional packet per device. ``order`` gives the delivery
    order of the chunks over the work range; ``reverse`` flips the default
    order (paper: Static vs Static rev — ``static_rev`` is registered as this
    class with ``reverse=True``, a plain config rather than a closure)."""

    def __init__(self, total_work, lws, devices,
                 order: Optional[List[int]] = None, reverse: bool = False):
        super().__init__(total_work, lws, devices)
        if order is None:
            order = list(range(len(devices)))
            if reverse:
                order.reverse()
        self.order = list(order)
        total_p = sum(d.power for d in self.devices)
        sizes = {}
        acc = 0
        for idx, di in enumerate(self.order):
            if idx == len(self.order) - 1:
                sizes[di] = self.G - acc
            else:
                s = min(self._align(self.G * self.devices[di].power / total_p),
                        self.G - acc)
                sizes[di] = s
                acc += s
        self._sizes = sizes
        self._given: Dict[int, bool] = {}

    def _chunk_bounds(self, device: int) -> Tuple[int, int]:
        # chunks are laid out in `order`: compute this device's offset
        off = 0
        for di in self.order:
            if di == device:
                break
            off += self._sizes[di]
        return off, self._sizes[device]

    def _carve(self, device: int) -> Optional[Packet]:
        if self._given.get(device):
            return None
        off, size = self._chunk_bounds(device)
        if size <= 0 or off >= self.G:
            self._given[device] = True
            return None
        self._given[device] = True
        return self._packet(off, min(size, self.G - off), device)

    def _release_dead_locked(self, device: int) -> None:
        # a dead device's unclaimed pre-assigned chunk is released to the
        # retry queue so survivors can absorb it (it would strand otherwise:
        # _carve only hands a chunk to its owner)
        if self._given.get(device):
            return
        self._given[device] = True
        off, size = self._chunk_bounds(device)
        size = min(size, self.G - off)
        if size > 0 and off < self.G:
            self._requeue_locked(self._packet(off, size, device))

    def _remaining_locked(self) -> int:  # static: all work pre-assigned
        done = sum(self._sizes[d] for d, g in self._given.items() if g)
        return (self.G - done + sum(p.size for p in self._retry)
                + sum(lease.work for lease in self._leases))


class DynamicScheduler(SchedulerBase):
    """n_packets equal chunks from an atomic queue (paper's Dynamic)."""

    def __init__(self, total_work, lws, devices, n_packets: int = 128):
        super().__init__(total_work, lws, devices)
        self.packet_size = self._align(math.ceil(self.G / n_packets))

    def _carve(self, device: int) -> Optional[Packet]:
        return self._take(self.packet_size, device)


class HGuidedScheduler(SchedulerBase):
    """The paper's HGuided (eq. in §II-B)."""

    def _carve(self, device: int) -> Optional[Packet]:
        d = self.devices[device]
        total_p = sum(x.power for x in self.devices)
        G_r = self.G - self._offset
        if G_r <= 0:
            return None
        n = len(self.devices)
        raw = math.ceil(G_r * d.power / (d.k * n * total_p))
        size = max(d.min_mult * self.lws, self._align(raw))
        return self._take(self._cap_size(device, size), device)

    def _cap_size(self, device: int, size: int) -> int:
        """Hook for subclasses to bound a carved packet (deadline caps)."""
        return size


def tuned_profiles(devices: Sequence[DeviceProfile]) -> List[DeviceProfile]:
    """Apply the paper's tuning laws by power ranking: strongest gets
    (m=30, k=1), mid (15, 1.5), weakest (1, 3.5); for n != 3 interpolate in
    rank space.  Single-k fallback (paper conclusion d) is k=2."""
    n = len(devices)
    out = [DeviceProfile(d.name, d.power, d.min_mult, d.k,
                         power_model=d.power_model) for d in devices]
    if n == 1:
        out[0].min_mult, out[0].k = 1, 2.0
        return out
    ranked = sorted(range(n), key=lambda i: devices[i].power)
    m_lo, m_hi = 1, 30
    k_lo, k_hi = 1.0, 3.5
    for rank, i in enumerate(ranked):
        t = rank / (n - 1)            # 0 = weakest, 1 = strongest
        if n == 3:                    # exact paper combo
            m = (1, 15, 30)[rank]
            k = (3.5, 1.5, 1.0)[rank]
        else:
            m = round(m_lo + (m_hi - m_lo) * t)
            k = k_hi + (k_lo - k_hi) * t
        out[i].min_mult = int(m)
        out[i].k = float(k)
    return out


class HGuidedOptScheduler(HGuidedScheduler):
    """HGuided with the paper's tuned (m, k) pairs + online EWMA powers.

    The minimum-packet multipliers are additionally capped at 1/4 of the
    device's fair share: the paper's m=30 is tuned for a 3-device desktop;
    at fleet scale a large forced minimum would hand a group half its share
    in one unadaptable packet."""

    def __init__(self, total_work, lws, devices, ewma: float = 0.5):
        region = as_region(total_work, lws)
        G, lws = region.dims[0].size, region.dims[0].lws
        profs = tuned_profiles(devices)
        total_p = sum(d.power for d in profs) or 1.0
        n = len(profs)
        for d in profs:
            share_wg = G * d.power / total_p
            d.min_mult = max(1, min(d.min_mult, int(share_wg / (4 * lws))))
            if n > 8:
                # fleet-scale adaptation (beyond paper): with near-equal
                # groups (a) k=1 issues a device's whole fair share as its
                # first packet and removes all adaptation headroom — the
                # paper's single-k conclusion (k=2) is the right floor; and
                # (b) every group is "untuned", so the paper's conclusion
                # (e) applies: keep m=1 — a forced minimum packet is what
                # strands work on stragglers at the tail
                d.k = max(d.k, 2.0)
                d.min_mult = 1
        super().__init__(total_work, lws, profs)
        self.ewma = ewma
        self._obs: Dict[int, float] = {}

    def observe(self, device: int, wg_per_s: float) -> None:
        """Feed measured throughput; re-rank powers online."""
        prev = self._obs.get(device)
        cur = wg_per_s if prev is None else (self.ewma * wg_per_s
                                             + (1 - self.ewma) * prev)
        self._obs[device] = cur
        self.update_power(device, cur)


class HGuidedDeadlineScheduler(HGuidedOptScheduler):
    """Deadline-aware HGuidedOpt for time-constrained serving.

    On top of the tuned (m, k) pairs and online EWMA powers, every carved
    packet is capped so its *predicted* execution time on the target device
    fits inside a fraction of the tightest remaining slack:

        cap_i = slack * slack_fraction * P_i      (work-groups)

    The caller (CoexecServer / simulate_serving) refreshes the slack before
    each dispatch round via ``update_slack(min_deadline - now)``.  As
    deadlines close in, packets shrink toward ``lws`` — more scheduling
    points, finer EDF admission, less work stranded behind a long packet
    when a request is about to miss.  With no deadline pressure
    (``slack=None``) it degenerates to HGuidedOpt exactly.
    """

    def __init__(self, total_work, lws, devices, ewma: float = 0.5,
                 slack_fraction: float = 0.5,
                 slack_s: Optional[float] = None):
        super().__init__(total_work, lws, devices, ewma=ewma)
        assert 0.0 < slack_fraction <= 1.0
        self.slack_fraction = slack_fraction
        self._slack: Optional[float] = None
        if slack_s is not None:     # construction-time slack (session submits
            self.update_slack(slack_s)   # build one scheduler per round)

    def update_slack(self, slack_s: Optional[float]) -> None:
        """Set the tightest remaining slack (seconds); None lifts the cap."""
        # plain attribute store (atomic in CPython); _carve runs under the
        # scheduler lock and only reads it once
        self._slack = None if slack_s is None else max(0.0, float(slack_s))

    def _cap_size(self, device: int, size: int) -> int:
        slack = self._slack
        if slack is None:
            return size
        d = self.devices[device]
        cap_wg = d.power * slack * self.slack_fraction
        # floor-align to lws but never below one work-group unit: a starved
        # device must still drain the queue, one minimal packet at a time
        cap = max(self.lws, self.lws * int(cap_wg // self.lws))
        return min(size, cap)


class HGuidedEnergyScheduler(HGuidedDeadlineScheduler):
    """Energy-capped HGuided for joule-constrained runs.

    The deadline scheduler's slack cap, rotated into the energy
    dimension: every carved packet's *predicted* joules are charged
    against a per-run ``energy_budget_j``, and packets for inefficient
    devices shrink as the budget's headroom burns down.

    Per device the marginal cost is its busy efficiency
    ``j_i = busy_w_i / P_i`` (J per work-group at full speed, from the
    profile's :class:`repro.energy.model.PowerModel`).  The floor cost of
    the remaining work is ``G_r * j_min`` — what it would cost if the
    most-efficient alive device ran all of it.  The spendable *headroom*
    is what the budget allows above that floor:

        headroom = (budget - spent) - G_r * j_min
        cap_i    = headroom * energy_fraction / (j_i - j_min)

    The most-efficient device is never capped (its packets cost the
    floor rate); every other device may burn at most a fraction of the
    headroom per packet, so as the budget binds their packets shrink —
    and once the headroom cannot afford even one ``lws`` packet above
    the floor rate, the device is *denied fresh work outright*: it
    retires from the run and the split degrades toward the
    most-efficient device, which drains the tail alone.  (Shrinking
    packets without denial would not shift work — a fast device pulling
    ``lws``-sized packets still pulls at nearly full rate; only refusal
    moves its share.)  This trades makespan for joules, exactly the
    J-vs-s flip the green-computing survey measures.  The budget stays
    a soft cap: predicted spend can overshoot by the packets already in
    flight when it bound.  Drain stays guaranteed because the
    most-efficient *alive* device is never denied (``mark_dead``
    re-elects it), and retry packets are never refused.  With
    ``energy_budget_j=None`` (or joule-blind profiles) it degenerates
    to HGuidedDeadline exactly.

    Deadline and energy caps compose: serving callers still feed
    ``update_slack`` and both caps apply (the tighter one wins).
    """

    def __init__(self, total_work, lws, devices, ewma: float = 0.5,
                 slack_fraction: float = 0.5,
                 slack_s: Optional[float] = None,
                 energy_budget_j: Optional[float] = None,
                 energy_fraction: float = 0.5):
        super().__init__(total_work, lws, devices, ewma=ewma,
                         slack_fraction=slack_fraction, slack_s=slack_s)
        assert 0.0 < energy_fraction <= 1.0
        self.energy_budget_j = None if energy_budget_j is None \
            else float(energy_budget_j)
        self.energy_fraction = energy_fraction
        self._spent_j = 0.0           # predicted joules charged at issue

    def predicted_spend_j(self) -> float:
        """Joules the issued packets are predicted to burn (requeued
        packets are conservatively re-charged on re-issue)."""
        with self._lock:
            return self._spent_j

    def _j_per_wg_locked(self, device: int) -> float:
        d = self.devices[device]
        pm = d.power_model
        if pm is None or pm.busy_w <= 0:
            return 0.0                # unmodeled device: cannot predict
        return pm.busy_w / max(d.power, 1e-9)

    def _min_j_per_wg_locked(self) -> float:
        vals = [self._j_per_wg_locked(i) for i in range(len(self.devices))
                if i not in self._dead]
        vals = [v for v in vals if v > 0]
        return min(vals) if vals else 0.0

    def _allow_wg_locked(self, device: int) -> Optional[float]:
        """Work-groups of headroom this device may burn per packet, or
        None when it is exempt (no budget / most-efficient / unmodeled /
        already dead)."""
        budget = self.energy_budget_j
        if budget is None or device in self._dead:
            return None
        j_d = self._j_per_wg_locked(device)
        j_min = self._min_j_per_wg_locked()
        if j_d <= 0 or j_min <= 0 or j_d <= j_min * (1 + 1e-12):
            return None               # most-efficient (or unmodeled)
        headroom = ((budget - self._spent_j)
                    - self._remaining_locked() * j_min)
        return max(0.0, headroom) * self.energy_fraction / (j_d - j_min)

    def _cap_size(self, device: int, size: int) -> int:
        size = super()._cap_size(device, size)     # deadline cap first
        allow_wg = self._allow_wg_locked(device)
        if allow_wg is None:
            return size
        cap = max(self.lws, self.lws * int(allow_wg // self.lws))
        return min(size, cap)

    def _charge_locked(self, device: int, size: int) -> None:
        if self.energy_budget_j is not None:
            self._spent_j += size * self._j_per_wg_locked(device)

    def _carve(self, device: int) -> Optional[Packet]:
        # deny-and-retire: when the headroom cannot afford even one
        # ``lws`` packet above the floor rate, this device gets no fresh
        # work — refusal (not shrinkage) is what actually moves its
        # share onto the efficient device.  Retries are never refused.
        allow_wg = self._allow_wg_locked(device)
        if allow_wg is not None and allow_wg < self.lws:
            return None
        pkt = super()._carve(device)
        if pkt is not None:
            self._charge_locked(device, pkt.size)
        return pkt

    def _pop_retry_locked(self, device: int) -> Optional[Packet]:
        pkt = super()._pop_retry_locked(device)
        if pkt is not None:
            self._charge_locked(device, pkt.size)
        return pkt


class HGuidedStealScheduler(HGuidedDeadlineScheduler):
    """The repo's new load-balancing algorithm: lease-amortized HGuided
    dispatch with a work-stealing tail.

    Carving law = HGuidedDeadline (tuned (m, k) pairs, online EWMA
    powers, optional slack cap — with ``slack_s=None`` it sizes packets
    exactly like HGuidedOpt).  What changes is the *hand-off*: devices
    dispatch through ``acquire()`` (leased packet plans, one global lock
    crossing per plan), and an idle device first drains its own lease,
    then **steals half the largest victim lease**, and only then falls
    back to the global carve.  Stealing keeps every device busy through
    the run tail — the stolen packets are exactly the ones a loaded
    device had planned but not started — while the lease amortization
    removes the per-packet lock hand-off the paper's management-overhead
    accounting charges against co-execution."""

    def _refill(self, device: int) -> int:
        # cheap unlocked peek (len() is GIL-atomic): only pay the steal's
        # lock crossing when some victim lease is plausibly non-empty
        if any(len(lease) for i, lease in enumerate(self._leases)
               if i != device):
            if self.steal(device):
                return 1
        return self.lease(device)


class GraphProgress:
    """Work accounting across the many scheduler contexts of one run graph.

    Each DAG node dispatches through its *own* scheduler instance
    (one ``_RunContext`` per submit), so no single scheduler can answer
    "how much of the graph is left?".  This tracker can: every submitted
    node registers its total dim-0 work up front; when its run context
    constructs its scheduler it attaches it (``remaining()`` then reads
    the live lease/exact-cover bookkeeping instead of the static total);
    terminal nodes — committed, failed, cancelled — drop out.

    Thread-safe: the session registers on the submit thread, run contexts
    attach from pooled runner threads, and ``remaining()`` may be polled
    by any observer.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._total: Dict[object, int] = {}      # node -> registered wg
        self._live: Dict[object, SchedulerBase] = {}

    def register(self, key: object, total_work: int) -> None:
        with self._lock:
            self._total[key] = int(total_work)

    def attach(self, key: object, sched: "SchedulerBase") -> None:
        """Swap the node's static total for its live scheduler."""
        with self._lock:
            if key in self._total:
                self._live[key] = sched

    def complete(self, key: object) -> None:
        """Drop a terminal node (done, failed, or cancelled)."""
        with self._lock:
            self._total.pop(key, None)
            self._live.pop(key, None)

    def remaining(self) -> int:
        """Outstanding work-groups across every non-terminal node of the
        graph: live schedulers report their exact lease/retry/pool
        accounting; not-yet-dispatched nodes report their full totals."""
        with self._lock:
            items = list(self._total.items())
            live = dict(self._live)
        out = 0
        for key, total in items:
            sched = live.get(key)
            out += sched.remaining() if sched is not None else total
        return out

    def nodes(self) -> Dict[object, int]:
        """Per-node outstanding work (same accounting as ``remaining``)."""
        with self._lock:
            items = list(self._total.items())
            live = dict(self._live)
        return {key: (live[key].remaining() if key in live else total)
                for key, total in items}

    def __len__(self) -> int:
        with self._lock:
            return len(self._total)


# ---------------------------------------------------------------- registry
@dataclass(frozen=True)
class SchedulerSpec:
    """Registry entry: the scheduler class plus its default constructor
    kwargs (how ``static_rev`` stays a config instead of a closure)."""
    cls: type
    defaults: Mapping[str, object] = field(default_factory=dict)


_REGISTRY: Dict[str, SchedulerSpec] = {}

# Back-compat view: name -> zero-config constructor.  Kept in lockstep with
# _REGISTRY by register/unregister; prefer make_scheduler()/the registry
# helpers in new code.
SCHEDULERS: Dict[str, Callable[..., "SchedulerBase"]] = {}


def register_scheduler(name: str, cls: type, *,
                       defaults: Optional[Mapping[str, object]] = None,
                       overwrite: bool = False) -> type:
    """Register a scheduler under ``name`` (the Tier-3 plugin hook).

    ``cls`` must subclass SchedulerBase with the ``(total_work, lws,
    devices, **kw)`` constructor contract; ``defaults`` are kwargs merged
    under any caller-supplied ones.  Returns ``cls`` so it can be used as a
    decorator: ``@register_scheduler("mine", defaults={...})`` is spelled
    ``register_scheduler("mine", MyScheduler)``.
    """
    if not (isinstance(cls, type) and issubclass(cls, SchedulerBase)):
        raise TypeError(f"scheduler {name!r} must be a SchedulerBase "
                        f"subclass, got {cls!r}")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"scheduler {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    spec = SchedulerSpec(cls, dict(defaults or {}))
    _REGISTRY[name] = spec
    SCHEDULERS[name] = cls if not spec.defaults else \
        functools.partial(cls, **spec.defaults)
    return cls


def unregister_scheduler(name: str) -> None:
    _REGISTRY.pop(name, None)
    SCHEDULERS.pop(name, None)


def available_schedulers() -> List[str]:
    return sorted(_REGISTRY)


def scheduler_spec(name: str) -> SchedulerSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scheduler {name!r}; registered: "
                       f"{available_schedulers()}") from None


def scheduler_accepts(name: str, param: str) -> bool:
    """True if ``name``'s constructor takes ``param`` (capability probe —
    e.g. only deadline-aware schedulers accept ``slack_s``).

    Walks the MRO so a plugin subclass with a ``**kwargs`` passthrough
    constructor still advertises its base's parameters; an explicit
    signature without ``param`` (and no ``**kwargs``) stops the walk."""
    for klass in scheduler_spec(name).cls.__mro__:
        init = klass.__dict__.get("__init__")
        if init is None:
            continue
        params = inspect.signature(init).parameters
        if param in params:
            return True
        if not any(p.kind is inspect.Parameter.VAR_KEYWORD
                   for p in params.values()):
            return False
    return False


def make_scheduler(name: str, total_work: Union[int, Region], lws: int,
                   devices: Sequence[DeviceProfile], **kw) -> SchedulerBase:
    """Build a registered scheduler over ``total_work`` — a Region
    (NDRange; ``lws`` then comes from ``dims[0].lws``) or a legacy flat
    work-group count."""
    spec = scheduler_spec(name)
    merged = {**spec.defaults, **kw}
    return spec.cls(total_work, lws, devices, **merged)


register_scheduler("static", StaticScheduler)
register_scheduler("static_rev", StaticScheduler, defaults={"reverse": True})
register_scheduler("dynamic", DynamicScheduler)
register_scheduler("hguided", HGuidedScheduler)
register_scheduler("hguided_opt", HGuidedOptScheduler)
register_scheduler("hguided_deadline", HGuidedDeadlineScheduler)
register_scheduler("hguided_energy", HGuidedEnergyScheduler)
register_scheduler("hguided_steal", HGuidedStealScheduler)


def rotate_static_order(name: str, n_devices: int,
                        round_index: int) -> Optional[List[int]]:
    """Weighted round-robin delivery order for per-round Static dispatch.

    Serving engines instantiate one scheduler per dispatch round; without
    rotating Static's fixed delivery order across rounds, every small
    round lands whole on the first-ordered device while the rest of the
    fleet idles.  Returns None for non-static schedulers (no override).
    Shared by CoexecServer and simulate_serving so the discrete-event twin
    cannot drift from the threaded server.
    """
    if name != "static":
        return None
    return [(j + round_index) % n_devices for j in range(n_devices)]
