"""Discrete-event co-execution simulator.

The threaded Engine (core/runtime.py) runs the real code paths, but this
container has one physical CPU — relative device speeds can't be reproduced
in wall-clock.  The simulator executes the *same scheduler objects* against
calibrated device models instead, which (a) reproduces the paper's
CPU/iGPU/GPU testbed faithfully, and (b) scales the evaluation to 1000+
device groups (elastic joins, failures, stragglers) in milliseconds.

Device model (per packet of size s work-groups starting at offset o):

    t = launch_overhead + s / throughput(o, s) [+ transfer costs]

* ``throughput(o, s)`` supports *irregular* programs (Ray, Mandelbrot): the
  per-work-group cost varies across the range, which is exactly what makes
  Static mis-balance in the paper.
* ``launch_overhead`` models the per-packet management/synchronization cost
  (host thread, driver queueing).  More packets => more overhead: the
  Dynamic-with-512-chunks pathology.
* init/teardown constants model the binary-mode costs; the ``opt_init`` /
  ``opt_buffers`` flags change them (and the per-packet transfer term)
  according to the measured effects of the paper's optimizations.

Events are device-completion times in a heap; the scheduler is consulted
exactly as in the threaded runtime (same next_packet/observe/requeue API).
Failures: a device dies at ``fail_at`` seconds; its in-flight packet is
requeued (fault tolerance) — stragglers: throughput multiplier drops at a
given time.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, \
    Tuple

from repro.core.metrics import PhaseBreakdown, RunResult
from repro.core.scheduler import (DeviceProfile, make_scheduler,
                                  rotate_static_order)
from repro.energy.meter import EnergyMeter, EnergyReport
from repro.energy.model import ZERO_POWER, PowerModel

# fraction of the input set that is full-size read-only buffers, re-copied
# per packet by the unoptimized buffer path
BULK_COPY_FRACTION = 0.45


class PacketCost(NamedTuple):
    """One packet's modeled cost, with its busy/stall split exposed.

    ``t`` is the wall time charged to the device's event timeline;
    ``h2d``/``d2h`` are the unhidden transfer components of it (phase
    observability).  ``busy_s`` is the slice of ``t`` the device spends
    *executing* (launch + compute); ``stall_s`` is the rest — unhidden
    transfer time the device waits out at idle watts.  The energy meter
    reads the split directly instead of re-deriving it from the transfer
    terms (``t == busy_s + stall_s`` exactly)."""
    t: float
    h2d: float
    d2h: float
    busy_s: float
    stall_s: float


@dataclass
class SimDevice:
    name: str
    throughput: float                      # work-groups / second (base)
    launch_overhead: float = 2e-4          # s per packet
    transfer_in: float = 0.0               # s per work-group of input
    transfer_out: float = 0.0              # s per work-group of output
    # irregularity: relative cost multiplier across the work range [0,1]
    irregularity: Optional[Callable[[float], float]] = None
    fail_at: Optional[float] = None        # hard failure time (s)
    straggle_at: Optional[float] = None    # throughput drops at this time
    straggle_factor: float = 1.0           # multiplier after straggle_at
    zero_copy: bool = False                # shares host memory (iGPU/CPU)
    # what the *scheduler profile* believes this device's power is, relative
    # to truth (offline profiling bias).  Static pays the full price of a
    # wrong profile; guided schedulers adapt via their shrinking tail.
    profile_bias: float = 1.0
    # per-packet multiplicative execution-time jitter (lognormal sigma)
    jitter: float = 0.0
    # energy model (busy/idle W, lock J, transfer J/byte); all-zero default
    # keeps every joule-blind config bit-identical with energy == 0
    power_model: PowerModel = ZERO_POWER
    # byte-traffic model for the transfer-energy term: a one-time stage-in
    # footprint (the program's read-only inputs) plus per-work-group
    # result bytes.  Zero-copy devices move no bytes under the
    # registered/pooled policies (same rule as the time model).
    stage_in_bytes: float = 0.0
    xfer_bytes_per_wg: float = 0.0

    def packet_bytes(self, size: int, policy: str, first: bool) -> float:
        """Bytes moved host<->device by one packet under ``policy`` (the
        energy meter's traffic term, mirroring the threaded loops): the
        per-packet path bulk re-stages the full input footprint every
        packet; registered/pooled stage it once per device and move only
        the packet's own result bytes; zero-copy devices move nothing
        except under the per-packet worst practice."""
        if policy == "per_packet":
            return self.stage_in_bytes + size * self.xfer_bytes_per_wg
        if self.zero_copy:
            return 0.0
        return (self.stage_in_bytes if first else 0.0) \
            + size * self.xfer_bytes_per_wg

    def packet_cost(self, offset: int, size: int, total: int, now: float,
                    policy: str, first: bool = True) -> PacketCost:
        """Per-packet cost under a buffer policy.

        Returns a :class:`PacketCost` ``(t, h2d_unhidden, d2h_unhidden,
        busy_s, stall_s)``: the wall time charged to the device's event
        timeline, the transfer components of it that could NOT be hidden
        behind compute (phase observability), and the busy/stall split of
        ``t`` (energy observability).

        * ``per_packet`` — every packet pays its range transfers PLUS the
          bulk re-copy of the full-size read-only inputs (the paper's
          driver worst practice), all serialized.
        * ``registered`` — the paper's buffer-flag optimization: zero-copy
          on shared-memory devices, only the necessary per-range copy on
          discrete ones — still serialized with compute.
        * ``pooled`` — registered plus the double-buffered transfer
          pipeline: packet k+1's H2D and packet k's D2H overlap packet
          k's compute, so only the transfer *exceeding* the compute window
          is charged — except the first packet's stage-in (``first``),
          which has nothing to hide behind (the pipeline fill).
        """
        # irregular work density integrated over the packet's range
        if self.irregularity is not None and total > 0:
            steps = 8
            acc = 0.0
            for i in range(steps):
                x = (offset + size * (i + 0.5) / steps) / total
                acc += self.irregularity(x)
            density = acc / steps
        else:
            density = 1.0
        # piecewise straggling: work done before straggle_at runs at full
        # speed, the remainder at straggle_factor (a packet spanning the
        # slowdown pays for its tail — this is what makes pre-assigned
        # static chunks so expensive under stragglers)
        d0 = size * density / self.throughput
        if self.straggle_at is not None:
            if now >= self.straggle_at:
                d0 = d0 / self.straggle_factor
            elif now + d0 > self.straggle_at:
                done = self.straggle_at - now
                d0 = done + (d0 - done) / self.straggle_factor
        t = self.launch_overhead + d0
        xin = self.transfer_in * size
        xout = self.transfer_out * size
        if policy == "per_packet":
            # without the flags EVERY PACKET bulk-copies the full-size
            # read-only inputs (the paper's "unnecessary complete bulk
            # copies of memory regions") — cost scales with the TOTAL
            # problem size per packet, which is what penalizes co-execution
            # (many packets) far more than a single-device run (one packet)
            h2d = xin + BULK_COPY_FRACTION * self.transfer_in * total
            d2h = xout + BULK_COPY_FRACTION * self.transfer_out * total
            return PacketCost(t + h2d + d2h, h2d, d2h, t, h2d + d2h)
        if self.zero_copy:
            # shared-memory device: the registered/pooled paths are both
            # zero-copy — there is nothing to transfer or overlap
            return PacketCost(t, 0.0, 0.0, t, 0.0)
        if policy == "registered":
            return PacketCost(t + xin + xout, xin, xout, t, xin + xout)
        # pooled: double-buffered staging — steady-state transfers hide
        # behind the compute window; the pipeline fill (the first packet's
        # stage-in, which strictly precedes its own compute) cannot
        assert policy == "pooled", policy
        if first:
            h2d = xin
            d2h = max(0.0, xout - d0)
        else:
            over = max(0.0, xin + xout - d0)
            share = xin / (xin + xout) if (xin + xout) > 0 else 0.0
            h2d = over * share
            d2h = over - h2d
        return PacketCost(t + h2d + d2h, h2d, d2h, t, h2d + d2h)

    def packet_time(self, offset: int, size: int, total: int, now: float,
                    opt_buffers: bool) -> float:
        """Legacy boolean-flag entry point (kept for the single-device
        baseline and pre-membuf callers)."""
        policy = "registered" if opt_buffers else "per_packet"
        return self.packet_cost(offset, size, total, now, policy)[0]


@dataclass
class SimConfig:
    scheduler: str = "hguided"
    scheduler_kwargs: Dict = field(default_factory=dict)
    opt_init: bool = False
    opt_buffers: bool = False
    # buffer policy name ("per_packet" / "registered" / "pooled"); None
    # keeps the legacy opt_buffers mapping.  "pooled" adds the transfer
    # pipeline's DMA/compute overlap to the registered-buffer model.
    buffer_policy: Optional[str] = None
    # binary-mode constants (paper Fig. 6: ~constant offset per run)
    init_cost: float = 0.230               # s, unoptimized init+release
    init_cost_optimized: float = 0.099     # s, saves ~131 ms (paper §V-B)
    # co-execution-only synchronization cost (scheduler start/stop barriers,
    # host-thread management): not paid by a single-device run
    sync_cost: float = 0.105
    sync_cost_optimized: float = 0.085
    # serialized host cost per packet launch (Runtime+Scheduler are host
    # threads; every launch crosses them — the paper's "the more packages
    # ... the more management ... incurring in more overheads")
    host_cost_per_packet: float = 1.0e-3
    # scheduler hand-off model: "per_packet" serializes EVERY launch
    # through the host (one lock crossing per packet — the calibrated
    # paper behavior); "leased" charges the host crossing only when a
    # granted pull actually crossed the scheduler's global lock (lease
    # refills, steals) — local lease pops are free, reproducing the
    # threaded engine's lock-amortized dispatch and its measured
    # crossover (benchmarks/sched_overhead.py).  Terminal empty probes
    # are uncharged in BOTH modes (a device's exit probe costs the same
    # either way), keeping the comparison fair.
    dispatch: str = "per_packet"
    # cost of ONE scheduler lock crossing (contended hand-off / thread
    # wake); None keeps the legacy host_cost_per_packet scale
    sched_overhead_s: Optional[float] = None
    # lease growth-law overrides (None keeps SchedulerBase defaults) —
    # the autotuner sweeps these in-sim before confirming on hardware
    lease_overhead_frac: Optional[float] = None
    lease_k_max: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        # fail fast like the engine: a typo'd mode must not silently
        # fall back to the per-packet model and corrupt a comparison
        if self.dispatch not in ("per_packet", "leased"):
            raise ValueError(f"SimConfig.dispatch must be 'per_packet' or "
                             f"'leased', got {self.dispatch!r}")

    @property
    def policy(self) -> str:
        """Effective buffer policy name."""
        if self.buffer_policy is not None:
            return self.buffer_policy
        return "registered" if self.opt_buffers else "per_packet"

    @property
    def hand_off_cost(self) -> float:
        """Host cost of one scheduler lock crossing."""
        if self.sched_overhead_s is not None:
            return self.sched_overhead_s
        return self.host_cost_per_packet

    def tune_scheduler(self, sched) -> None:
        """Apply the leased-dispatch cost model to a fresh scheduler: the
        adaptive lease law balances lock-crossing cost against packet
        latency, so it must see the MODELED crossing cost (not the
        wall-clock class default) plus any swept growth-law constants."""
        if self.dispatch == "leased":
            sched.set_lease_params(
                lease_overhead_s=self.hand_off_cost,
                lease_overhead_frac=self.lease_overhead_frac,
                lease_k_max=self.lease_k_max)


def simulate(total_work: int, lws: int, devices: Sequence[SimDevice],
             cfg: SimConfig) -> RunResult:
    import random
    rng = random.Random(cfg.seed)
    policy = cfg.policy
    leased = cfg.dispatch == "leased"
    hand_off = cfg.hand_off_cost
    profiles = [DeviceProfile(d.name, d.throughput * d.profile_bias,
                              power_model=d.power_model)
                for d in devices]
    sched = make_scheduler(cfg.scheduler, total_work, lws, profiles,
                           **cfg.scheduler_kwargs)
    cfg.tune_scheduler(sched)
    n = len(devices)
    busy = [0.0] * n
    finish = [0.0] * n
    swait = [0.0] * n                      # modeled scheduler hand-off wait
    first = [True] * n                     # pipeline fill per device
    packets: List = []
    heap: List[Tuple[float, int]] = []     # (ready_time, device)
    for i in range(n):
        heapq.heappush(heap, (0.0, i))
    dead = [False] * n
    h2d_total = 0.0
    d2h_total = 0.0
    cbusy = [0.0] * n                      # executing seconds (energy busy)
    bytes_moved = [0.0] * n                # host<->device traffic (energy)

    host_free = 0.0
    while heap:
        t, i = heapq.heappop(heap)
        d = devices[i]
        if dead[i]:
            continue
        c0 = sched.stats.lock_crossings
        pkt = sched.acquire(i) if leased else sched.next_packet(i)
        crossings = sched.stats.lock_crossings - c0
        if pkt is None:
            finish[i] = max(finish[i], t)
            continue
        # launches serialize through the host Runtime/Scheduler threads —
        # under "leased" dispatch only when the scheduler crossed its
        # global lock (refills/steals); local lease pops are free
        if crossings:
            start = max(t, host_free)
            host_free = start + crossings * hand_off
        else:
            start = t
        swait[i] += start - t
        was_first = first[i]
        cost = d.packet_cost(pkt.offset, pkt.size, total_work,
                             start, policy, first[i])
        first[i] = False
        raw_dt = cost.t + (start - t)
        dt = raw_dt
        if d.jitter > 0:
            dt *= math.exp(rng.gauss(0.0, d.jitter))
        end = t + dt
        if d.fail_at is not None and end > d.fail_at >= t:
            # device dies mid-packet: requeue, mark dead (releases the
            # device's lease and any pre-assigned unclaimed chunk)
            dead[i] = True
            finish[i] = d.fail_at
            sched.requeue(pkt)
            sched.release(i)
            sched.mark_dead(i)
            # wake an idle survivor (if any already drained the queue)
            for j in range(n):
                if not dead[j]:
                    heapq.heappush(heap, (max(d.fail_at, finish[j]), j))
            continue
        busy[i] += dt
        finish[i] = end
        packets.append(pkt)
        h2d_total += cost.h2d
        d2h_total += cost.d2h
        # energy: the jitter multiplier stretches the whole event, so the
        # packet's busy slice stretches with it (same busy:stall ratio)
        cbusy[i] += cost.busy_s * (dt / raw_dt if raw_dt > 0 else 1.0)
        bytes_moved[i] += d.packet_bytes(pkt.size, policy, was_first)
        sched.note_packet_latency(i, dt)   # drives the adaptive lease size
        if hasattr(sched, "observe"):
            sched.observe(i, pkt.size / max(dt, 1e-12))
        sched.release(i)
        heapq.heappush(heap, (end, i))

    if sched.remaining() > 0:
        raise RuntimeError("all devices failed with work remaining")
    roi = max(finish)
    if n > 1:  # co-execution pays the host synchronization cost
        roi += cfg.sync_cost_optimized if cfg.opt_init else cfg.sync_cost
    init = cfg.init_cost_optimized if cfg.opt_init else cfg.init_cost
    # energy: every device is powered for the whole ROI window (idle watts
    # fill the gap between its busy seconds and the window); a dead device
    # is powered only until its failure time.  Lock-crossing energy uses
    # the scheduler's per-device crossing counters — the same counters the
    # dispatch model charges wall time for.
    crossings = sched.lock_crossings_by_device()
    meter = EnergyMeter()
    for i, d in enumerate(devices):
        window = min(roi, d.fail_at) if dead[i] else roi
        meter.add(d.name, d.power_model, busy_s=min(cbusy[i], window),
                  window_s=window, crossings=crossings[i],
                  bytes_moved=bytes_moved[i])
    # h2d/d2h are the UNHIDDEN transfer components already charged inside
    # the event timeline (the simulator's offload window == its ROI
    # window); under "pooled" the pipeline shrinks them toward the fill
    return RunResult(total_time=roi, device_busy=busy, device_finish=finish,
                     packets=packets, binary_time=roi + init,
                     aborted_devices=sum(dead),
                     phases=PhaseBreakdown(init_s=init, offload_s=roi,
                                           roi_s=roi, h2d_s=h2d_total,
                                           d2h_s=d2h_total),
                     sched_wait_s=swait, energy=meter.report())


def single_device_time(total_work: int, lws: int, device: SimDevice,
                       cfg: Optional[SimConfig] = None) -> float:
    """Whole problem on one device, one packet (the paper's baseline)."""
    cfg = cfg or SimConfig()
    return device.packet_time(0, total_work, total_work, 0.0,
                              cfg.opt_buffers)


# ---------------------------------------------------------------------------
# DAG-aware simulation: the EngineSession ready-set dispatcher's twin.
# ---------------------------------------------------------------------------

@dataclass
class SimNode:
    """One node of a simulated run graph: a co-executable range plus the
    names of its predecessor nodes."""
    name: str
    total_work: int
    lws: int = 1
    deps: Tuple[str, ...] = ()


@dataclass
class DagSimResult:
    makespan: float
    node_finish: Dict[str, float]
    node_start: Dict[str, float]
    device_busy: List[float]
    depth: Dict[str, int]                  # node -> DAG level


def dag_depths(nodes: Sequence[SimNode]) -> Dict[str, int]:
    """Longest-path depth of every node (0 for roots); raises on cycles
    or unknown dep names."""
    by_name = {n.name: n for n in nodes}
    if len(by_name) != len(nodes):
        raise ValueError("duplicate node names")
    depth: Dict[str, int] = {}

    def visit(name: str, stack: Tuple[str, ...]) -> int:
        if name in depth:
            return depth[name]
        if name in stack:
            raise ValueError(f"dependency cycle through {name!r}")
        node = by_name.get(name)
        if node is None:
            raise ValueError(f"unknown dep {name!r}")
        d = 0 if not node.deps else 1 + max(
            visit(p, stack + (name,)) for p in node.deps)
        depth[name] = d
        return d

    for n in nodes:
        visit(n.name, ())
    return depth


def simulate_dag(nodes: Sequence[SimNode], devices: Sequence[SimDevice],
                 cfg: SimConfig, *,
                 dispatch_mode: str = "deps") -> DagSimResult:
    """Discrete-event execution of a run graph over a shared fleet.

    The threaded twin is ``EngineSession(max_inflight=n)`` with
    ``submit(..., deps=...)``: every *active* node owns its own scheduler
    instance (exactly like one ``_RunContext`` per submit) and a free
    device pulls from the earliest-submitted active node that still has
    work, so concurrently-ready nodes co-execute over the same devices.

    ``dispatch_mode`` selects the readiness rule under comparison:

    * ``"deps"``  — ready-set dispatch: a node activates the instant its
      actual predecessors finish (the session's DAG dispatcher);
    * ``"levels"`` — level-barrier dispatch: a node activates only once
      EVERY node of lower depth has finished (the classic breadth-first
      baseline the benchmark beats — a barrier drains the fleet to idle
      at each level boundary and the largest node gates its whole level).

    Healthy-fleet model: per-packet costs, irregularity, jitter and the
    buffer-policy transfer model are simulate()'s; failure/straggler
    injection stays with the single-run ``simulate``.
    """
    import random
    if dispatch_mode not in ("deps", "levels"):
        raise ValueError(f"dispatch_mode must be 'deps' or 'levels', "
                         f"got {dispatch_mode!r}")
    rng = random.Random(cfg.seed)
    depth = dag_depths(nodes)
    policy = cfg.policy
    leased = cfg.dispatch == "leased"
    hand_off = cfg.hand_off_cost
    n_dev = len(devices)
    profiles = [DeviceProfile(d.name, d.throughput * d.profile_bias,
                              power_model=d.power_model)
                for d in devices]

    finished: Dict[str, float] = {}
    started: Dict[str, float] = {}
    scheds: Dict[str, object] = {}         # active node -> scheduler
    max_end: Dict[str, float] = {}         # active node -> latest packet end
    first = [True] * n_dev                 # pipeline fill per device

    def ready(node: SimNode, now: float) -> bool:
        if dispatch_mode == "deps":
            return all(p in finished for p in node.deps)
        return all(finished.get(m.name) is not None
                   for m in nodes if depth[m.name] < depth[node.name])

    def activate(now: float) -> bool:
        woke = False
        for node in nodes:                 # submit order == FIFO priority
            if node.name in scheds or node.name in finished:
                continue
            if ready(node, now):
                sched = make_scheduler(cfg.scheduler, node.total_work,
                                       node.lws, profiles,
                                       **cfg.scheduler_kwargs)
                cfg.tune_scheduler(sched)
                scheds[node.name] = sched
                max_end[node.name] = now
                started[node.name] = now
                woke = True
        return woke

    activate(0.0)
    busy = [0.0] * n_dev
    free = [0.0] * n_dev
    heap: List[Tuple[float, int]] = [(0.0, i) for i in range(n_dev)]
    heapq.heapify(heap)
    idle: List[int] = []
    host_free = 0.0

    while heap:
        t, i = heapq.heappop(heap)
        d = devices[i]
        # pull from the earliest-submitted active node with work
        pkt = None
        src = None
        for node in nodes:
            sched = scheds.get(node.name)
            if sched is None or node.name in finished:
                continue
            c0 = sched.stats.lock_crossings
            pkt = sched.acquire(i) if leased else sched.next_packet(i)
            crossings = sched.stats.lock_crossings - c0
            if pkt is not None:
                src = node
                break
        if pkt is None:
            idle.append(i)                 # re-woken on node activation
            free[i] = t
            continue
        if crossings:
            start = max(t, host_free)
            host_free = start + crossings * hand_off
        else:
            start = t
        dt = d.packet_cost(pkt.offset, pkt.size, src.total_work, start,
                           policy, first[i])[0] + (start - t)
        first[i] = False
        if d.jitter > 0:
            dt *= math.exp(rng.gauss(0.0, d.jitter))
        end = t + dt
        busy[i] += dt
        free[i] = end
        sched = scheds[src.name]
        max_end[src.name] = max(max_end[src.name], end)
        sched.note_packet_latency(i, dt)
        if hasattr(sched, "observe"):
            sched.observe(i, pkt.size / max(dt, 1e-12))
        sched.release(i)
        if sched.remaining() == 0 and src.name not in finished:
            # every packet of this node has been carved AND resolved (each
            # acquire resolves its end time immediately), so the node's
            # finish is the latest packet end — not this packet's
            fin = max_end[src.name]
            finished[src.name] = fin
            if activate(fin):
                # newly-ready nodes: wake every parked device
                for j in idle:
                    heapq.heappush(heap, (max(fin, free[j]), j))
                idle = []
        heapq.heappush(heap, (end, i))

    if len(finished) != len(nodes):
        raise RuntimeError(
            "graph stalled: "
            f"{sorted(set(n.name for n in nodes) - set(finished))} "
            "never became ready (cycle or lost wakeup)")
    return DagSimResult(makespan=max(finished.values(), default=0.0),
                        node_finish=dict(finished),
                        node_start=dict(started),
                        device_busy=busy, depth=depth)


# ---------------------------------------------------------------------------
# Multi-tenant simulation: the FleetArbiter's discrete-event twin.
# ---------------------------------------------------------------------------

@dataclass
class SimTenant:
    """One tenant of a simulated shared fleet: a work range plus the
    arbitration policy knobs of ``repro.tenancy.TenantConfig``.
    ``arrival`` delays activation (an exclusive tenant arriving mid-stream
    is the takeover-latency experiment)."""
    name: str
    total_work: int
    lws: int = 1
    weight: float = 1.0
    priority: int = 0
    exclusive: bool = False
    arrival: float = 0.0
    scheduler: Optional[str] = None        # per-tenant override of cfg's


@dataclass
class TenantSimResult:
    makespan: float
    tenant_finish: Dict[str, float]
    tenant_wg: Dict[str, int]              # executed work per tenant
    shares: Dict[str, float]               # tenant_wg normalized
    windows: List[Tuple[str, int, float, float, int]]
    #   (tenant, device, start, end, wg) — the isolation audit record
    preemptions: int
    takeover_latency: Dict[str, float]     # exclusive: first grant - arrival
    device_busy: List[float]


def simulate_multitenant(tenants: Sequence[SimTenant],
                         devices: Sequence[SimDevice],
                         cfg: SimConfig) -> TenantSimResult:
    """Discrete-event execution of N tenants sharing one device fleet.

    The threaded twin is ``FleetArbiter`` + N tenant ``EngineSession``s:
    one scheduler instance per tenant (exactly one ``_RunContext`` each),
    and every device event runs the arbiter's election — exclusive fence
    head first, then the highest priority class with work, then lowest
    weighted virtual time (``vt += wg / weight`` per packet).  Grants
    flip only at packet boundaries (a device event IS one), and an
    exclusive tenant's first packet gates on every co-tenant's in-flight
    packet end — zero overlap by construction, recorded in ``windows``
    so tests can verify rather than assume it.  Devices keep
    ``simulate()``'s cost model (irregularity, jitter, ``fail_at``
    requeue + mark_dead fault tolerance).
    """
    import random
    rng = random.Random(cfg.seed)
    policy = cfg.policy
    leased = cfg.dispatch == "leased"
    hand_off = cfg.hand_off_cost
    n = len(devices)
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError("duplicate tenant names")
    profiles = [DeviceProfile(d.name, d.throughput * d.profile_bias,
                              power_model=d.power_model)
                for d in devices]
    scheds: Dict[str, object] = {}
    for ten in tenants:
        s = make_scheduler(ten.scheduler or cfg.scheduler, ten.total_work,
                           ten.lws, profiles, **cfg.scheduler_kwargs)
        cfg.tune_scheduler(s)
        scheds[ten.name] = s
    vt = {t.name: 0.0 for t in tenants}
    usage = {t.name: 0 for t in tenants}
    windows: List[Tuple[str, int, float, float, int]] = []
    takeover: Dict[str, float] = {}
    preempt = 0
    grant: List[Optional[str]] = [None] * n
    cur_tenant: List[Optional[str]] = [None] * n   # packet in flight
    cur_end = [0.0] * n
    busy = [0.0] * n
    dead = [False] * n
    first = [True] * n
    heap: List[Tuple[float, int]] = [(0.0, i) for i in range(n)]
    heapq.heapify(heap)
    idle: List[int] = []
    host_free = 0.0
    arrivals = sorted({t.arrival for t in tenants})

    def has_work(name: str) -> bool:
        return scheds[name].remaining() > 0

    def elect_order(now: float) -> List[SimTenant]:
        """Candidates in grant order — the arbiter's _elect_locked rule.
        An active exclusive tenant starves everyone else (the fence)."""
        ex = [t for t in tenants
              if t.exclusive and t.arrival <= now and has_work(t.name)]
        if ex:
            return [min(ex, key=lambda t: (t.arrival, t.name))]
        cands = [t for t in tenants if t.arrival <= now and has_work(t.name)]
        return sorted(cands, key=lambda t: (-t.priority, vt[t.name], t.name))

    def wake_idle(at: float) -> None:
        nonlocal idle
        for j in idle:
            if not dead[j]:
                heapq.heappush(heap, (at, j))
        idle = []

    while heap:
        t0, i = heapq.heappop(heap)
        if dead[i]:
            continue
        d = devices[i]
        cur_tenant[i] = None               # this device's packet has ended
        pkt = None
        src: Optional[SimTenant] = None
        crossings = 0
        for cand in elect_order(t0):
            sched = scheds[cand.name]
            c0 = sched.stats.lock_crossings
            p = sched.acquire(i) if leased else sched.next_packet(i)
            crossings = sched.stats.lock_crossings - c0
            if p is not None:
                pkt, src = p, cand
                break
        if pkt is None:
            nxt = [a for a in arrivals if a > t0]
            if nxt:                        # sleep until the next activation
                heapq.heappush(heap, (nxt[0], i))
            else:
                idle.append(i)             # re-woken on packet completion
            continue
        start = t0
        if src.exclusive:
            # the fence: no exclusive packet may start while a co-tenant
            # packet is in flight anywhere (the arbiter's _begin_run wait)
            start = max([start] + [cur_end[j] for j in range(n)
                                   if cur_tenant[j] is not None
                                   and cur_tenant[j] != src.name])
        if crossings:
            s2 = max(start, host_free)
            host_free = s2 + crossings * hand_off
            start = s2
        if src.exclusive and src.name not in takeover:
            takeover[src.name] = start - src.arrival
        if grant[i] is not None and grant[i] != src.name \
                and has_work(grant[i]):
            preempt += 1                   # took the device from live work
        grant[i] = src.name
        cost = d.packet_cost(pkt.offset, pkt.size, src.total_work, start,
                             policy, first[i])
        first[i] = False
        dt = cost.t + (start - t0)
        if d.jitter > 0:
            dt *= math.exp(rng.gauss(0.0, d.jitter))
        end = t0 + dt
        if d.fail_at is not None and end > d.fail_at >= t0:
            dead[i] = True
            sched.requeue(pkt)
            sched.release(i)
            sched.mark_dead(i)
            wake_idle(d.fail_at)
            for j in range(n):             # survivors absorb the requeue
                if not dead[j] and j != i:
                    heapq.heappush(heap, (max(d.fail_at, cur_end[j]), j))
            continue
        vt[src.name] += pkt.size / src.weight
        usage[src.name] += pkt.size
        busy[i] += dt
        cur_tenant[i] = src.name
        cur_end[i] = end
        windows.append((src.name, i, start, end, pkt.size))
        sched.note_packet_latency(i, dt)
        if hasattr(sched, "observe"):
            sched.observe(i, pkt.size / max(dt, 1e-12))
        sched.release(i)
        heapq.heappush(heap, (end, i))
        wake_idle(end)                     # completions re-open elections

    for ten in tenants:
        if scheds[ten.name].remaining() > 0:
            raise RuntimeError(
                f"tenant {ten.name!r}: all devices failed with work left")
    tenant_end = {t.name: 0.0 for t in tenants}
    for name, _dev, _s, e, _wg in windows:
        tenant_end[name] = max(tenant_end[name], e)
    total = sum(usage.values())
    shares = {k: (v / total if total else 0.0) for k, v in usage.items()}
    return TenantSimResult(
        makespan=max(tenant_end.values(), default=0.0),
        tenant_finish=tenant_end, tenant_wg=dict(usage), shares=shares,
        windows=windows, preemptions=preempt, takeover_latency=takeover,
        device_busy=busy)


# ---------------------------------------------------------------------------
# Open-loop serving: the CoexecServer's discrete-event twin.
# ---------------------------------------------------------------------------

@dataclass
class ServeSimState:
    """Carry-over state for incremental serving simulation (the fleet
    router's co-simulation hook).

    A fleet-level driver places requests epoch by epoch and needs each
    replica's ``simulate_serving`` to *resume* — device clocks, online
    power estimates, the pipeline fill and the jitter stream must carry
    across calls, or chunked execution would diverge from a one-shot run.
    Obtain one from ``ServeSimResult.state`` and pass it back via
    ``simulate_serving(..., resume=state)``.  ``residual_wg(now)`` is the
    measured outstanding work the router's EWMA tracks.
    """
    free: List[float]                      # per-device clock (busy until)
    busy: List[float]                      # cumulative busy time
    swait: List[float]                     # cumulative modeled sched wait
    dead: List[bool]
    first_pkt: List[bool]                  # pipeline fill paid?
    powers: List[float]                    # online EWMA power estimates
    now: float = 0.0
    rounds: int = 0
    rng: Optional[object] = None           # jitter stream (random.Random)
    # cumulative energy accounting (empty lists == zero-initialized; kept
    # defaulted so pre-energy constructors keep working)
    cbusy: List[float] = field(default_factory=list)   # executing seconds
    crossings: List[int] = field(default_factory=list)
    bytes_moved: List[float] = field(default_factory=list)

    def residual_wg(self, now: float) -> float:
        """In-flight work (wg) still queued on surviving device clocks."""
        return sum(max(f - now, 0.0) * p
                   for f, p, d in zip(self.free, self.powers, self.dead)
                   if not d)

    def alive_power(self) -> float:
        return sum(p for p, d in zip(self.powers, self.dead) if not d)


@dataclass
class ServeSimResult:
    requests: List                         # input requests, accounting filled
    duration: float                        # last completion / shed time
    device_busy: List[float]
    rounds: int
    all_dead: bool = False                 # every device failed mid-stream
    # per-device modeled scheduler hand-off wait, summed across rounds
    sched_wait: List[float] = field(default_factory=list)
    # carry-over hook: pass back as resume= to continue this fleet's
    # timeline with more requests (fleet co-simulation)
    state: Optional[ServeSimState] = None
    # joule accounting over the (cumulative, if resumed) timeline; None
    # never happens from simulate_serving itself — kept Optional for
    # hand-built results in tests
    energy: Optional[EnergyReport] = None

    @property
    def energy_j(self) -> float:
        """Total joules (0.0 for joule-blind power models)."""
        return self.energy.total_j if self.energy is not None else 0.0


def simulate_serving(requests: Sequence, lws: int,
                     devices: Sequence[SimDevice], cfg: SimConfig, *,
                     policy: str = "shed",
                     batch_window_s: float = 0.0,
                     round_quantum_s: float = math.inf,
                     admission=None,
                     resume: Optional[ServeSimState] = None
                     ) -> ServeSimResult:
    """Open-loop serving against calibrated device models.

    ``requests`` are ``repro.serve.workload.Request``-shaped objects (duck
    typed: rid/arrival/deadline/size read; finish/shed/replica written), kept
    out of this module so core never imports the serve layer.  Semantics
    mirror CoexecServer: successive *dispatch rounds* of EDF-ordered
    admission, one scheduler instance per round (same SCHEDULERS registry,
    same observe/requeue API as ``simulate``), predictions and shedding from
    the cross-round EWMA powers.  Devices keep simulate()'s failure /
    straggler / jitter / transfer model, so the same serving policies can be
    stress-tested at 1000-replica scale in milliseconds.

    Router-policy hooks (the fleet subsystem's attachment points):

    * ``admission`` — an injected policy object with the
      ``EdfAdmission.admit`` contract (serve/admission.py).  When given it
      replaces the inline EDF + quantum + shed procedure, so the threaded
      server, the fleet router and this simulator run the *same* decision
      code.  With the matching config the hook path is bit-identical to
      the inline one (locked by tests/test_admission.py).
    * ``resume`` — a :class:`ServeSimState` from a previous call: device
      clocks, EWMA powers, pipeline fill and jitter stream continue, so a
      fleet driver can feed a replica its routed requests epoch by epoch.
      The returned ``busy``/``sched_wait``/``rounds`` are then cumulative
      over the resumed timeline.
    """
    import random
    assert policy in ("shed", "none")
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
    n = len(devices)
    policy_name = cfg.policy
    leased = cfg.dispatch == "leased"
    hand_off = cfg.hand_off_cost
    if resume is not None:
        if len(resume.free) != n:
            raise ValueError(f"resume state has {len(resume.free)} devices, "
                             f"got {n}")
        st = resume
        rng = st.rng if st.rng is not None else random.Random(cfg.seed)
    else:
        st = ServeSimState(
            free=[0.0] * n, busy=[0.0] * n, swait=[0.0] * n,
            dead=[False] * n, first_pkt=[True] * n,
            # cross-round power estimates: start from the (possibly
            # biased) offline profile; rounds with an observing scheduler
            # refine them online
            powers=[d.throughput * d.profile_bias for d in devices])
        rng = random.Random(cfg.seed)
    st.rng = rng
    # zero-init the energy accumulators (resume states built before the
    # energy fields existed arrive with empty lists)
    if len(st.cbusy) != n:
        st.cbusy = [0.0] * n
    if len(st.crossings) != n:
        st.crossings = [0] * n
    if len(st.bytes_moved) != n:
        st.bytes_moved = [0.0] * n
    swait = st.swait
    powers = st.powers
    free = st.free
    busy = st.busy
    dead = st.dead
    # pipeline fill: with pooled buffers the arena persists across rounds,
    # so a device pays the stage-in fill once per serve, not once per round
    first_pkt = st.first_pkt
    now = st.now
    i_next = 0
    pending: List = []
    rounds = st.rounds
    all_dead = False

    def alive() -> List[int]:
        return [i for i in range(n) if not dead[i]]

    while pending or i_next < len(reqs):
        if not alive():
            all_dead = True
            for r in pending + reqs[i_next:]:
                r.shed = True
            break
        # release arrivals; when idle, jump the clock to the next arrival
        # plus the batching window (standard serving micro-batching: a few
        # ms of waiting gives the round enough work for a proportional
        # split to be meaningful and amortizes per-packet overheads)
        if not pending and i_next < len(reqs):
            now = max(now, reqs[i_next].arrival + batch_window_s)
        while i_next < len(reqs) and reqs[i_next].arrival <= now:
            pending.append(reqs[i_next])
            i_next += 1
        # admission: EDF order, shed predicted misses (CoexecServer._admit).
        # Predictions start from the earliest time any replica frees up, so
        # an in-flight backlog pushes predicted finishes (and sheds) out.
        pending.sort(key=lambda r: (r.deadline, r.rid))
        total_p = sum(powers[i] for i in alive())
        # residual in-flight work (wg) already queued on device clocks:
        # without it the predictor only sees THIS round's queue and admits
        # doomed requests under backlog
        resid = sum(max(free[i] - now, 0.0) * powers[i] for i in alive())
        if admission is not None:
            # injected policy object (serve/admission.py): the exact
            # decision procedure the threaded server and the fleet router
            # run — bit-identical to the inline path below with the
            # matching config (tests/test_admission.py locks it)
            admitted, pending = admission.admit(
                pending, now, total_power=total_p, residual_wg=resid,
                calibrated=True)
        else:
            # round quantum (iteration-level scheduling): admit only ~one
            # quantum of EDF-first work per round, so under backlog the
            # server re-sorts, re-predicts and re-sheds frequently instead
            # of committing the whole queue to one long round
            cap_wg = total_p * round_quantum_s
            admitted = []
            leftover: List = []
            cum = 0.0
            for r in pending:
                if admitted and cum + r.size > cap_wg:
                    leftover.append(r)
                    continue
                cum += r.size
                if (policy == "shed"
                        and now + (resid + cum) / total_p > r.deadline):
                    r.shed = True
                    cum -= r.size
                else:
                    admitted.append(r)
            pending = leftover
        if not admitted:
            continue
        rounds += 1
        # one scheduler instance over the admitted round
        amap = alive()
        G = sum(r.size for r in admitted)
        wg_owner: List[int] = []           # work-group offset -> request idx
        for j, r in enumerate(admitted):
            wg_owner.extend([j] * r.size)
        profiles = [DeviceProfile(devices[g].name, powers[g],
                                  power_model=devices[g].power_model)
                    for g in amap]
        skw = dict(cfg.scheduler_kwargs)
        order = rotate_static_order(cfg.scheduler, len(amap), rounds)
        if order is not None:
            skw.setdefault("order", order)
        sched = make_scheduler(cfg.scheduler, G, lws, profiles, **skw)
        cfg.tune_scheduler(sched)
        if hasattr(sched, "update_slack"):
            sched.update_slack(min(r.deadline for r in admitted) - now)
        done_wg = [0] * len(admitted)
        fin_max = [0.0] * len(admitted)
        heap: List[Tuple[float, int]] = []
        for ai, g in enumerate(amap):
            heapq.heappush(heap, (max(now, free[g]), ai))
        # host-thread serialization is round-local: rounds overlap in wall
        # time but are processed sequentially, so carrying the chain across
        # rounds would let a straggler's late launch block earlier ones
        host_free = now
        while heap:
            t, ai = heapq.heappop(heap)
            g = amap[ai]
            d = devices[g]
            if dead[g]:
                continue
            if t < free[g]:
                # stale entry (failure wakeups push duplicates for devices
                # that already have a live event): a device can't start a
                # packet before its clock frees up
                heapq.heappush(heap, (free[g], ai))
                continue
            c0 = sched.stats.lock_crossings
            pkt = sched.acquire(ai) if leased else sched.next_packet(ai)
            crossings = sched.stats.lock_crossings - c0
            if pkt is None:
                continue
            # host serialization only on actual lock crossings (leased
            # dispatch amortizes them; local lease pops are free)
            if crossings:
                start = max(t, host_free)
                host_free = start + crossings * hand_off
            else:
                start = t
            swait[g] += start - t
            was_first = first_pkt[g]
            cost = d.packet_cost(pkt.offset, pkt.size, G, start, policy_name,
                                 first_pkt[g])
            first_pkt[g] = False
            raw_dt = cost.t + (start - t)
            dt = raw_dt
            if d.jitter > 0:
                dt *= math.exp(rng.gauss(0.0, d.jitter))
            end = t + dt
            # unlike the fixed-batch simulate(), a serving device can be
            # idle when its failure time passes — it is dead for any packet
            # starting at or after fail_at, not just one spanning it
            if d.fail_at is not None and (t >= d.fail_at
                                          or end > d.fail_at >= t):
                dead[g] = True
                free[g] = min(t, d.fail_at)
                sched.requeue(pkt)
                sched.release(ai)
                # reclaim the dead device's leased-but-unexecuted packets
                # AND any pre-assigned unclaimed chunk (Static*) so the
                # survivors can absorb them this round — same contract as
                # simulate() and the threaded engine's device loops
                sched.mark_dead(ai)
                for aj, gj in enumerate(amap):
                    if not dead[gj]:
                        heapq.heappush(heap, (max(d.fail_at, free[gj]), aj))
                continue
            busy[g] += dt
            free[g] = end
            st.cbusy[g] += cost.busy_s * (dt / raw_dt if raw_dt > 0 else 1.0)
            st.bytes_moved[g] += d.packet_bytes(pkt.size, policy_name,
                                                was_first)
            sched.note_packet_latency(ai, dt)
            if hasattr(sched, "observe"):
                sched.observe(ai, pkt.size / max(dt, 1e-12))
            sched.release(ai)
            for o in range(pkt.offset, pkt.offset + pkt.size):
                j = wg_owner[o]
                done_wg[j] += 1
                fin_max[j] = max(fin_max[j], end)
                if done_wg[j] == admitted[j].size:
                    admitted[j].finish = fin_max[j]
                    admitted[j].replica = d.name
            heapq.heappush(heap, (end, ai))
        if sched.remaining() > 0:
            # every device died mid-round (amap was the full alive set):
            # unfinished requests are lost, and the fleet is gone even if
            # the loop exits before re-checking alive()
            all_dead = True
            for j, r in enumerate(admitted):
                if done_wg[j] < r.size:
                    r.shed = True
        # energy: fold the round scheduler's per-device lock-crossing
        # counters into the cumulative state (it indexes the round's
        # alive map)
        rc = sched.lock_crossings_by_device()
        for ai, g in enumerate(amap):
            st.crossings[g] += rc[ai]
        # carry the schedulers' online estimates into the next round's
        # profile (schedulers without observe leave them untouched — Static
        # keeps trusting its offline profile, and keeps paying for it)
        for ai, g in enumerate(amap):
            if not dead[g] and hasattr(sched, "observe"):
                powers[g] = sched.devices[ai].power
        # next round: earliest point a surviving device frees up, but never
        # before the next arrival if the fleet drained the backlog
        if i_next < len(reqs) or pending:
            nxt = min(free[g] for g in alive()) if alive() else now
            now = max(now, nxt)

    st.now = now
    st.rounds = rounds
    fins = [r.finish for r in reqs if r.finish is not None]
    duration = max(fins) if fins else now
    # energy: every device is powered for the whole serving window (idle
    # watts bridge the arrival gaps); a dead device only until it failed.
    # Cumulative over a resumed timeline, like busy/sched_wait.
    end_t = max([duration, now]
                + [f for f, dd in zip(free, dead) if not dd])
    meter = EnergyMeter()
    for g, d in enumerate(devices):
        window = min(end_t, d.fail_at) if (dead[g] and d.fail_at is not None) \
            else end_t
        meter.add(d.name, d.power_model,
                  busy_s=min(st.cbusy[g], window), window_s=window,
                  crossings=st.crossings[g], bytes_moved=st.bytes_moved[g])
    return ServeSimResult(requests=reqs, duration=duration,
                          device_busy=list(busy), rounds=rounds,
                          all_dead=all_dead, sched_wait=list(swait),
                          state=st, energy=meter.report())
