"""Discrete-event co-execution simulator.

The threaded Engine (core/runtime.py) runs the real code paths, but this
container has one physical CPU — relative device speeds can't be reproduced
in wall-clock.  The simulator executes the *same scheduler objects* against
calibrated device models instead, which (a) reproduces the paper's
CPU/iGPU/GPU testbed faithfully, and (b) scales the evaluation to 1000+
device groups (elastic joins, failures, stragglers) in milliseconds.

Device model (per packet of size s work-groups starting at offset o):

    t = launch_overhead + s / throughput(o, s) [+ transfer costs]

* ``throughput(o, s)`` supports *irregular* programs (Ray, Mandelbrot): the
  per-work-group cost varies across the range, which is exactly what makes
  Static mis-balance in the paper.
* ``launch_overhead`` models the per-packet management/synchronization cost
  (host thread, driver queueing).  More packets => more overhead: the
  Dynamic-with-512-chunks pathology.
* init/teardown constants model the binary-mode costs; the ``opt_init`` /
  ``opt_buffers`` flags change them (and the per-packet transfer term)
  according to the measured effects of the paper's optimizations.

Events are device-completion times in a heap; the scheduler is consulted
exactly as in the threaded runtime (same next_packet/observe/requeue API).
Failures: a device dies at ``fail_at`` seconds; its in-flight packet is
requeued (fault tolerance) — stragglers: throughput multiplier drops at a
given time.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.metrics import RunResult
from repro.core.scheduler import DeviceProfile, make_scheduler

# fraction of the input set that is full-size read-only buffers, re-copied
# per packet by the unoptimized buffer path
BULK_COPY_FRACTION = 0.45


@dataclass
class SimDevice:
    name: str
    throughput: float                      # work-groups / second (base)
    launch_overhead: float = 2e-4          # s per packet
    transfer_in: float = 0.0               # s per work-group of input
    transfer_out: float = 0.0              # s per work-group of output
    # irregularity: relative cost multiplier across the work range [0,1]
    irregularity: Optional[Callable[[float], float]] = None
    fail_at: Optional[float] = None        # hard failure time (s)
    straggle_at: Optional[float] = None    # throughput drops at this time
    straggle_factor: float = 1.0           # multiplier after straggle_at
    zero_copy: bool = False                # shares host memory (iGPU/CPU)
    # what the *scheduler profile* believes this device's power is, relative
    # to truth (offline profiling bias).  Static pays the full price of a
    # wrong profile; guided schedulers adapt via their shrinking tail.
    profile_bias: float = 1.0
    # per-packet multiplicative execution-time jitter (lognormal sigma)
    jitter: float = 0.0

    def packet_time(self, offset: int, size: int, total: int, now: float,
                    opt_buffers: bool) -> float:
        # irregular work density integrated over the packet's range
        if self.irregularity is not None and total > 0:
            steps = 8
            acc = 0.0
            for i in range(steps):
                x = (offset + size * (i + 0.5) / steps) / total
                acc += self.irregularity(x)
            density = acc / steps
        else:
            density = 1.0
        # piecewise straggling: work done before straggle_at runs at full
        # speed, the remainder at straggle_factor (a packet spanning the
        # slowdown pays for its tail — this is what makes pre-assigned
        # static chunks so expensive under stragglers)
        d0 = size * density / self.throughput
        if self.straggle_at is not None:
            if now >= self.straggle_at:
                d0 = d0 / self.straggle_factor
            elif now + d0 > self.straggle_at:
                done = self.straggle_at - now
                d0 = done + (d0 - done) / self.straggle_factor
        t = self.launch_overhead + d0
        xfer = (self.transfer_in + self.transfer_out) * size
        if opt_buffers:
            # buffer-flag optimization: the driver recognizes read-only /
            # shared buffers — zero-copy on shared-memory devices, only the
            # necessary per-range copy on discrete ones
            xfer = 0.0 if self.zero_copy else xfer
        else:
            # without the flags EVERY PACKET bulk-copies the full-size
            # read-only inputs (the paper's "unnecessary complete bulk
            # copies of memory regions") — cost scales with the TOTAL
            # problem size per packet, which is what penalizes co-execution
            # (many packets) far more than a single-device run (one packet)
            xfer += BULK_COPY_FRACTION * (self.transfer_in
                                          + self.transfer_out) * total
        return t + xfer


@dataclass
class SimConfig:
    scheduler: str = "hguided"
    scheduler_kwargs: Dict = field(default_factory=dict)
    opt_init: bool = False
    opt_buffers: bool = False
    # binary-mode constants (paper Fig. 6: ~constant offset per run)
    init_cost: float = 0.230               # s, unoptimized init+release
    init_cost_optimized: float = 0.099     # s, saves ~131 ms (paper §V-B)
    # co-execution-only synchronization cost (scheduler start/stop barriers,
    # host-thread management): not paid by a single-device run
    sync_cost: float = 0.105
    sync_cost_optimized: float = 0.085
    # serialized host cost per packet launch (Runtime+Scheduler are host
    # threads; every launch crosses them — the paper's "the more packages
    # ... the more management ... incurring in more overheads")
    host_cost_per_packet: float = 1.0e-3
    seed: int = 0


def simulate(total_work: int, lws: int, devices: Sequence[SimDevice],
             cfg: SimConfig) -> RunResult:
    import random
    rng = random.Random(cfg.seed)
    profiles = [DeviceProfile(d.name, d.throughput * d.profile_bias)
                for d in devices]
    sched = make_scheduler(cfg.scheduler, total_work, lws, profiles,
                           **cfg.scheduler_kwargs)
    n = len(devices)
    now = [0.0] * n                        # per-device clock
    busy = [0.0] * n
    finish = [0.0] * n
    packets: List = []
    heap: List[Tuple[float, int]] = []     # (ready_time, device)
    for i in range(n):
        heapq.heappush(heap, (0.0, i))
    dead = [False] * n
    pending_retry: List = []

    host_free = 0.0
    while heap:
        t, i = heapq.heappop(heap)
        d = devices[i]
        if dead[i]:
            continue
        pkt = sched.next_packet(i)
        if pkt is None:
            finish[i] = max(finish[i], t)
            continue
        # every launch serializes through the host Runtime/Scheduler threads
        start = max(t, host_free)
        host_free = start + cfg.host_cost_per_packet
        dt = d.packet_time(pkt.offset, pkt.size, total_work, start,
                           cfg.opt_buffers) + (start - t)
        if d.jitter > 0:
            dt *= math.exp(rng.gauss(0.0, d.jitter))
        end = t + dt
        if d.fail_at is not None and end > d.fail_at >= t:
            # device dies mid-packet: requeue, mark dead
            dead[i] = True
            finish[i] = d.fail_at
            sched.requeue(pkt)
            # wake an idle survivor (if any already drained the queue)
            for j in range(n):
                if not dead[j]:
                    heapq.heappush(heap, (max(d.fail_at, finish[j]), j))
            continue
        busy[i] += dt
        finish[i] = end
        packets.append(pkt)
        if hasattr(sched, "observe"):
            sched.observe(i, pkt.size / max(dt, 1e-12))
        heapq.heappush(heap, (end, i))

    if sched.remaining() > 0:
        raise RuntimeError("all devices failed with work remaining")
    roi = max(finish)
    if n > 1:  # co-execution pays the host synchronization cost
        roi += cfg.sync_cost_optimized if cfg.opt_init else cfg.sync_cost
    init = cfg.init_cost_optimized if cfg.opt_init else cfg.init_cost
    return RunResult(total_time=roi, device_busy=busy, device_finish=finish,
                     packets=packets, binary_time=roi + init,
                     aborted_devices=sum(dead))


def single_device_time(total_work: int, lws: int, device: SimDevice,
                       cfg: Optional[SimConfig] = None) -> float:
    """Whole problem on one device, one packet (the paper's baseline)."""
    cfg = cfg or SimConfig()
    return device.packet_time(0, total_work, total_work, 0.0,
                              cfg.opt_buffers)
