"""Evaluation metrics (paper §IV).

* balance      = T_FD / T_LD (first-finisher / last-finisher busy time); 1.0
                 means all devices finished together.
* S_max        = sum_i(T_i) / max_i(T_i) where T_i = single-device response
                 time of the whole problem on device i.
* speedup      = T_fastest_single / T_coexec  (baseline: fastest device,
                 i.e. the GPU in the paper).
* efficiency   = speedup / S_max.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.energy.meter import EnergyReport


@dataclass(frozen=True)
class PhaseBreakdown:
    """Per-phase wall-clock of one run (the paper's two timing modes made
    measurable instead of inferred):

    * ``init_s``      — setup: executable builds (or cache hits) overlapped
                        with scheduler preparation, buffer registration.
    * ``h2d_s``       — host-to-device staging: the initial stage-in wave
                        (scheduler pull + launch binding + input staging)
                        before the first packet computes.
    * ``roi_s``       — the ROI window: packet dispatch + compute, first
                        carve to queue drained (== ``RunResult.total_time``).
    * ``d2h_s``       — device-to-host staging: the commit tail after the
                        queue drains (result conversion + assembly still
                        in flight on the transfer pipeline).
    * ``offload_s``   — the offload window: ``h2d_s + roi_s + d2h_s`` (the
                        full data path to and from the devices).
    * ``teardown_s``  — releasing per-run state; for BINARY-mode submits
                        also the cache/buffer eviction.

    In the threaded engine the five windows are disjoint wall segments, so
    ``init_s + h2d_s + roi_s + d2h_s + teardown_s == binary`` exactly and
    ``offload_s == h2d_s + roi_s + d2h_s``.  The simulator keeps transfer
    costs inside its event timeline (``offload_s == roi_s``) and reports
    ``h2d_s`` / ``d2h_s`` as the *unhidden* transfer components charged to
    that timeline — under ``BufferPolicy.POOLED`` the double-buffered
    pipeline hides per-packet staging behind compute, shrinking them.

    ``binary = init_s + offload_s + teardown_s`` is the paper's binary-mode
    response time; ``roi_s`` alone is its ROI-mode response time.
    """
    init_s: float = 0.0
    offload_s: float = 0.0
    roi_s: float = 0.0
    teardown_s: float = 0.0
    h2d_s: float = 0.0
    d2h_s: float = 0.0

    @property
    def binary(self) -> float:
        return self.init_s + self.offload_s + self.teardown_s

    @property
    def staging(self) -> float:
        """The transfer (staging) time on the run's critical path."""
        return self.h2d_s + self.d2h_s

    @property
    def management(self) -> float:
        """Everything that is not the ROI window (the paper's 'management
        overheads')."""
        return self.binary - self.roi_s


@dataclass
class RunResult:
    """Timing record of one co-execution run."""
    total_time: float                   # response time (ROI unless noted)
    device_busy: List[float]            # per-device busy time
    device_finish: List[float]          # per-device finish timestamp
    packets: List                       # executed packets (scheduler.Packet)
    binary_time: Optional[float] = None  # incl. init/teardown ("binary" mode)
    aborted_devices: int = 0
    retries: int = 0                    # packets re-issued after a requeue
    phases: Optional[PhaseBreakdown] = None  # per-phase wall-clock
    # per-device time blocked on the scheduler hand-off (lock waits +
    # carves + steals); empty when the engine predates the lease API
    sched_wait_s: List[float] = field(default_factory=list)
    # joule accounting (repro.energy): per-device busy/idle/lock/transfer
    # energy integrated from the phase windows by the executor's
    # EnergyMeter.  None only when an executor predates the energy
    # subsystem; joule-blind (zero PowerModel) runs report total_j == 0.
    energy: Optional[EnergyReport] = None

    @property
    def energy_j(self) -> float:
        """Total joules of this run (0.0 for joule-blind models)."""
        return self.energy.total_j if self.energy is not None else 0.0

    def __post_init__(self):
        if not self.retries:
            self.retries = sum(1 for p in self.packets
                               if getattr(p, "retried", False))


def balance(result: RunResult) -> float:
    fin = [t for t in result.device_finish if t > 0]
    if len(fin) <= 1:
        return 1.0
    return min(fin) / max(fin)


def s_max_from_times(single_times: Sequence[float]) -> float:
    """Max achievable speedup vs the fastest device.  With device powers
    p_i = 1/T_i a perfect proportional split finishes in 1/sum(p_i), so
    S_max = sum(p_i)/p_fastest.  (The paper prints sum(T_i)/max(T_i), which
    equals this only in the homogeneous case; we use the physical formula —
    for the paper's testbed the two differ by <10% and do not change any
    ranking.)"""
    powers = [1.0 / t for t in single_times]
    return sum(powers) / max(powers)


def speedup(fastest_single: float, coexec_time: float) -> float:
    return fastest_single / coexec_time


def efficiency(fastest_single: float, coexec_time: float,
               single_times: Sequence[float]) -> float:
    return (speedup(fastest_single, coexec_time)
            / s_max_from_times(single_times))


def geomean(xs: Sequence[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return 0.0
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def inflection_point(problem_sizes: Sequence[float],
                     coexec_times: Sequence[float],
                     single_times: Sequence[float]) -> Optional[float]:
    """Smallest problem size where co-execution beats the fastest single
    device (paper Fig. 6's vertical lines), linearly interpolated."""
    for i in range(len(problem_sizes)):
        if coexec_times[i] < single_times[i]:
            if i == 0:
                return float(problem_sizes[0])
            # interpolate crossing between i-1 and i
            d_prev = coexec_times[i - 1] - single_times[i - 1]
            d_cur = coexec_times[i] - single_times[i]
            t = d_prev / (d_prev - d_cur) if d_prev != d_cur else 1.0
            return float(problem_sizes[i - 1]
                         + t * (problem_sizes[i] - problem_sizes[i - 1]))
    return None
