"""Device-group abstraction (the paper's Tier-3 ``Device``).

A DeviceGroup owns one executor (a jax.Device — on TPU deployments a mesh
sub-slice handle) and runs range-partitioned packets of a Program.  The
per-packet throughput is EWMA-tracked — that is the online computing-power
estimate fed back to HGuidedOpt.

``throttle`` (>1 slows the device down by sleeping the extra fraction of
each packet's measured compute time) provides *controlled* heterogeneity on
a host where all executors are identical CPU devices; the calibrated
co-execution figures additionally use the discrete-event simulator
(core/simulate.py) with the paper's device profiles.  ``fail_after``
injects a hard device failure after N packets (fault-tolerance tests).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

from repro.energy.model import ZERO_POWER, PowerModel


class DeviceFailure(RuntimeError):
    pass


@dataclass
class DeviceGroup:
    name: str
    device: Optional[Any] = None          # jax.Device; None = default
    throttle: float = 1.0                 # >1 => proportionally slower
    fail_after: Optional[int] = None      # fail on the Nth packet
    ewma: float = 0.5
    # energy model (busy/idle W, lock J, transfer J/byte); the all-zero
    # default keeps every joule-blind config bit-identical (energy == 0)
    power_model: PowerModel = ZERO_POWER

    # runtime state
    packets_done: int = 0
    busy_time: float = 0.0
    finish_time: float = 0.0
    throughput: Optional[float] = None    # work-groups / s (EWMA)
    dead: bool = False

    def put(self, x):
        if self.device is None:
            return x
        return jax.device_put(x, self.device)

    def run_packet(self, fn: Callable, offset: int, size: int):
        """Execute fn(offset, size); returns (result, wg_per_s)."""
        if (self.fail_after is not None
                and self.packets_done >= self.fail_after):
            self.dead = True
            raise DeviceFailure(f"{self.name} failed (injected)")
        t0 = time.perf_counter()
        out = fn(offset, size)
        out = jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        if self.throttle > 1.0:
            time.sleep(dt * (self.throttle - 1.0))
            dt *= self.throttle
        self.packets_done += 1
        self.busy_time += dt
        wg_per_s = size / max(dt, 1e-9)
        self.throughput = wg_per_s if self.throughput is None else (
            self.ewma * wg_per_s + (1 - self.ewma) * self.throughput)
        return out, wg_per_s
