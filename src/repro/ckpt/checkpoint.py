"""Fault-tolerant sharded checkpointing (no external deps).

Layout:  <dir>/step_<n>/
            manifest.json        tree structure, shapes, dtypes, host count
            host<k>.npz          this host's param/optimizer shards
            COMMIT               written last — a checkpoint without COMMIT
                                 is incomplete and ignored on restore

Writes go to ``step_<n>.tmp`` and are atomically renamed, so a host failure
mid-save never corrupts the latest good checkpoint.  ``AsyncCheckpointer``
snapshots to host memory synchronously (jax.device_get) and persists on a
background thread so the train loop only blocks for the copy, not the I/O.
On a multi-controller deployment each host saves its addressable shards;
in this single-process container host_count == 1.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(state, directory: str, step: int, *, host_id: int = 0,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(tmp, f"host{host_id}.npz"),
             **{k: v for k, v in arrays.items()})
    manifest = {
        "step": step,
        "hosts": 1,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            p = os.path.join(directory, name)
            if os.path.exists(os.path.join(p, "COMMIT")):
                out.append(int(name[5:]))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore(template, directory: str, step: Optional[int] = None,
            *, host_id: int = 0):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). Returns (state, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"host{host_id}.npz"))
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_t:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        want = manifest["leaves"][key]
        assert list(arr.shape) == want["shape"], key
        leaves.append(arr)
    state = jax.tree_util.tree_unflatten(treedef.treedef if hasattr(treedef, "treedef")
                                         else treedef, leaves)
    return state, step


class AsyncCheckpointer:
    """Snapshot synchronously, persist asynchronously; at most one pending."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error

    def save(self, state, step: int):
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def run():
            try:
                save(snapshot, self.directory, step, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()
