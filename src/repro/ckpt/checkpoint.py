"""Fault-tolerant sharded checkpointing + the co-execution run journal.

Two persistence layers live here:

1. **Training checkpoints** (``save``/``restore``/``AsyncCheckpointer``):
   Layout:  <dir>/step_<n>/
               manifest.json     tree structure, shapes, dtypes, host count
               host<k>.npz       this host's param/optimizer shards
               COMMIT            written last — a checkpoint without COMMIT
                                 is incomplete and ignored on restore
   Writes go to ``step_<n>.tmp`` and are atomically renamed, so a host
   failure mid-save never corrupts the latest good checkpoint.
   ``AsyncCheckpointer`` snapshots to host memory synchronously
   (jax.device_get) and persists on a background thread so the train loop
   only blocks for the copy, not the I/O.  On a multi-controller
   deployment each host saves its addressable shards; in this
   single-process container host_count == 1.

2. **The run journal** (:class:`RunJournal` / :func:`resume_run`): the
   persistent run state behind DAG checkpoint/resume.  Every packet a run
   commits appends one length-framed record — node key, absolute dim-0
   span, and the committed output rows — exactly when the scheduler's
   lease/exact-cover bookkeeping releases the packet, so the journal's
   spans tile each node's region without overlap.  A killed session
   resumes from the journal: committed spans are replayed into the output
   buffer (zero re-execution) and only the uncovered **gaps** are
   re-submitted as lws-aligned sub-region runs.  A torn tail record (the
   process died mid-append) is detected by the framing and dropped, so a
   crash can lose at most the packet being written — never corrupt the
   committed prefix.
"""
from __future__ import annotations

import json
import os
import shutil
import struct
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(state, directory: str, step: int, *, host_id: int = 0,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(tmp, f"host{host_id}.npz"),
             **{k: v for k, v in arrays.items()})
    manifest = {
        "step": step,
        "hosts": 1,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            p = os.path.join(directory, name)
            if os.path.exists(os.path.join(p, "COMMIT")):
                out.append(int(name[5:]))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore(template, directory: str, step: Optional[int] = None,
            *, host_id: int = 0):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). Returns (state, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, f"host{host_id}.npz"))
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_t:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        want = manifest["leaves"][key]
        assert list(arr.shape) == want["shape"], key
        leaves.append(arr)
    state = jax.tree_util.tree_unflatten(
        treedef.treedef if hasattr(treedef, "treedef") else treedef,
        leaves)
    return state, step


class AsyncCheckpointer:
    """Snapshot synchronously, persist asynchronously; at most one pending."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error

    def save(self, state, step: int):
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def run():
            try:
                save(snapshot, self.directory, step, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()


# ---------------------------------------------------------------------------
# Run journal: persistent packet-commit state for resumable (DAG) runs.
# ---------------------------------------------------------------------------

_JOURNAL_MAGIC = b"RPJ1"


@dataclass(frozen=True)
class PacketRecord:
    """One committed packet: node key, absolute dim-0 span (work-groups,
    relative to the node program's region start) and its output rows."""
    key: str
    offset: int
    size: int
    data: np.ndarray


class RunJournal:
    """Append-only, crash-safe packet-commit journal.

    Framing per record: ``<u32 header_len><header JSON><payload bytes>``
    after a 4-byte file magic.  The header carries the payload geometry
    (shape + dtype), so a reader never trusts payload length to anything
    but the header it just validated; an incomplete tail record (torn
    write) fails the frame check and is dropped.

    Thread-safe: run contexts append from many device/committer threads.
    Appends are flushed per record — after a kill, everything written is
    recoverable up to the packet being appended at the instant of death.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._lock = threading.Lock()
        self._fh = None
        self.appended = 0

    def _open_locked(self):
        if self._fh is None:
            fresh = not os.path.exists(self.path) \
                or os.path.getsize(self.path) == 0
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(self.path, "ab")
            if fresh:
                self._fh.write(_JOURNAL_MAGIC)
        return self._fh

    def append_packet(self, key: str, offset: int, size: int,
                      payload: np.ndarray) -> None:
        """Record one committed packet (called by the engine under the
        packet's commit, before its scheduler ``release``)."""
        arr = np.ascontiguousarray(payload)
        header = json.dumps({
            "key": key, "off": int(offset), "size": int(size),
            "shape": list(arr.shape), "dtype": str(arr.dtype),
        }).encode()
        with self._lock:
            fh = self._open_locked()
            fh.write(struct.pack("<I", len(header)))
            fh.write(header)
            fh.write(arr.tobytes())
            fh.flush()
            self.appended += 1

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "RunJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reading ------------------------------------------------------------
    @classmethod
    def read(cls, path: str) -> Dict[str, List[PacketRecord]]:
        """Load every complete record, grouped by node key.  A missing
        file reads as empty (nothing was ever committed); a torn tail
        record is silently dropped (it never committed)."""
        out: Dict[str, List[PacketRecord]] = {}
        if not os.path.exists(path):
            return out
        with open(path, "rb") as fh:
            blob = fh.read()
        if blob[:4] != _JOURNAL_MAGIC:
            raise ValueError(f"{path}: not a run journal "
                             f"(magic {blob[:4]!r})")
        pos = 4
        n = len(blob)
        while pos + 4 <= n:
            (hlen,) = struct.unpack_from("<I", blob, pos)
            if pos + 4 + hlen > n:
                break                          # torn header
            try:
                hdr = json.loads(blob[pos + 4:pos + 4 + hlen])
            except ValueError:
                break                          # torn / corrupt header
            dtype = np.dtype(hdr["dtype"])
            nbytes = int(np.prod(hdr["shape"])) * dtype.itemsize
            start = pos + 4 + hlen
            if start + nbytes > n:
                break                          # torn payload
            data = np.frombuffer(blob[start:start + nbytes],
                                 dtype=dtype).reshape(hdr["shape"])
            out.setdefault(hdr["key"], []).append(
                PacketRecord(hdr["key"], hdr["off"], hdr["size"], data))
            pos = start + nbytes
        return out

    @classmethod
    def truncate_packets(cls, path: str, keep: int,
                         out_path: Optional[str] = None) -> str:
        """Copy the journal keeping only the first ``keep`` records — the
        test/benchmark stand-in for a session killed at a packet
        boundary.  Returns the truncated journal's path."""
        out_path = out_path or path + f".trunc{keep}"
        with open(path, "rb") as fh:
            blob = fh.read()
        pos = 4
        for _ in range(keep):
            (hlen,) = struct.unpack_from("<I", blob, pos)
            hdr = json.loads(blob[pos + 4:pos + 4 + hlen])
            nbytes = (int(np.prod(hdr["shape"]))
                      * np.dtype(hdr["dtype"]).itemsize)
            pos += 4 + hlen + nbytes
        with open(out_path, "wb") as fh:
            fh.write(blob[:pos])
        return out_path


def merge_spans(records) -> List[Tuple[int, int]]:
    """Merge packet spans into maximal disjoint ``[a, b)`` intervals."""
    spans = sorted((r.offset, r.offset + r.size) for r in records)
    merged: List[Tuple[int, int]] = []
    for a, b in spans:
        if merged and a <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], b))
        else:
            merged.append((a, b))
    return merged


@dataclass
class ResumeReport:
    """What :func:`resume_run` did for one node."""
    output: np.ndarray
    replayed_wg: int = 0        # work-groups restored from the journal
    executed_wg: int = 0        # work-groups re-executed via gap submits
    gaps: List[Tuple[int, int]] = field(default_factory=list)
    results: List = field(default_factory=list)   # gap RunResults

    @property
    def fully_replayed(self) -> bool:
        return self.executed_wg == 0


def resume_run(session, program, journal: RunJournal, key: str,
               **submit_kw) -> ResumeReport:
    """Resume one node of a journaled graph: replay committed packets,
    re-execute only the gaps.

    Committed spans from ``journal`` (read from disk, so a freshly
    restarted process works) are written straight into the node's output
    buffer — zero device work.  The uncovered remainder is submitted as
    lws-aligned sub-region runs (``region=``) through ``session``, with
    the same journal attached so a *second* kill resumes from strictly
    more progress.  Packet carve boundaries are always dim-0 lws-aligned
    (final remainder excepted), so every gap is a valid ROI region by
    construction.  Blocking; returns a :class:`ResumeReport`.
    """
    from repro.core.region import Dim, Region   # local: avoid cycles

    region = program.work_region
    d0 = region.dims[0]
    G = d0.size
    out_cols = program.out_cols if region.ndim == 1 \
        else region.dims[1].size * program.out_cols
    rpw = program.out_rows_per_wg
    output = np.zeros((G * rpw, out_cols), program.out_dtype)

    records = RunJournal.read(journal.path).get(key, [])
    replayed = 0
    for rec in records:
        if not (0 <= rec.offset and rec.offset + rec.size <= G):
            raise ValueError(
                f"journal {journal.path}: record [{rec.offset}, "
                f"{rec.offset + rec.size}) outside node {key!r} "
                f"work range [0, {G})")
        rows = rec.data.reshape(rec.size * rpw, out_cols)
        output[rec.offset * rpw:(rec.offset + rec.size) * rpw] = rows
    committed = merge_spans(records)
    replayed = sum(b - a for a, b in committed)

    # the gaps: maximal uncovered [a, b) intervals of the node's dim-0
    gaps: List[Tuple[int, int]] = []
    cursor = 0
    for a, b in committed:
        if a > cursor:
            gaps.append((cursor, a))
        cursor = max(cursor, b)
    if cursor < G:
        gaps.append((cursor, G))

    report = ResumeReport(output=output, replayed_wg=replayed, gaps=gaps)
    if not gaps:
        return report

    handles = []
    for a, b in gaps:
        gap_region = Region((Dim(d0.offset + a, b - a, d0.lws),)
                            + region.dims[1:])
        handles.append(session.submit(program, region=gap_region,
                                      journal=journal, journal_key=key,
                                      **submit_kw))
    for (a, b), h in zip(gaps, handles):
        res = h.result()
        report.results.append(res)
        report.executed_wg += b - a
        output[a * rpw:b * rpw] = np.asarray(res.output).reshape(
            (b - a) * rpw, out_cols)
    return report
