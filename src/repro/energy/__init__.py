"""Energy subsystem: power models, joule accounting, energy-aware policy.

Layering: this package imports nothing from ``repro.core`` (or above), so
core modules — device, metrics, scheduler, simulate, runtime — can attach
power models and stamp :class:`EnergyReport`s without an import cycle.

* :mod:`repro.energy.model` — :class:`PowerModel` (busy/idle watts,
  lock-crossing J, transfer J/byte), the ``ZERO_POWER`` joule-blind
  default, and desktop-class ``PRESETS``.
* :mod:`repro.energy.meter` — :class:`EnergyMeter` /
  :class:`EnergyReport`: one accounting-identity implementation shared by
  the threaded engine, ``simulate`` and ``simulate_serving``.

The energy-*policy* surfaces live with their peers: the budget-capped
``hguided_energy`` scheduler in ``repro.core.scheduler`` and the
``energy`` fleet placement in ``repro.fleet.placement``.
"""
from repro.energy.meter import (DeviceEnergy, EnergyMeter,  # noqa: F401
                                EnergyReport, meter_run, zero_report)
from repro.energy.model import (PRESETS, ZERO_POWER,  # noqa: F401
                                PowerModel)

__all__ = [
    "PowerModel", "ZERO_POWER", "PRESETS",
    "DeviceEnergy", "EnergyMeter", "EnergyReport", "meter_run",
    "zero_report",
]
