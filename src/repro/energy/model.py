"""Per-device power models (the energy subsystem's value types).

The source paper's opening claim is that commodity heterogeneous systems
earn their place through performance *and energy*; the "Towards Green
Computing" OpenCL survey (PAPERS.md) shows the optimal device split can
*flip* when the objective is joules instead of seconds.  This module is
the J-side vocabulary: a :class:`PowerModel` describes how one device (or
the host path serving it) converts time and traffic into energy.

Four calibrated constants per device:

* ``busy_w``          — watts while the device is executing packets;
* ``idle_w``          — watts while powered but waiting (transfer stalls,
                        scheduler waits, the run tail after the device's
                        last packet);
* ``lock_j``          — joules per scheduler global-lock crossing charged
                        to the host path (thread wake + contended hand-off
                        — the energy twin of ``SimConfig.sched_overhead_s``);
* ``xfer_j_per_byte`` — joules per byte staged between host and device
                        (DMA + memcpy energy; zero-copy devices move no
                        bytes and pay nothing).

The default model is **all zeros**: every existing config, test, journal
replay and benchmark charges exactly 0 J and produces bit-identical
results — energy is an opt-in measurement surface, not a behavior change.

``EFFICIENCY`` (J per work-group at full speed, ``busy_w / throughput``)
is the quantity the energy-capped scheduler and the ``energy`` fleet
placement rank devices by; it lives with the consumers because it needs a
throughput, which is not the model's business.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class PowerModel:
    """How one device converts time and traffic into joules."""

    busy_w: float = 0.0           # W while executing packets
    idle_w: float = 0.0           # W while powered but waiting
    lock_j: float = 0.0           # J per scheduler lock crossing (host)
    xfer_j_per_byte: float = 0.0  # J per byte staged host<->device

    def __post_init__(self):
        for name in ("busy_w", "idle_w", "lock_j", "xfer_j_per_byte"):
            v = getattr(self, name)
            if v < 0:
                raise ValueError(f"PowerModel.{name} must be >= 0, got {v}")

    @property
    def is_zero(self) -> bool:
        """True for the default joule-blind model (all existing configs)."""
        return (self.busy_w == 0.0 and self.idle_w == 0.0
                and self.lock_j == 0.0 and self.xfer_j_per_byte == 0.0)

    def joules(self, busy_s: float, idle_s: float, *,
               crossings: int = 0, bytes_moved: float = 0.0) -> float:
        """The accounting identity, per device:

            J = busy_s * busy_w + idle_s * idle_w
                + crossings * lock_j + bytes_moved * xfer_j_per_byte

        Every executor (threaded engine, ``simulate``,
        ``simulate_serving``) charges energy through this one formula, so
        the per-run total is the sum of these terms by construction —
        the same way the five phase windows sum to the wall clock.
        """
        return (busy_s * self.busy_w + idle_s * self.idle_w
                + crossings * self.lock_j
                + bytes_moved * self.xfer_j_per_byte)


#: The joule-blind default shared by every device dataclass field.
ZERO_POWER = PowerModel()

#: Calibrated desktop-class presets (orders of magnitude from the green
#: computing OpenCL survey's CPU/iGPU/dGPU measurements, not this host):
#: the discrete GPU is fastest but hungriest, the iGPU is the efficiency
#: sweet spot, the CPU pays the worst J/wg.  Benchmarks and examples use
#: these; calibrated deployments fit their own.
PRESETS: Dict[str, PowerModel] = {
    "cpu": PowerModel(busy_w=65.0, idle_w=12.0, lock_j=2e-4,
                      xfer_j_per_byte=0.0),
    "igpu": PowerModel(busy_w=28.0, idle_w=5.0, lock_j=2e-4,
                       xfer_j_per_byte=0.0),
    "gpu": PowerModel(busy_w=180.0, idle_w=25.0, lock_j=2e-4,
                      xfer_j_per_byte=6e-9),
}
