"""EnergyMeter: integrate per-device time windows into joules.

The meter is the energy twin of :class:`repro.core.runtime.PhaseClock`:
one accounting implementation shared by every executor.  Each device
contributes a :class:`DeviceEnergy` sample — busy seconds, a powered
window, lock crossings and bytes moved — and the report's totals are the
sums of the per-device terms **by construction** (the accounting
identity, enforced the same way the five phase windows sum to the wall
clock):

    total_j == sum_d ( busy_d * busy_w_d + idle_d * idle_w_d
                       + crossings_d * lock_j_d
                       + bytes_d * xfer_j_per_byte_d )

Executors fill the samples from bookkeeping they already keep:

* the threaded engine: ``RunResult.device_busy`` against the ROI window,
  the scheduler's per-device lock-crossing counters, and the bytes its
  device loops actually staged/committed;
* ``simulate`` / ``simulate_serving``: the modeled busy/stall split
  :meth:`SimDevice.packet_cost` now exposes, the same per-device crossing
  counters (same scheduler objects), and the modeled byte traffic.

Both charge the *same* :class:`repro.energy.model.PowerModel`, which is
what makes the sim/hardware energy cross-check meaningful.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.energy.model import PowerModel, ZERO_POWER


@dataclass(frozen=True)
class DeviceEnergy:
    """One device's energy sample over one run (or serving window).

    ``idle_s`` is derived: the powered window minus the busy time,
    clamped at zero (measured busy can exceed the window by clock
    granularity).  A dead device's window ends at its death — it is
    powered off, not idling, for the rest of the run.
    """
    name: str
    model: PowerModel
    busy_s: float
    window_s: float
    crossings: int = 0
    bytes_moved: float = 0.0

    @property
    def idle_s(self) -> float:
        return max(0.0, self.window_s - self.busy_s)

    @property
    def busy_j(self) -> float:
        return self.busy_s * self.model.busy_w

    @property
    def idle_j(self) -> float:
        return self.idle_s * self.model.idle_w

    @property
    def lock_j(self) -> float:
        return self.crossings * self.model.lock_j

    @property
    def xfer_j(self) -> float:
        return self.bytes_moved * self.model.xfer_j_per_byte

    @property
    def total_j(self) -> float:
        return self.model.joules(self.busy_s, self.idle_s,
                                 crossings=self.crossings,
                                 bytes_moved=self.bytes_moved)


@dataclass(frozen=True)
class EnergyReport:
    """Per-run joule accounting: per-device samples plus their totals."""
    devices: Tuple[DeviceEnergy, ...]

    @property
    def total_j(self) -> float:
        return sum(d.total_j for d in self.devices)

    @property
    def busy_j(self) -> float:
        return sum(d.busy_j for d in self.devices)

    @property
    def idle_j(self) -> float:
        return sum(d.idle_j for d in self.devices)

    @property
    def lock_j(self) -> float:
        return sum(d.lock_j for d in self.devices)

    @property
    def xfer_j(self) -> float:
        return sum(d.xfer_j for d in self.devices)

    def identity_gap(self) -> float:
        """|total - (busy + idle + lock + xfer)| — 0 up to float
        associativity; the property suite asserts it stays below 1e-9
        relative across every scheduler under fault injection."""
        return abs(self.total_j
                   - (self.busy_j + self.idle_j + self.lock_j
                      + self.xfer_j))

    def by_name(self, name: str) -> DeviceEnergy:
        for d in self.devices:
            if d.name == name:
                return d
        raise KeyError(name)

    def row(self) -> str:
        return (f"total={self.total_j:.3f}J busy={self.busy_j:.3f}J "
                f"idle={self.idle_j:.3f}J lock={self.lock_j:.4f}J "
                f"xfer={self.xfer_j:.4f}J")


class EnergyMeter:
    """Accumulate per-device samples; emit one :class:`EnergyReport`.

    ``add`` may be called once per device (batch runs) or repeatedly
    (serving: cumulative busy/crossings/bytes per round are re-sampled —
    the *last* sample per name wins, so callers pass running totals).
    """

    def __init__(self):
        self._samples: List[DeviceEnergy] = []

    def add(self, name: str, model: Optional[PowerModel], *,
            busy_s: float, window_s: float, crossings: int = 0,
            bytes_moved: float = 0.0) -> DeviceEnergy:
        sample = DeviceEnergy(name=name, model=model or ZERO_POWER,
                              busy_s=busy_s, window_s=window_s,
                              crossings=crossings, bytes_moved=bytes_moved)
        self._samples = [s for s in self._samples if s.name != name]
        self._samples.append(sample)
        return sample

    def report(self) -> EnergyReport:
        return EnergyReport(devices=tuple(self._samples))


def meter_run(result, models: Sequence[Optional[PowerModel]],
              names: Sequence[str], *,
              crossings: Optional[Sequence[int]] = None,
              bytes_moved: Optional[Sequence[float]] = None,
              windows: Optional[Sequence[float]] = None) -> EnergyReport:
    """Meter a finished run from its existing phase accounting.

    ``result`` is duck-typed ``RunResult``: ``device_busy`` gives the
    per-device busy seconds and ``phases.roi_s`` the shared powered
    window (a device is powered for the whole co-execution window, busy
    for its measured slice of it).  ``windows`` overrides the per-device
    window — the simulator passes a dead device's death time.
    """
    n = len(names)
    roi = result.phases.roi_s if result.phases is not None else 0.0
    meter = EnergyMeter()
    for i in range(n):
        meter.add(
            names[i], models[i] if i < len(models) else None,
            busy_s=result.device_busy[i],
            window_s=windows[i] if windows is not None else roi,
            crossings=crossings[i] if crossings is not None else 0,
            bytes_moved=bytes_moved[i] if bytes_moved is not None else 0.0)
    return meter.report()


def zero_report(names: Iterable[str]) -> EnergyReport:
    """The joule-blind report: every device 0 J (back-compat surface)."""
    return EnergyReport(devices=tuple(
        DeviceEnergy(name=n, model=ZERO_POWER, busy_s=0.0, window_s=0.0)
        for n in names))
