"""Pallas TPU kernel: causal flash attention (GQA-aware).

TPU adaptation of FlashAttention: grid = (batch, kv_head, q_block,
kv_block); the q block (bq, G*D) sits in VMEM, k/v stream through the
innermost (sequential) kv-grid dimension in (bk, D) blocks; the
online-softmax running max/denominator/accumulator live in VMEM scratch
across that dimension.  Causal kv blocks beyond the q block's diagonal are
skipped via pl.when — the MXU sees only lower-triangle block pairs, and the
O(S^2) scores never touch HBM (this is exactly the traffic that dominates
the baseline jnp prefill roofline; see EXPERIMENTS.md §Perf).

Block sizes: bq=bk=128 align with the 128x128 MXU; head_dim 64/80/128 all
lower cleanly.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, G: int, D: int, scale: float):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # process only kv blocks that intersect the causal lower triangle of
    # this q block (supports bq != bk)
    @pl.when(jk * bk < (iq + 1) * bq)
    def _step():
        q = q_ref[...].reshape(bq * G, D).astype(jnp.float32)   # (bq*G, D)
        k = k_ref[...].reshape(bk, D).astype(jnp.float32)
        v = v_ref[...].reshape(bk, D).astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale                             # (bq*G, bk)
        # causal mask in global positions (exact for any bq/bk ratio)
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, G, bk), 0)
        kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, G, bk), 2)
        tri = (kpos <= qpos).reshape(bq * G, bk)
        s = jnp.where(tri, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v)
        m_scr[...] = m_new

    @pl.when(jk == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l).reshape(1, 1, bq, G * D) \
            .astype(o_ref.dtype)


def flash_attention(q, k, v, *, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (B,S,H,D); k,v: (B,S,KH,D), causal. S % bq == 0 == S % bk."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = 1.0 / math.sqrt(D)
    # layout: (B, KH, S, G*D) for q; (B, KH, S, D) for k/v
    qr = jnp.moveaxis(q.reshape(B, S, KH, G, D), 1, 2).reshape(B, KH, S, G * D)
    kr = jnp.moveaxis(k, 1, 2)                                  # (B, KH, S, D)
    vr = jnp.moveaxis(v, 1, 2)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, G=G, D=D,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, KH, S // bq, S // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, G * D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, G * D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, S, G * D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq * G, 1), jnp.float32),
            pltpu.VMEM((bq * G, 1), jnp.float32),
            pltpu.VMEM((bq * G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, KH, S, G, D)
    return jnp.moveaxis(out, 2, 1).reshape(B, S, H, D)
