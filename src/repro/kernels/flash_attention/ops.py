"""Flash-attention op: jit'd wrapper, dispatching between the Pallas kernel
(TPU target / interpret validation) and the blocked-jnp path used by the
portable model stack (models/layers.py)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref as R
from repro.models.layers import blocked_causal_attention


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "chunk"))
def attention(q, k, v, *, use_pallas: bool = False, interpret: bool = True,
              chunk: int = 2048):
    if use_pallas:
        return K.flash_attention(q, k, v, interpret=interpret)
    return blocked_causal_attention(q, k, v, chunk)


attention_ref = R.attention_ref
