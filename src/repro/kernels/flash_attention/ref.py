"""Pure-jnp oracle: exact causal attention (materialized scores)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v):
    """q: (B,S,H,D); k,v: (B,S,KH,D) with H % KH == 0 -> (B,S,H,D)."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)
