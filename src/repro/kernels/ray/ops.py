"""Ray op: jit'd wrapper + range-partitionable entry (lws=128 -> one
work-group = 1 pixel row; paper scene sizes 4096px)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ray import ref as R

LWS = 4            # rows per work-group


@partial(jax.jit, static_argnames=("n_rows", "width", "height"))
def _run(centers, radii, colors, row0, *, n_rows: int, width: int,
         height: int):
    scene = {"centers": centers, "radii": radii, "colors": colors}
    return R.render_rows(scene, row0, n_rows, width, height)


def run_range(scene, offset: int, size: int, *, width: int, height: int,
              **_):
    return _run(scene["centers"], scene["radii"], scene["colors"],
                jnp.int32(offset * LWS), n_rows=size * LWS, width=width,
                height=height)


@partial(jax.jit, static_argnames=("n_rows", "n_cols", "width", "height"))
def _run_tile(centers, radii, colors, row0, col0, *, n_rows: int,
              n_cols: int, width: int, height: int):
    scene = {"centers": centers, "radii": radii, "colors": colors}
    return R.render_rows(scene, row0, n_rows, width, height,
                         col0=col0, n_cols=n_cols)


def run_region(scene, row0: int, n_rows: int, col0: int, n_cols: int, *,
               width: int, height: int):
    """Render the pixel tile [row0, row0+n_rows) x [col0, col0+n_cols)
    -> (n_rows, n_cols, 3) (the NDRange entry, coordinates in pixels)."""
    return _run_tile(scene["centers"], scene["radii"], scene["colors"],
                     jnp.int32(row0), jnp.int32(col0), n_rows=n_rows,
                     n_cols=n_cols, width=width, height=height)


def total_work(height: int) -> int:
    assert height % LWS == 0
    return height // LWS
