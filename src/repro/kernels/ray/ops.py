"""Ray op: jit'd wrapper + range-partitionable entry (lws=128 -> one
work-group = 1 pixel row; paper scene sizes 4096px)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ray import ref as R

LWS = 4            # rows per work-group


@partial(jax.jit, static_argnames=("n_rows", "width", "height"))
def _run(centers, radii, colors, row0, *, n_rows: int, width: int,
         height: int):
    scene = {"centers": centers, "radii": radii, "colors": colors}
    return R.render_rows(scene, row0, n_rows, width, height)


def run_range(scene, offset: int, size: int, *, width: int, height: int,
              **_):
    return _run(scene["centers"], scene["radii"], scene["colors"],
                jnp.int32(offset * LWS), n_rows=size * LWS, width=width,
                height=height)


def total_work(height: int) -> int:
    assert height % LWS == 0
    return height // LWS
