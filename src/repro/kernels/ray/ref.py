"""Raytracer benchmark (paper: open-source OpenCL raytracer [4], two
scenes, lws=128, custom structs, irregular workload).

Pure-jnp implementation of a sphere-scene raytracer with one bounce of
Lambert shading + hard shadows.  No Pallas kernel: per-ray control flow is
data-dependent branching (shadow rays, misses) that a TPU VPU executes as
masked lanes anyway — jnp.where already expresses exactly that; a Pallas
version would be line-for-line identical.  Two scenes ("ray1", "ray2")
differ in sphere layout, giving different irregularity profiles (paper's
Ray vs Ray2).
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np


def make_scene(which: int, n_spheres: int = 32, seed: int = 7):
    rng = np.random.default_rng(seed + which)
    if which == 1:
        centers = rng.uniform(-6, 6, (n_spheres, 3)).astype(np.float32)
        centers[:, 2] = rng.uniform(4, 14, n_spheres)
        radii = rng.uniform(0.4, 1.2, n_spheres).astype(np.float32)
    else:
        # scene 2: clustered spheres -> strongly irregular ray cost
        centers = (rng.standard_normal((n_spheres, 3)) * 1.5).astype(
            np.float32)
        centers[:, 2] = 8.0 + rng.standard_normal(n_spheres) * 0.8
        radii = rng.uniform(0.2, 2.2, n_spheres).astype(np.float32)
    colors = rng.uniform(0.2, 1.0, (n_spheres, 3)).astype(np.float32)
    return {"centers": jnp.asarray(centers), "radii": jnp.asarray(radii),
            "colors": jnp.asarray(colors)}


_LIGHT = jnp.asarray([8.0, 10.0, -2.0])


def _intersect(orig, dirn, centers, radii):
    """Returns (t_hit, idx) closest sphere per ray. orig/dirn: (..., 3)."""
    oc = orig[..., None, :] - centers                 # (..., S, 3)
    b = (oc * dirn[..., None, :]).sum(-1)
    c = (oc * oc).sum(-1) - radii ** 2
    disc = b * b - c
    ok = disc > 0
    sq = jnp.sqrt(jnp.where(ok, disc, 0.0))
    t0 = -b - sq
    t1 = -b + sq
    t = jnp.where(t0 > 1e-3, t0, t1)
    t = jnp.where(ok & (t > 1e-3), t, jnp.inf)
    idx = jnp.argmin(t, axis=-1)
    return jnp.take_along_axis(t, idx[..., None], axis=-1)[..., 0], idx


def render_rows(scene, row0, n_rows: int, width: int, height: int,
                col0=0, n_cols: int = 0):
    """Shade the pixel tile rows [row0, row0+n_rows) x cols
    [col0, col0+n_cols) -> (n_rows, n_cols, 3); n_cols=0 = full width."""
    if not n_cols:
        n_cols = width
    ys = (jnp.arange(n_rows) + row0 + 0.5) / height * 2.0 - 1.0
    xs = (jnp.arange(n_cols) + col0 + 0.5) / width * 2.0 - 1.0
    dirx = jnp.broadcast_to(xs[None, :], (n_rows, n_cols))
    diry = jnp.broadcast_to(-ys[:, None], (n_rows, n_cols))
    dirz = jnp.ones((n_rows, n_cols), jnp.float32)
    d = jnp.stack([dirx, diry, dirz], axis=-1)
    d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
    o = jnp.zeros_like(d)
    t, idx = _intersect(o, d, scene["centers"], scene["radii"])
    hit = jnp.isfinite(t)
    tsafe = jnp.where(hit, t, 0.0)
    p = o + d * tsafe[..., None]
    n = (p - scene["centers"][idx])
    n = n / jnp.maximum(jnp.linalg.norm(n, axis=-1, keepdims=True), 1e-6)
    l = _LIGHT - p
    l = l / jnp.maximum(jnp.linalg.norm(l, axis=-1, keepdims=True), 1e-6)
    lam = jnp.maximum((n * l).sum(-1), 0.0)
    # hard shadow ray
    ts, _ = _intersect(p + n * 1e-3, l, scene["centers"], scene["radii"])
    lit = ~jnp.isfinite(ts)
    base = scene["colors"][idx]
    shade = base * (0.15 + 0.85 * lam * lit.astype(jnp.float32))[..., None]
    bg = jnp.broadcast_to(jnp.asarray([0.05, 0.05, 0.1]), shade.shape)
    return jnp.where(hit[..., None], shade, bg)
