"""Pure-jnp oracle: sequential selective-scan recurrence.

h_t = a_t * h_{t-1} + b_t ;  y_t = sum_s C_t[s] * h_t[:, s]
a,b: (B,S,di,ds); C: (B,S,ds) -> y: (B,S,di), h_T: (B,di,ds)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(a, b, C, h0=None):
    B, S, di, ds = a.shape
    h = jnp.zeros((B, di, ds), jnp.float32) if h0 is None else h0

    def body(h, xs):
        at, bt, Ct = xs
        h = at * h + bt
        y = jnp.einsum("bds,bs->bd", h, Ct)
        return h, y

    h, ys = jax.lax.scan(
        body, h, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0),
                  jnp.moveaxis(C, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), h
