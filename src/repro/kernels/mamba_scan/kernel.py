"""Pallas TPU kernel: chunked selective scan (Mamba1 recurrence).

TPU adaptation: the CUDA kernel parallelizes over (batch, d_inner) threads
with a sequential time loop in registers.  Here the grid is
(batch, d_inner tiles, seq chunks); the innermost chunk axis is sequential
("arbitrary" dimension semantics) and carries the hidden state in a VMEM
scratch that persists across grid steps — the TPU analogue of the
register-resident state.  Within a chunk the recurrence is an in-VMEM
fori loop over (tile_d, ds) planes: elementwise VPU work with zero HBM
traffic for intermediate h.  VMEM per step: 3 * chunk * tile_d * ds * 4B
(a,b blocks) + tile_d * ds scratch ≈ 2.2 MiB at chunk=64, tile_d=512,
ds=16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr, *,
                 chunk: int, tile_d: int, ds: int):
    jc = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(jc == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].reshape(chunk, tile_d, ds)
    b = b_ref[...].reshape(chunk, tile_d, ds)
    c = c_ref[...].reshape(chunk, ds)

    def body(t, carry):
        h, ys = carry
        h = a[t] * h + b[t]                       # (tile_d, ds)
        y = (h * c[t][None, :]).sum(axis=1)       # (tile_d,)
        ys = jax.lax.dynamic_update_slice(ys, y[None, :], (t, 0))
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros((chunk, tile_d), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, body, (h0, ys0))
    h_scr[...] = h
    y_ref[...] = ys.reshape(1, chunk, tile_d)

    @pl.when(jc == nc - 1)
    def _finish():
        hout_ref[...] = h.reshape(1, tile_d, ds)


def selective_scan(a, b, C, *, chunk: int = 64, tile_d: int = 512,
                   interpret: bool = True):
    """a,b: (B,S,di,ds) f32; C: (B,S,ds) f32 -> (y (B,S,di), h (B,di,ds))."""
    B, S, di, ds = a.shape
    chunk = min(chunk, S)
    tile_d = min(tile_d, di)
    assert S % chunk == 0 and di % tile_d == 0, (S, chunk, di, tile_d)
    kernel = functools.partial(_scan_kernel, chunk=chunk, tile_d=tile_d,
                               ds=ds)
    # layouts: a,b -> (B, di_tiles...) keep (B, S, di, ds); block S, di
    y, h = pl.pallas_call(
        kernel,
        grid=(B, di // tile_d, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, tile_d, ds),
                         lambda bi, di_, jc: (bi, jc, di_, 0)),
            pl.BlockSpec((1, chunk, tile_d, ds),
                         lambda bi, di_, jc: (bi, jc, di_, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bi, di_, jc: (bi, jc, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, tile_d),
                         lambda bi, di_, jc: (bi, jc, di_)),
            pl.BlockSpec((1, tile_d, ds), lambda bi, di_, jc: (bi, di_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((tile_d, ds), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if hasattr(pltpu, "CompilerParams") else None,
    )(a, b, C)
    return y, h
