"""Selective-scan op: jit'd wrapper dispatching Pallas kernel vs the
chunked associative-scan jnp path used by the portable model stack."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan import kernel as K
from repro.kernels.mamba_scan import ref as R
from repro.models.layers import _ssm_scan_chunked


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "chunk"))
def selective_scan(a, b, C, *, use_pallas: bool = False,
                   interpret: bool = True, chunk: int = 128):
    if use_pallas:
        return K.selective_scan(a, b, C, chunk=min(chunk, 64),
                                interpret=interpret)
    B, S, di, ds = a.shape
    h0 = jnp.zeros((B, di, ds), jnp.float32)
    y, h = _ssm_scan_chunked(a, b, C, h0, chunk)
    return y, h


selective_scan_ref = R.selective_scan_ref
