"""Flash-decode op: jit'd wrapper dispatching the Pallas kernel (TPU
target / interpret validation) vs the portable mixed-precision jnp path
used by models/layers.py::cached_decode_attention."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_decode import kernel as K
from repro.kernels.flash_decode import ref as R
from repro.models.layers import cached_decode_attention


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "bk"))
def decode_attention(q, k_cache, v_cache, pos, *, use_pallas: bool = False,
                     interpret: bool = True, bk: int = 512):
    """q: (B,H,D); caches: (B,S,KH,D); pos: () -> (B,H,D)."""
    if use_pallas:
        return K.flash_decode(q, k_cache, v_cache, pos, bk=bk,
                              interpret=interpret)
    out = cached_decode_attention(q[:, None], k_cache, v_cache, pos)
    return out[:, 0]


decode_attention_ref = R.decode_attention_ref
