"""Pure-jnp oracle: single-token cached decode attention (GQA)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q: (B,H,D); caches: (B,S,KH,D); pos: () -> (B,H,D).
    Attends to cache positions [0, pos]."""
    B, H, D = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    s = s / math.sqrt(D)
    valid = (jnp.arange(k_cache.shape[1]) <= pos)[None, None, None, :]
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
