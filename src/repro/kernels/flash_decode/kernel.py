"""Pallas TPU kernel: flash-decode — single-token attention over a long KV
cache (the §Perf cell C "next lever").

TPU adaptation: grid = (batch, kv_head, kv_block); the (G, D) query tile
sits in VMEM, cache blocks (bk, D) stream through the sequential innermost
grid axis in their STORAGE dtype (bf16 — no f32 cache copy ever exists,
matching the mixed-precision jnp path), online-softmax state in VMEM
scratch.  Blocks entirely beyond `pos` are skipped with pl.when — the
kernel reads exactly ceil((pos+1)/bk) cache blocks, which is the
irreducible decode traffic.  The masked tail inside the boundary block is
handled with a positional mask.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, bk: int, G: int, D: int, scale: float):
    jk = pl.program_id(2)
    nk = pl.num_programs(2)
    pos = pos_ref[0]

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip cache blocks entirely beyond the current position
    @pl.when(jk * bk <= pos)
    def _step():
        q = q_ref[...].reshape(G, D)
        k = k_ref[...].reshape(bk, D)
        v = v_ref[...].reshape(bk, D)
        s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
        kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p.astype(v.dtype), v.astype(jnp.float32))
        m_scr[...] = m_new

    @pl.when(jk == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l).reshape(1, 1, G, D).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, pos, *, bk: int = 512,
                 interpret: bool = True):
    """q: (B,H,D); caches: (B,S,KH,D) in storage dtype; pos: () int32."""
    B, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    scale = 1.0 / math.sqrt(D)
    qr = q.reshape(B, KH, G * D)
    kr = jnp.moveaxis(k_cache, 1, 2)          # (B, KH, S, D)
    vr = jnp.moveaxis(v_cache, 1, 2)
    pos_arr = jnp.asarray([pos], jnp.int32)
    kernel = functools.partial(_decode_kernel, bk=bk, G=G, D=D, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, KH, S // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (0,)),
            pl.BlockSpec((1, 1, G * D), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qr, kr, vr)
    return out.reshape(B, H, D)
