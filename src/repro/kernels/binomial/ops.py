"""Binomial op: jit'd wrapper + range-partitionable entry.
One work-group = LWS options (the paper's one-option-per-work-group with
lws=255 turns into option tiles on TPU)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.binomial import kernel as K
from repro.kernels.binomial import ref as R

LWS = 128
STEPS = R.STEPS


def make_inputs(n_options: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    s0 = rng.uniform(5.0, 30.0, n_options).astype(np.float32)
    strike = rng.uniform(1.0, 100.0, n_options).astype(np.float32)
    ty = rng.uniform(0.25, 10.0, n_options).astype(np.float32)
    return s0, strike, ty


@partial(jax.jit, static_argnames=("size", "use_pallas", "interpret"))
def _run(s0, strike, ty, offset, *, size: int, use_pallas: bool = False,
         interpret: bool = True):

    def sl(x):
        return jax.lax.dynamic_slice(x, (offset,), (size,))

    a, b, c = sl(s0), sl(strike), sl(ty)
    if use_pallas:
        return K.price_options(a, b, c, steps=STEPS, tile=min(128, size),
                               interpret=interpret)
    return R.price_options(a, b, c, steps=STEPS)


def run_range(s0, strike, ty, offset: int, size: int, *,
              use_pallas: bool = False, interpret: bool = True):
    return _run(s0, strike, ty, jnp.int32(offset * LWS), size=size * LWS,
                use_pallas=use_pallas, interpret=interpret)


def total_work(n_options: int) -> int:
    assert n_options % LWS == 0
    return n_options // LWS
