"""Pure-jnp oracle for Binomial option pricing (paper Table I: lws=255,
4194304 samples, 1:1 buffers, 1:255 out pattern, uses local memory).

European call priced on a recombining binomial tree with N=254 steps
(so each option's tree has lws=255 leaves, matching the OpenCL kernel
that maps one option per work-group of 255 work-items)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

STEPS = 254
RISKFREE = 0.02
VOLATILITY = 0.30


def price_options(s0, strike, t_years, *, steps: int = STEPS):
    """s0/strike/t_years: (n,) arrays -> (n,) option values."""
    dt = t_years / steps
    vdt = VOLATILITY * jnp.sqrt(dt)
    u = jnp.exp(vdt)
    d = 1.0 / u
    a = jnp.exp(RISKFREE * dt)
    pu = (a - d) / (u - d)
    pd = 1.0 - pu
    disc = jnp.exp(-RISKFREE * dt)
    j = jnp.arange(steps + 1, dtype=jnp.float32)
    # leaf prices: S * u^j * d^(steps-j)
    sT = s0[:, None] * jnp.exp(vdt[:, None] * (2.0 * j[None, :] - steps))
    v = jnp.maximum(sT - strike[:, None], 0.0)

    def body(i, v):
        # v[:, :steps+1-i] = disc * (pd*v[:, :-1] + pu*v[:, 1:]) -- fixed
        # width with trailing garbage, masked out by construction
        vn = disc[:, None] * (pd[:, None] * v[:, :-1] + pu[:, None] * v[:, 1:])
        return jnp.concatenate([vn, v[:, -1:]], axis=1)

    v = jax.lax.fori_loop(0, steps, body, v)
    return v[:, 0]
