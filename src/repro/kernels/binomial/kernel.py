"""Pallas TPU kernel: binomial option pricing, option-tile blocked.

TPU adaptation: OpenCL maps one option per work-group and one tree level
per 255-work-item local array with barriers between backward-induction
steps.  On TPU the whole (tile_opts, steps+1) value plane lives in VMEM and
each induction step is one fused VPU op over the plane — barriers become
data flow.  tile=128 options x 256 levels x 4B = 128 KiB VMEM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.binomial.ref import RISKFREE, VOLATILITY


def _binomial_kernel(s0_ref, strike_ref, ty_ref, out_ref, *, steps: int):
    s0 = s0_ref[...]
    strike = strike_ref[...]
    ty = ty_ref[...]
    dt = ty / steps
    vdt = VOLATILITY * jnp.sqrt(dt)
    u_minus_d = jnp.exp(vdt) - jnp.exp(-vdt)
    a = jnp.exp(RISKFREE * dt)
    pu = (a - jnp.exp(-vdt)) / u_minus_d
    pd = 1.0 - pu
    disc = jnp.exp(-RISKFREE * dt)
    j = jnp.arange(steps + 1, dtype=jnp.float32)
    sT = s0[:, None] * jnp.exp(vdt[:, None] * (2.0 * j[None, :] - steps))
    v = jnp.maximum(sT - strike[:, None], 0.0)

    def body(i, v):
        vn = disc[:, None] * (pd[:, None] * v[:, :-1] + pu[:, None] * v[:, 1:])
        return jnp.concatenate([vn, v[:, -1:]], axis=1)

    v = jax.lax.fori_loop(0, steps, body, v)
    out_ref[...] = v[:, 0]


def price_options(s0, strike, t_years, *, steps: int = 254,
                  tile: int = 128, interpret: bool = True):
    n = s0.shape[0]
    assert n % tile == 0, (n, tile)
    kernel = functools.partial(_binomial_kernel, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))] * 3,
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(s0, strike, t_years)
