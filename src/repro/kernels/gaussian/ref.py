"""Pure-jnp oracle for the Gaussian blur benchmark (paper Table I:
lws=128, 2:1 read:write buffers, 8192px image, 31px filter)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gaussian_weights(ksize: int, sigma: float = 0.0) -> np.ndarray:
    sigma = sigma or 0.3 * ((ksize - 1) * 0.5 - 1) + 0.8
    x = np.arange(ksize) - (ksize - 1) / 2
    w = np.exp(-(x * x) / (2 * sigma * sigma))
    return (w / w.sum()).astype(np.float32)


def blur_rows_ref(img_padded, w1d, row0: int, n_rows: int):
    """Separable 2D gaussian blur of rows [row0, row0+n_rows).
    img_padded: (H + K - 1, W + K - 1) with symmetric K//2 halo."""
    K = w1d.shape[0]
    Wout = img_padded.shape[1] - (K - 1)
    block = jnp.asarray(img_padded[row0:row0 + n_rows + K - 1])
    # vertical pass
    tmp = sum(w1d[k] * block[k:k + n_rows, :] for k in range(K))
    # horizontal pass
    out = sum(w1d[k] * tmp[:, k:k + Wout] for k in range(K))
    return out


def blur_full_ref(img, ksize: int = 31):
    w = jnp.asarray(gaussian_weights(ksize))
    pad = ksize // 2
    ip = jnp.pad(img, pad, mode="edge")
    return blur_rows_ref(ip, w, 0, img.shape[0])
