"""Pallas TPU kernel: separable Gaussian blur, row-tile blocked.

TPU adaptation (vs the OpenCL per-pixel NDRange): one grid step produces a
``tile_h x W`` row band.  The vertical pass needs a K-1 row halo; Pallas
blocks are non-overlapping, so the kernel takes the padded image twice —
block i ("cur") and block i+1 ("nxt") — and assembles the
``tile_h + K - 1`` band in VMEM (requires K - 1 <= tile_h, true for the
paper's 31px filter with tile_h = 64).  The horizontal pass slides within
the band with static slices => unrolled VPU vector ops.  VMEM working set:
2 * tile_h * (W + K - 1) * 4B ≈ 4.2 MiB at W = 8192.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blur_kernel(cur_ref, nxt_ref, w_ref, out_ref, *, K: int, tile_h: int):
    cur = cur_ref[...]                       # (tile_h, Wp)
    nxt = nxt_ref[...]                       # (tile_h, Wp)
    w = w_ref[...]                           # (K,)
    band = jnp.concatenate([cur, nxt[:K - 1, :]], axis=0)
    Wout = out_ref.shape[1]
    tmp = jnp.zeros((tile_h, band.shape[1]), jnp.float32)
    for k in range(K):                       # vertical pass (static unroll)
        tmp = tmp + w[k] * band[k:k + tile_h, :]
    out = jnp.zeros((tile_h, Wout), jnp.float32)
    for k in range(K):                       # horizontal pass
        out = out + w[k] * tmp[:, k:k + Wout]
    out_ref[...] = out


def blur_rows(img_padded, w1d, *, tile_h: int = 64, interpret: bool = True):
    """img_padded: (H + K - 1, W + K - 1) with edge padding; returns (H, W).
    H must be a multiple of tile_h and K - 1 <= tile_h."""
    K = w1d.shape[0]
    Hp, Wp = img_padded.shape
    H, W = Hp - (K - 1), Wp - (K - 1)
    assert H % tile_h == 0, (H, tile_h)
    assert K - 1 <= tile_h, (K, tile_h)
    n = H // tile_h
    # room for the "next" view of the final tile: pad rows to (n + 1) tiles
    extra = (n + 1) * tile_h - Hp
    imgp = jnp.pad(img_padded, ((0, max(extra, 0)), (0, 0)))
    grid = (n,)
    kernel = functools.partial(_blur_kernel, K=K, tile_h=tile_h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_h, Wp), lambda i: (i, 0)),       # cur band
            pl.BlockSpec((tile_h, Wp), lambda i: (i + 1, 0)),   # halo band
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_h, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        interpret=interpret,
    )(imgp, imgp, w1d)
