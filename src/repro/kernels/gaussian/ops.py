"""Gaussian blur op: jit'd wrapper + range-partitionable co-execution entry.

``run_range(img_padded, w, offset, size)`` computes work-groups
[offset, offset+size) where one work-group = ``lws`` output rows — the unit
the schedulers partition (paper Table I: lws=128).
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np

from repro.kernels.gaussian import kernel as K
from repro.kernels.gaussian import ref as R

LWS = 128          # output rows per work-group (paper: local work size)
KSIZE = 31


def prepare(img: np.ndarray, ksize: int = KSIZE):
    """Host-side setup: pad once (read-only input buffer)."""
    pad = ksize // 2
    ip = np.pad(img, pad, mode="edge").astype(np.float32)
    w = R.gaussian_weights(ksize)
    return ip, w


@partial(jax.jit, static_argnames=("n_rows", "use_pallas", "interpret"))
def _run(img_padded, w, row0, *, n_rows: int, use_pallas: bool = False,
         interpret: bool = True):
    if use_pallas:
        Hp, Wp = img_padded.shape
        Ks = w.shape[0]
        block = jax.lax.dynamic_slice(
            img_padded, (row0, 0), (n_rows + Ks - 1, Wp))
        return K.blur_rows(block, w, tile_h=min(64, n_rows),
                           interpret=interpret)
    return _ref_range(img_padded, w, row0, n_rows)


def _ref_range(img_padded, w, row0, n_rows):
    Ks = w.shape[0]
    Wp = img_padded.shape[1]
    block = jax.lax.dynamic_slice(img_padded, (row0, 0),
                                  (n_rows + Ks - 1, Wp))
    tmp = sum(w[k] * block[k:k + n_rows, :] for k in range(Ks))
    Wout = Wp - (Ks - 1)
    return sum(w[k] * tmp[:, k:k + Wout] for k in range(Ks))


def run_range(img_padded, w, offset: int, size: int, *,
              use_pallas: bool = False, interpret: bool = True):
    """Blur output work-groups [offset, offset+size); returns
    (size*LWS, W) rows."""
    return _run(img_padded, w, offset * LWS, n_rows=size * LWS,
                use_pallas=use_pallas, interpret=interpret)


@partial(jax.jit, static_argnames=("n_rows", "n_cols"))
def _run_tile(img_padded, w, row0, col0, *, n_rows: int, n_cols: int):
    Ks = w.shape[0]
    block = jax.lax.dynamic_slice(
        img_padded, (row0, col0), (n_rows + Ks - 1, n_cols + Ks - 1))
    tmp = sum(w[k] * block[k:k + n_rows, :] for k in range(Ks))
    return sum(w[k] * tmp[:, k:k + n_cols] for k in range(Ks))


def run_region(img_padded, w, row0: int, n_rows: int,
               col0: int, n_cols: int):
    """Blur the output tile [row0, row0+n_rows) x [col0, col0+n_cols)
    (the NDRange entry: coordinates in output pixels).  One compiled
    executable serves every same-shape tile — re-offloading an ROI pays
    only the kernel, as the paper's ROI mode requires."""
    return _run_tile(img_padded, w, row0, col0,
                     n_rows=n_rows, n_cols=n_cols)


def total_work(img: np.ndarray) -> int:
    assert img.shape[0] % LWS == 0
    return img.shape[0] // LWS
