"""Pallas TPU kernel: all-pairs NBody accelerations, target-tile blocked.

TPU adaptation: the OpenCL kernel tiles sources through local memory with
barriers.  Here one grid step owns a (tile_t) target block in VMEM; sources
stream through the second grid dimension in (tile_s, 4) blocks and the
(tile_t, tile_s) pairwise interactions are VPU broadcasts; the partial
accelerations accumulate in the output block across the source-grid
dimension (revisited output block — the standard Pallas reduction
pattern).  VMEM: tile_t*4 + tile_s*4 + tile_t*tile_s floats ~ 0.3 MiB at
256x256."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.nbody.ref import EPS2


def _nbody_kernel(tgt_ref, src_ref, out_ref, *, tile_t: int, tile_s: int):
    j = pl.program_id(1)
    tgt = tgt_ref[...]                      # (tile_t, 4)
    src = src_ref[...]                      # (tile_s, 4)
    d = src[None, :, :3] - tgt[:, None, :3]          # (T, S, 3)
    r2 = (d * d).sum(-1) + EPS2
    inv_r3 = jax.lax.rsqrt(r2) / r2 * src[None, :, 3]
    acc = (d * inv_r3[..., None]).sum(axis=1)        # (T, 3)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += acc


def accelerations(targets, sources, *, tile_t: int = 128, tile_s: int = 256,
                  interpret: bool = True):
    """targets: (T, 4); sources: (N, 4) -> (T, 3)."""
    T = targets.shape[0]
    N = sources.shape[0]
    assert T % tile_t == 0 and N % tile_s == 0, (T, N)
    kernel = functools.partial(_nbody_kernel, tile_t=tile_t, tile_s=tile_s)
    return pl.pallas_call(
        kernel,
        grid=(T // tile_t, N // tile_s),
        in_specs=[
            pl.BlockSpec((tile_t, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_s, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_t, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 3), jnp.float32),
        interpret=interpret,
    )(targets, sources)
