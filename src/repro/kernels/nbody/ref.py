"""Pure-jnp oracle for NBody (paper Table I: lws=64, 229376 bodies,
2:2 buffers, 7 kernel args): one Euler step of all-pairs gravitation."""
from __future__ import annotations

import jax.numpy as jnp

EPS2 = 1e-3
DT = 0.005


def accelerations(pos_mass, tgt0: int, n_tgt: int):
    """pos_mass: (N, 4) = [x,y,z,m]; returns (n_tgt, 3) accelerations of
    bodies [tgt0, tgt0+n_tgt)."""
    tgt = jnp.asarray(pos_mass[tgt0:tgt0 + n_tgt, :3])
    src = pos_mass[:, :3]
    m = pos_mass[:, 3]
    d = src[None, :, :] - tgt[:, None, :]               # (T, N, 3)
    r2 = (d * d).sum(-1) + EPS2
    inv_r3 = jnp.power(r2, -1.5) * m[None, :]
    return (d * inv_r3[..., None]).sum(axis=1)          # (T, 3)


def step(pos_mass, vel, tgt0: int, n_tgt: int):
    """Euler update of the target slice; returns (new_pos_mass_slice,
    new_vel_slice) each (n_tgt, 4)/(n_tgt, 3)."""
    acc = accelerations(pos_mass, tgt0, n_tgt)
    v = vel[tgt0:tgt0 + n_tgt] + acc * DT
    p = pos_mass[tgt0:tgt0 + n_tgt, :3] + v * DT
    pm = jnp.concatenate([p, pos_mass[tgt0:tgt0 + n_tgt, 3:]], axis=1)
    return pm, v
