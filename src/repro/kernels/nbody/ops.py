"""NBody op: jit'd wrapper + range-partitionable entry (lws=64 bodies)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.nbody import kernel as K
from repro.kernels.nbody import ref as R

LWS = 64
DT = R.DT


def make_inputs(n_bodies: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    pos = rng.standard_normal((n_bodies, 3)).astype(np.float32) * 10.0
    mass = rng.uniform(0.5, 2.0, (n_bodies, 1)).astype(np.float32)
    vel = rng.standard_normal((n_bodies, 3)).astype(np.float32) * 0.1
    return np.concatenate([pos, mass], 1), vel


@partial(jax.jit, static_argnames=("size", "use_pallas", "interpret"))
def _run(pos_mass, vel, offset, *, size: int, use_pallas: bool = False,
         interpret: bool = True):
    if use_pallas:
        tgt = jax.lax.dynamic_slice(pos_mass, (offset, 0), (size, 4))
        acc = K.accelerations(tgt, pos_mass, tile_t=min(128, size),
                              interpret=interpret)
        v = jax.lax.dynamic_slice(vel, (offset, 0), (size, 3)) + acc * DT
        p = tgt[:, :3] + v * DT
        return jnp.concatenate([p, tgt[:, 3:], v], axis=1)
    tgt = jax.lax.dynamic_slice(pos_mass, (offset, 0), (size, 4))
    src = pos_mass[:, :3]
    m = pos_mass[:, 3]
    d = src[None, :, :] - tgt[:, None, :3]
    r2 = (d * d).sum(-1) + R.EPS2
    inv_r3 = jax.lax.rsqrt(r2) / r2 * m[None, :]
    acc = (d * inv_r3[..., None]).sum(axis=1)
    v = jax.lax.dynamic_slice(vel, (offset, 0), (size, 3)) + acc * DT
    p = tgt[:, :3] + v * DT
    return jnp.concatenate([p, tgt[:, 3:], v], axis=1)


def run_range(pos_mass, vel, offset: int, size: int, *,
              use_pallas: bool = False, interpret: bool = True):
    """Returns (size*LWS, 7) rows: [x,y,z,m,vx,vy,vz] after one step."""
    return _run(pos_mass, vel, jnp.int32(offset * LWS), size=size * LWS,
                use_pallas=use_pallas, interpret=interpret)


def total_work(n_bodies: int) -> int:
    assert n_bodies % LWS == 0
    return n_bodies // LWS
