"""Mandelbrot op: jit'd wrapper + range-partitionable entry (lws=256 px
rows... the paper's lws=256 work-items = 1 row-block of the 14336px image;
we define 1 work-group = 1 pixel row block of 256/width... practically:
one work-group = 2 rows at width 128 lanes per row-group; for simplicity
1 work-group = 1 image row)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mandelbrot import kernel as K
from repro.kernels.mandelbrot import ref as R

LWS = 8            # rows per work-group (alignment unit for packets)
MAX_ITER = 5000


@partial(jax.jit, static_argnames=("n_rows", "width", "height", "max_iter",
                                   "use_pallas", "interpret"))
def _run(row0, *, n_rows: int, width: int, height: int, max_iter: int,
         use_pallas: bool = False, interpret: bool = True):
    if use_pallas:
        return K.escape_counts(row0, n_rows, width, height, max_iter,
                               interpret=interpret)
    return R.escape_counts(row0, n_rows, width, height, max_iter)


def run_range(offset: int, size: int, *, width: int, height: int,
              max_iter: int = MAX_ITER, use_pallas: bool = False,
              interpret: bool = True):
    return _run(jnp.int32(offset * LWS), n_rows=size * LWS, width=width,
                height=height, max_iter=max_iter, use_pallas=use_pallas,
                interpret=interpret)


@partial(jax.jit, static_argnames=("n_rows", "n_cols", "width", "height",
                                   "max_iter"))
def _run_tile(row0, col0, *, n_rows: int, n_cols: int, width: int,
              height: int, max_iter: int):
    return R.escape_counts(row0, n_rows, width, height, max_iter,
                           col0=col0, n_cols=n_cols)


def run_region(row0: int, n_rows: int, col0: int, n_cols: int, *,
               width: int, height: int, max_iter: int = MAX_ITER):
    """Escape counts for the pixel tile [row0, row0+n_rows) x
    [col0, col0+n_cols) (the NDRange entry, coordinates in pixels)."""
    return _run_tile(jnp.int32(row0), jnp.int32(col0), n_rows=n_rows,
                     n_cols=n_cols, width=width, height=height,
                     max_iter=max_iter)


def total_work(height: int) -> int:
    assert height % LWS == 0
    return height // LWS
