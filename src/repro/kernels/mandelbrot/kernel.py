"""Pallas TPU kernel: Mandelbrot escape iterations, row-tile blocked.

TPU adaptation: the OpenCL kernel is one work-item per pixel with early
exit; SIMD lanes on the VPU can't exit early, so the kernel runs the fixed
``max_iter`` loop over a (tile_h, W) VMEM tile with a liveness mask — the
exact shape a TPU vector unit wants.  The irregularity the paper exploits
(work varies per region) survives at packet granularity: rows in the
needle/bulb region cost the full 5000 iterations in every lane, edge rows
exit the mask early (the `alive` popcount drops but the loop is fixed —
cost becomes uniform per packet, which is FASTER and is recorded in
DESIGN.md as a TPU-vs-GPU behavioural difference; the co-execution figures
model the GPU-style early-exit cost profile in the simulator)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.mandelbrot.ref import X0, X1, Y0, Y1


def _mandel_kernel(row0_ref, out_ref, *, width: int, height: int,
                   tile_h: int, max_iter: int):
    i = pl.program_id(0)
    row0 = row0_ref[0] + i * tile_h
    ys = Y0 + (Y1 - Y0) * (jnp.arange(tile_h, dtype=jnp.float32)
                           + row0.astype(jnp.float32) + 0.5) / height
    xs = X0 + (X1 - X0) * (jnp.arange(width, dtype=jnp.float32) + 0.5) / width
    cr = jnp.broadcast_to(xs[None, :], (tile_h, width))
    ci = jnp.broadcast_to(ys[:, None], (tile_h, width))

    def body(_, st):
        zr, zi, cnt = st
        zr2, zi2 = zr * zr, zi * zi
        alive = (zr2 + zi2) <= 4.0
        new_zr = jnp.where(alive, zr2 - zi2 + cr, zr)
        new_zi = jnp.where(alive, 2 * zr * zi + ci, zi)
        return new_zr, new_zi, cnt + alive.astype(jnp.int32)

    zr = jnp.zeros((tile_h, width), jnp.float32)
    zi = jnp.zeros((tile_h, width), jnp.float32)
    cnt = jnp.zeros((tile_h, width), jnp.int32)
    _, _, cnt = jax.lax.fori_loop(0, max_iter, body, (zr, zi, cnt))
    out_ref[...] = cnt


def escape_counts(row0, n_rows: int, width: int, height: int,
                  max_iter: int, *, tile_h: int = 8, interpret: bool = True):
    assert n_rows % tile_h == 0
    grid = (n_rows // tile_h,)
    kernel = functools.partial(_mandel_kernel, width=width, height=height,
                               tile_h=tile_h, max_iter=max_iter)
    row0_arr = jnp.asarray([row0], jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((tile_h, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, width), jnp.int32),
        interpret=interpret,
    )(row0_arr)
