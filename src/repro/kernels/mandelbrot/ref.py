"""Pure-jnp oracle for Mandelbrot (paper Table I: lws=256, 14336px,
5000 max iterations, 4:1 out pattern, irregular workload)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# view window matching the classic AMD APP SDK sample
X0, X1 = -2.25, 0.75
Y0, Y1 = -1.5, 1.5


def escape_counts(row0: int, n_rows: int, width: int, height: int,
                  max_iter: int, col0: int = 0, n_cols: int = 0):
    """Iteration counts for the pixel tile rows [row0, row0+n_rows) x
    cols [col0, col0+n_cols); n_cols=0 means the full width."""
    if not n_cols:
        n_cols = width
    ys = Y0 + (Y1 - Y0) * (jnp.arange(n_rows) + row0 + 0.5) / height
    xs = X0 + (X1 - X0) * (jnp.arange(n_cols) + col0 + 0.5) / width
    cr = jnp.broadcast_to(xs[None, :], (n_rows, n_cols))
    ci = jnp.broadcast_to(ys[:, None], (n_rows, n_cols))

    def body(_, st):
        zr, zi, cnt = st
        zr2, zi2 = zr * zr, zi * zi
        alive = (zr2 + zi2) <= 4.0
        new_zr = jnp.where(alive, zr2 - zi2 + cr, zr)
        new_zi = jnp.where(alive, 2 * zr * zi + ci, zi)
        return new_zr, new_zi, cnt + alive.astype(jnp.int32)

    zr = jnp.zeros_like(cr)
    zi = jnp.zeros_like(ci)
    cnt = jnp.zeros(cr.shape, jnp.int32)
    zr, zi, cnt = jax.lax.fori_loop(0, max_iter, body, (zr, zi, cnt))
    return cnt
