"""Logical-axis sharding resolver.

Every parameter / activation in the framework is annotated with a tuple of
*logical* axis names (``("vocab", "d_model")`` …).  The resolver maps logical
names to mesh axes through an ordered rule table with **divisibility
fallbacks**: a rule is only taken if the mesh-axis product divides the dim
size and none of its mesh axes is already used by another dim of the same
tensor.  This is what lets one rule table serve all ten assigned archs —
e.g. internvl2's 14 heads or 151655 vocab simply fall through to the next
candidate (or replication) instead of crashing the partitioner.

FSDP: for parameters we additionally shard the largest still-unsharded dim
over the ``data`` (and ``pod``) axes — ZeRO-3 style — when the config asks
for it.  XLA/GSPMD inserts the per-layer all-gathers inside the layer scan.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Candidate mesh-axis tuples per logical axis, in preference order.  An empty
# tuple means "replicate" and always succeeds.
Rules = Dict[str, List[Tuple[str, ...]]]

# Priority: lower = resolved first (gets first pick of mesh axes).
_PRIORITY = {
    "batch": 0,
    "experts": 1,
    "heads": 2,
    "d_ff": 2,
    "d_inner": 2,
    "vocab": 3,
    "kv_heads": 4,
    "kv_seq": 5,
    "seq": 6,
    "d_model": 8,       # last-resort TP dim (row-parallel fallback)
    "capacity": 7,
}

DEFAULT_RULES: Rules = {
    "batch":    [("pod", "data"), ("data",)],
    "experts":  [("model",)],
    "heads":    [("model",)],
    "kv_heads": [("model",)],
    "d_ff":     [("model",)],
    "d_inner":  [("model",)],
    "vocab":    [("model",)],
    "kv_seq":   [("model",)],       # GQA caches: few kv heads -> shard time
    "seq":      [("data",)],        # SP once batch can't use it (e.g. batch=1)
    "capacity": [("pod", "data"), ("data",)],  # MoE (E,C,d) buffers
    "d_model":  [],                 # replicated by default (see FSDP below)
}

# Param dims eligible for the FSDP (ZeRO-3) extra shard, tried in this order.
_FSDP_AXES = [("data",), ("pod", "data"), ("pod",)]
_FSDP_ELIGIBLE = ("d_model", "d_ff", "d_inner", "vocab", "experts_inner",
                  "heads_flat", "kv_lora", "conv", "dt_rank", "d_state_in")


def _axes_size(mesh_shape: Dict[str, int], axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh_shape[a]
    return n


@dataclass
class ShardingResolver:
    mesh: Mesh
    rules: Rules = field(default_factory=lambda: dict(DEFAULT_RULES))
    fsdp: bool = False              # extra data-axis shard on params

    def _mesh_shape(self) -> Dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    # ------------------------------------------------------------------
    def spec(self, logical: Sequence[Optional[str]],
             shape: Sequence[int], *, param: bool = False) -> P:
        """Resolve one tensor's logical axes to a PartitionSpec."""
        ms = self._mesh_shape()
        n = len(logical)
        assert n == len(shape), (logical, shape)
        assign: List[Optional[Tuple[str, ...]]] = [None] * n
        used: set = set()
        order = sorted(range(n),
                       key=lambda i: _PRIORITY.get(logical[i] or "", 99))
        for i in order:
            name = logical[i]
            if name is None:
                continue
            for cand in self.rules.get(name, []):
                if not cand:
                    break
                if any(a in used or a not in ms for a in cand):
                    continue
                if shape[i] % _axes_size(ms, cand) != 0:
                    continue
                assign[i] = cand
                used.update(cand)
                break
        if param and self.fsdp:
            self._apply_fsdp(logical, shape, assign, used, ms)
        return P(*[a if a is None else (a[0] if len(a) == 1 else a)
                   for a in assign])

    def _apply_fsdp(self, logical, shape, assign, used, ms) -> None:
        # Shard the largest eligible unsharded dim over the data axes.
        cands = [i for i in range(len(shape))
                 if assign[i] is None and (logical[i] in _FSDP_ELIGIBLE
                                           or logical[i] == "d_model")]
        cands.sort(key=lambda i: -shape[i])
        for i in cands:
            for axes in _FSDP_AXES:
                if any(a in used or a not in ms for a in axes):
                    continue
                if shape[i] % _axes_size(ms, axes) != 0:
                    continue
                assign[i] = axes
                used.update(axes)
                return

    # ------------------------------------------------------------------
    def sharding(self, logical, shape, *,
                 param: bool = False) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape, param=param))

    def tree_specs(self, logical_tree, shape_tree, *, param: bool = False):
        """Map ``spec`` over parallel pytrees of logical axes and shapes."""
        return jax.tree.map(
            lambda lg, sh: self.spec(lg, sh, param=param),
            logical_tree, shape_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x),
        )

    def tree_shardings(self, logical_tree, shape_tree, *, param: bool = False):
        specs = self.tree_specs(logical_tree, shape_tree, param=param)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))


def constrain(x, resolver: Optional[ShardingResolver],
              logical: Tuple[Optional[str], ...]):
    """with_sharding_constraint via the resolver (no-op when resolver
    is None)."""
    if resolver is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, resolver.sharding(logical, x.shape))


def shapes_of(tree):
    return jax.tree.map(lambda x: tuple(x.shape), tree)
