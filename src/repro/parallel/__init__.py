from repro.parallel.sharding import (  # noqa: F401
    DEFAULT_RULES,
    ShardingResolver,
    constrain,
    shapes_of,
)
