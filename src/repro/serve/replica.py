"""Model replica: one decode executor behind the serving dispatch engine.

A Replica is the serving analogue of the Engine's DeviceGroup: it owns
one model instance (a mesh sub-slice on a real deployment; a throttled
executor on this single-CPU container) and executes request packets —
batched prefill + greedy decode.  Heterogeneity across replicas (mixed
accelerator generations, degraded hosts) is emulated with ``throttle``
exactly as in core/device.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device import DeviceGroup
from repro.models import transformer as T


class Replica:
    """One model replica with its own decode loop."""

    def __init__(self, name: str, cfg, params, throttle: float = 1.0):
        self.name = name
        self.cfg = cfg
        self.params = params
        self.group = DeviceGroup(name, throttle=throttle)
        self._decode = jax.jit(
            lambda p, t, c, pos: T.decode_step(cfg, p, t, c, pos))

    def serve(self, prompts, gen: int,
              cache_len: int = None) -> np.ndarray:
        """prompts: (B, P) -> generated tokens (B, gen).

        ``cache_len`` pins the KV-cache length independently of ``gen`` so
        degraded (shorter) generations reuse the same compiled executables.
        """
        cfg = self.cfg
        B, P = prompts.shape
        cache, _ = T.init_cache(cfg, B, cache_len or P + gen)
        lg, cache = T.prefill(cfg, self.params, prompts, cache)
        tok = jnp.argmax(lg[:, -1], -1)[:, None]
        out = []
        for i in range(gen):
            out.append(np.asarray(tok))
            lg, cache = self._decode(self.params, tok, cache,
                                     jnp.int32(P + i))
            tok = jnp.argmax(lg[:, -1], -1)[:, None]
        return np.concatenate(out, axis=1)
