"""Deadline-aware co-execution serving subsystem.

Open-loop request workloads (workload.py) dispatched across heterogeneous
model replicas by the paper's scheduler stack (server.py), with a shared
accounting path (stats.py).  The discrete-event twin lives in
core/simulate.py::simulate_serving and reuses the same Request objects,
schedulers and metrics at 1000-replica scale.
"""
from repro.serve.admission import AdmissionConfig, EdfAdmission
from repro.serve.replica import Replica
from repro.serve.server import CoexecServer, ServeOutcome, ServerConfig
from repro.serve.stats import ServeStats, percentile, summarize
from repro.serve.workload import (ARRIVALS, Request, RequestQueue,
                                  TraceWorkload, bursty_arrivals,
                                  make_requests, poisson_arrivals,
                                  record_trace, trace_arrivals)

__all__ = [
    "ARRIVALS", "AdmissionConfig", "CoexecServer", "EdfAdmission",
    "Replica", "Request", "RequestQueue", "ServeOutcome", "ServeStats",
    "ServerConfig", "TraceWorkload", "bursty_arrivals", "make_requests",
    "percentile", "poisson_arrivals", "record_trace", "summarize",
    "trace_arrivals",
]
