"""Open-loop serving workloads: requests, deadlines, arrival processes.

The closed-loop toy loop ("serve one fixed batch, as fast as possible")
hides exactly the effect the paper studies: under *time-constrained*
scenarios the per-offload management overheads and load imbalance turn
into deadline misses.  An open-loop workload decouples arrivals from
completions — requests keep arriving whether or not the system keeps up —
which is how serving systems are actually driven (and how overload
becomes visible as shed/missed requests instead of silently stretched
makespans).

Three arrival processes:

* ``poisson_arrivals``  — memoryless baseline at a given rate.
* ``bursty_arrivals``   — on/off modulated Poisson (mean rate preserved):
  exponential ON phases at ``burst``× the base rate, OFF phases at
  ``off_frac``× — the diurnal-spike shape that stresses admission.
* ``trace_arrivals``    — replay explicit timestamps (production traces).

Plus trace **record/replay** (``record_trace`` / ``TraceWorkload``): any
measured run — threaded server, simulator, fleet router — can be written
to JSONL (arrival, size, deadline, plus the measured finish/shed/replica
accounting) and replayed *bit-identically* as a fresh workload, so "heavy
traffic" comparisons run every policy against the exact same schedule.
"""
from __future__ import annotations

import bisect
import json
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import numpy as np


@dataclass
class Request:
    """One serving request: a unit of open-loop work with a deadline.

    ``size`` is the request's service demand in scheduler work-groups
    (1 for a plain decode request; >1 models long prompts / long
    generations in the simulator).  The dispatch engine fills the
    accounting fields.
    """
    rid: int
    arrival: float                       # seconds since workload start
    deadline: float                      # absolute seconds
    size: int = 1
    prompt: Optional[np.ndarray] = None  # token ids (threaded mode)
    # -- accounting, written by CoexecServer / simulate_serving ------------
    finish: Optional[float] = None
    shed: bool = False
    degraded: bool = False
    gen_alloc: Optional[int] = None      # granted decode tokens (degrade)
    replica: Optional[str] = None

    @property
    def latency(self) -> Optional[float]:
        return None if self.finish is None else self.finish - self.arrival

    @property
    def met_slo(self) -> bool:
        return (not self.shed and self.finish is not None
                and self.finish <= self.deadline)


def poisson_arrivals(n: int, rate: float,
                     rng: np.random.Generator) -> List[float]:
    """n arrival times of a Poisson process at ``rate`` req/s."""
    assert rate > 0
    gaps = rng.exponential(1.0 / rate, size=n)
    return list(np.cumsum(gaps))


def bursty_arrivals(n: int, rate: float, rng: np.random.Generator, *,
                    burst: float = 4.0, off_frac: float = 0.2,
                    mean_phase_s: float = 0.5) -> List[float]:
    """On/off modulated Poisson with time-average rate ≈ ``rate``.

    ON phases run at ``burst * rate``, OFF phases at ``off_frac * rate``;
    phase durations are exponential with mean ``mean_phase_s``, and the
    ON-time fraction is chosen so the long-run average recovers ``rate``.
    """
    assert burst > 1.0 and 0.0 <= off_frac < 1.0
    rate_hi, rate_lo = burst * rate, off_frac * rate
    frac_on = (rate - rate_lo) / (rate_hi - rate_lo)
    out: List[float] = []
    t = 0.0
    on = rng.random() < frac_on
    while len(out) < n:
        # phase length: mean_phase_s split so E[on]/E[cycle] == frac_on
        mean = mean_phase_s * (frac_on if on else (1 - frac_on)) * 2
        dur = rng.exponential(max(mean, 1e-6))
        r = rate_hi if on else rate_lo
        if r > 0:
            tt = t + rng.exponential(1.0 / r)
            while tt < t + dur and len(out) < n:
                out.append(tt)
                tt += rng.exponential(1.0 / r)
        t += dur
        on = not on
    return out[:n]


def trace_arrivals(times: Sequence[float]) -> List[float]:
    """Replay explicit arrival timestamps (must be non-decreasing)."""
    out = [float(t) for t in times]
    if any(b < a for a, b in zip(out, out[1:])):
        raise ValueError("trace arrivals must be non-decreasing")
    return out


ARRIVALS = {
    "poisson": poisson_arrivals,
    "bursty": bursty_arrivals,
}


def make_requests(arrivals: Sequence[float], slo: float, *,
                  size: int = 1,
                  prompt_fn: Optional[Callable[[int], np.ndarray]] = None,
                  ) -> List[Request]:
    """Attach deadlines (arrival + slo) and optional prompts."""
    reqs = []
    for i, a in enumerate(arrivals):
        reqs.append(Request(rid=i, arrival=float(a),
                            deadline=float(a) + slo, size=size,
                            prompt=None if prompt_fn is None
                            else prompt_fn(i)))
    return reqs


# -- trace record / replay ---------------------------------------------------
# One JSONL record per request.  The workload half (rid/arrival/deadline/
# size) is what replay rebuilds; the outcome half (finish/shed/degraded/
# replica) makes the trace a measurement artifact too — "heavy traffic"
# claims point at a file, not a vibe.
TRACE_VERSION = 1


def _trace_record(r: Request) -> dict:
    return {
        "rid": r.rid,
        "arrival": r.arrival,
        "deadline": r.deadline,
        "size": r.size,
        "finish": r.finish,
        "shed": r.shed,
        "degraded": r.degraded,
        "replica": r.replica,
    }


def record_trace(outcome, path: str) -> int:
    """Write a workload run to ``path`` as JSONL; returns records written.

    ``outcome`` is anything carrying the requests: a ``ServeOutcome`` /
    ``FleetSimResult`` (``.requests``) or a plain sequence of Requests.
    Records are written in (arrival, rid) order — the replay order — with
    a leading header line carrying the trace version.
    """
    reqs = getattr(outcome, "requests", outcome)
    reqs = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    with open(path, "w") as f:
        f.write(json.dumps({"trace_version": TRACE_VERSION,
                            "n_requests": len(reqs)}) + "\n")
        for r in reqs:
            f.write(json.dumps(_trace_record(r)) + "\n")
    return len(reqs)


class TraceWorkload:
    """A recorded workload, replayable bit-identically.

    ``requests()`` rebuilds *fresh* Request objects — identical rid /
    arrival / deadline / size schedule, accounting fields cleared — so the
    same trace can be replayed through any router policy or server and
    the outcomes compared on equal footing.  The recorded outcome half is
    kept on ``records`` for analysis (e.g. comparing a replay against the
    measured original).
    """

    def __init__(self, records: Sequence[dict]):
        recs = sorted(records, key=lambda d: (d["arrival"], d["rid"]))
        for a, b in zip(recs, recs[1:]):
            if b["arrival"] < a["arrival"]:
                raise ValueError("trace arrivals must be non-decreasing")
        self.records: List[dict] = [dict(d) for d in recs]

    @classmethod
    def load(cls, path: str) -> "TraceWorkload":
        records = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                if "trace_version" in d:      # header line
                    if d["trace_version"] != TRACE_VERSION:
                        raise ValueError(
                            f"unsupported trace version "
                            f"{d['trace_version']} (have {TRACE_VERSION})")
                    continue
                records.append(d)
        return cls(records)

    @classmethod
    def from_requests(cls, requests: Sequence[Request]) -> "TraceWorkload":
        return cls([_trace_record(r) for r in requests])

    def requests(self, *,
                 prompt_fn: Optional[Callable[[int], np.ndarray]] = None
                 ) -> List[Request]:
        """Fresh Request objects replaying the recorded schedule exactly.

        Prompts are not serialized (token arrays don't belong in a trace
        file); ``prompt_fn(rid)`` reattaches them for threaded replays.
        """
        return [Request(rid=d["rid"], arrival=float(d["arrival"]),
                        deadline=float(d["deadline"]), size=int(d["size"]),
                        prompt=None if prompt_fn is None
                        else prompt_fn(d["rid"]))
                for d in self.records]

    def queue(self, **kw) -> "RequestQueue":
        return RequestQueue(self.requests(**kw))

    def arrivals(self) -> List[float]:
        return [d["arrival"] for d in self.records]

    def __len__(self) -> int:
        return len(self.records)

    def __repr__(self) -> str:
        span = (self.records[-1]["arrival"] - self.records[0]["arrival"]
                if self.records else 0.0)
        return (f"TraceWorkload({len(self.records)} requests over "
                f"{span:.3f}s)")


class RequestQueue:
    """Time-ordered open-loop request source.

    The admission loop polls it with the current clock; requests become
    visible only once their arrival time has passed (open loop: the queue
    never waits for the server).
    """

    def __init__(self, requests: Sequence[Request]):
        self._reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
        self._arrivals = [r.arrival for r in self._reqs]
        self._i = 0

    def poll(self, now: float) -> List[Request]:
        """Requests that have arrived since the last poll."""
        j = bisect.bisect_right(self._arrivals, now)
        out = self._reqs[self._i:j]
        self._i = j
        return out

    def next_arrival(self) -> Optional[float]:
        if self._i >= len(self._reqs):
            return None
        return self._arrivals[self._i]

    def preview(self) -> Optional[Request]:
        """First unreleased request, without consuming it (warmup shapes)."""
        if self._i >= len(self._reqs):
            return None
        return self._reqs[self._i]

    def remaining(self) -> int:
        return len(self._reqs) - self._i

    def __len__(self) -> int:
        return len(self._reqs)
