"""CoexecServer: deadline-aware open-loop serving on the co-execution stack.

Generalizes the old fixed-batch worker loop into a continuous serving
engine.  The request stream is the co-execution work set (1 work-group =
one request); the paper's schedulers are the dispatch engine across
heterogeneous replicas.  Dataflow per *dispatch round*:

    RequestQueue --poll(now)--> admission (EDF order, shed/degrade)
        --> scheduler over the admitted round (HGuided* packets)
        --> replica worker threads pull packets, decode, commit
        --> per-request latency accounting + EWMA power feedback

* **Admission (EDF-within-round)**: pending requests are sorted by
  deadline; each request's completion is predicted from the replicas'
  online EWMA computing powers (the same estimates HGuidedOpt adapts
  with).  A request predicted to miss is *shed* (dropped now, so its
  work cannot drag every later request past its deadline too) or
  *degraded* (granted proportionally fewer decode tokens) per policy.
* **Dispatch**: the admitted round becomes one ``EngineSession`` submit —
  one work-group per request, one Program whose range function serves
  ``lws``-sized sub-batches on the packet's replica.  Any registered
  scheduler works; ``hguided_deadline`` additionally receives the round's
  tightest slack (``slack_s``) so packets shrink as deadlines close in.
* **Feedback**: measured requests/s per replica updates both the live
  scheduler (within-round adaptation) and the server's EWMA powers
  (carried across rounds — the admission predictor and the next round's
  initial profile).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api.policies import OffloadMode
from repro.api.session import EngineSession
from repro.core.device import DeviceGroup
from repro.core.runtime import Program
from repro.core.scheduler import rotate_static_order, scheduler_accepts
from repro.energy.model import ZERO_POWER, PowerModel
from repro.serve.admission import AdmissionConfig, EdfAdmission
from repro.serve.replica import Replica
from repro.serve.stats import ServeStats, summarize
from repro.serve.workload import Request, RequestQueue


@dataclass
class ServerConfig:
    scheduler: str = "hguided_deadline"
    scheduler_kwargs: Dict = field(default_factory=dict)
    lws: int = 1                  # requests per packet alignment unit
    gen: int = 16                 # decode tokens per request
    policy: str = "shed"          # "shed" | "degrade" | "none"
    min_gen: int = 1              # floor for degraded requests
    ewma: float = 0.5             # cross-round power smoothing
    poll_interval_s: float = 2e-3
    batch_window_s: float = 0.0   # micro-batching: wait for round to fill
    round_quantum_s: float = float("inf")  # max EDF-first work per round
    warmup: bool = True           # pre-compile before starting the clock
    # scheduler hand-off for dispatch rounds: "leased" (lock-amortized
    # packet plans; with scheduler="hguided_steal" idle replicas also
    # steal from the largest victim lease) or "per_packet" (baseline)
    dispatch: str = "leased"
    # per-replica power models (name -> PowerModel) for joule accounting;
    # unlisted replicas stay joule-blind (ZERO_POWER), so the default is
    # a behavior- and stats-identical server with energy_j == 0
    power_models: Dict[str, PowerModel] = field(default_factory=dict)


def _no_collect(pkt, res, dev) -> None:
    """Round programs commit per-request state in their range function."""


@dataclass
class ServeOutcome:
    stats: ServeStats
    requests: List[Request]
    results: Dict[int, np.ndarray]        # rid -> generated tokens


class CoexecServer:
    """Continuous admission + co-execution dispatch over model replicas."""

    def __init__(self, replicas: Sequence[Replica], cfg: ServerConfig, *,
                 initial_power: Optional[Dict[str, float]] = None):
        assert cfg.policy in ("shed", "degrade", "none")
        self.replicas = list(replicas)
        self.cfg = cfg
        # requests/s per replica.  Admission needs an absolute scale: until
        # one round has been observed, predictions are uncalibrated and
        # admission lets everything through (unless the caller provides
        # measured powers up front).
        self._power: Dict[str, float] = dict(initial_power or {})
        self._calibrated = initial_power is not None
        self._round = 0
        self._lock = threading.Lock()
        # one dispatch group per replica.  Heterogeneity is emulated inside
        # the round program (replica.group.throttle scales each sub-batch),
        # so the dispatch groups themselves are unthrottled — the session
        # must not throttle a second time.
        self._by_name = {r.name: r for r in self.replicas}
        # admission is a shared policy object (serve/admission.py): the
        # same EDF + shed/degrade procedure the fleet router runs one rung
        # up.  unit_work: the threaded server prices every request at one
        # work-group, matching the requests/s scale of its EWMA powers.
        self.admission = EdfAdmission(AdmissionConfig(
            policy=cfg.policy, gen=cfg.gen, min_gen=cfg.min_gen,
            round_quantum_s=cfg.round_quantum_s, unit_work=True))
        self.session = EngineSession(
            [DeviceGroup(r.name,
                         power_model=cfg.power_models.get(r.name,
                                                          ZERO_POWER))
             for r in self.replicas],
            scheduler=cfg.scheduler, dispatch=cfg.dispatch,
            name="coexec_server")
        self._energy_j = 0.0          # joules across all dispatch rounds

    # -- admission -----------------------------------------------------------
    def _admit(self, pending: List[Request], now: float,
               completed: List[Request]
               ) -> Tuple[List[Request], List[Request]]:
        """EDF-order ``pending``; shed/degrade predicted misses in place.

        Thin wrapper over the shared :class:`EdfAdmission` policy object
        (serve/admission.py — also the fleet router's admitter).  Returns
        (admitted round, leftover beyond the round quantum) — the leftover
        stays queued so EDF re-sorting / re-prediction happens every
        quantum instead of once per backlog (iteration-level scheduling).
        """
        return self.admission.admit(
            pending, now,
            total_power=sum(self._power.values()),
            calibrated=self._calibrated,
            completed=completed)

    # -- dispatch ------------------------------------------------------------
    def _run_round(self, admitted: List[Request], now: float, t0: float,
                   results: Dict[int, np.ndarray],
                   dispatch: Dict[str, int]) -> None:
        cfg = self.cfg
        powers = [self._power.get(r.name, 1.0 / r.group.throttle)
                  for r in self.replicas]
        skw = dict(cfg.scheduler_kwargs)
        order = rotate_static_order(cfg.scheduler, len(self.replicas),
                                    self._round)
        if order is not None:
            skw.setdefault("order", order)
        if scheduler_accepts(cfg.scheduler, "slack_s"):
            skw["slack_s"] = min(r.deadline for r in admitted) - now
        self._round += 1

        def build(group: DeviceGroup):
            rep = self._by_name[group.name]

            def fn(offset: int, size: int):
                # execute in lws-sized sub-batches: fixed batch shapes keep
                # XLA from recompiling per packet size, and give finer
                # per-request completion times
                for c0 in range(0, size, cfg.lws):
                    sub = admitted[offset + c0:
                                   offset + min(c0 + cfg.lws, size)]
                    gen_eff = min(r.gen_alloc for r in sub)
                    # pad to exactly lws rows and pin the cache length:
                    # one compiled (prefill, decode) pair serves every
                    # packet, whatever the round or degrade policy carved
                    rows = [r.prompt for r in sub]
                    rows += [rows[-1]] * (cfg.lws - len(rows))
                    prompts = np.stack(rows)
                    cache_len = prompts.shape[1] + cfg.gen
                    t_pkt = time.perf_counter()
                    toks = rep.serve(prompts, gen_eff, cache_len)
                    dt = time.perf_counter() - t_pkt
                    if rep.group.throttle > 1:    # emulated heterogeneity
                        time.sleep(dt * (rep.group.throttle - 1))
                        dt *= rep.group.throttle
                    fin = time.perf_counter() - t0
                    rps = len(sub) / max(dt, 1e-9)
                    with self._lock:
                        for j, r in enumerate(sub):
                            r.finish = fin
                            r.replica = rep.name
                            r.degraded = r.degraded or gen_eff < cfg.gen
                            results[r.rid] = toks[j]
                        dispatch[rep.name] = (dispatch.get(rep.name, 0)
                                              + len(sub))
                        prev = self._power.get(rep.name)
                        self._power[rep.name] = rps if prev is None else (
                            cfg.ewma * rps + (1 - cfg.ewma) * prev)
            return fn

        # one work-group per admitted request; results are committed by the
        # range function itself, so collect is a no-op sink.  Rounds are
        # BINARY offloads: each is self-contained (fresh build, teardown
        # after) — a round program never recurs, so nothing must survive it
        prog = Program(f"round{self._round}", len(admitted), cfg.lws, build)
        res = self.session.submit(prog, powers=powers, scheduler=cfg.scheduler,
                                  scheduler_kwargs=skw, collect=_no_collect,
                                  mode=OffloadMode.BINARY).result()
        self._energy_j += getattr(res, "energy_j", 0.0)
        self._calibrated = True

    # -- main entry ----------------------------------------------------------
    def _warmup(self, queue: RequestQueue) -> None:
        """Compile prefill + decode for the serving batch shape on every
        replica BEFORE the clock starts — cold-start compile time must not
        poison the EWMA powers the admission predictor relies on."""
        first = queue.preview()
        if first is None or first.prompt is None:
            return
        prompts = np.stack([first.prompt] * self.cfg.lws)
        cache_len = prompts.shape[1] + self.cfg.gen
        for rep in self.replicas:
            rep.serve(prompts, 1, cache_len)

    def run(self, queue: RequestQueue) -> ServeOutcome:
        """Serve the whole queue open-loop; returns stats + outputs."""
        if self.cfg.warmup:
            self._warmup(queue)
        t0 = time.perf_counter()
        completed: List[Request] = []
        results: Dict[int, np.ndarray] = {}
        dispatch: Dict[str, int] = {r.name: 0 for r in self.replicas}
        pending: List[Request] = []
        while True:
            now = time.perf_counter() - t0
            pending.extend(queue.poll(now))
            if not pending:
                nxt = queue.next_arrival()
                if nxt is None:
                    break
                # the queue is fixed at run() time: nothing can arrive
                # before nxt, so sleep straight through to it
                time.sleep(max(nxt - now, 0.0) + 1e-4)
                continue
            # micro-batching: hold a young round open while more requests
            # are still inbound, so the scheduler has work to split
            oldest = min(r.arrival for r in pending)
            if (self.cfg.batch_window_s > 0
                    and queue.next_arrival() is not None
                    and now - oldest < self.cfg.batch_window_s):
                time.sleep(self.cfg.poll_interval_s)
                continue
            admitted, pending = self._admit(pending, now, completed)
            if not admitted:
                continue
            self._run_round(admitted, now, t0, results, dispatch)
            completed.extend(admitted)
        stats = summarize(completed, duration=time.perf_counter() - t0,
                          dispatch=dispatch, energy_j=self._energy_j)
        return ServeOutcome(stats=stats, requests=completed, results=results)

    def close(self) -> None:
        """Release the dispatch session (a server can serve many queues;
        close when done)."""
        self.session.close()
