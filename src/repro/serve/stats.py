"""Serving metrics: latency percentiles, SLO attainment, goodput.

One accounting path shared by the threaded CoexecServer and the
discrete-event simulator (core/simulate.simulate_serving): both fill the
same ``Request`` fields, both are summarized here.

* p50/p99 latency — over *served* requests only (shed requests have no
  latency; they show up in attainment and shed_frac instead).
* SLO attainment — fraction of ALL offered requests that finished by
  their deadline.  Shedding a request can never raise attainment; it can
  only protect the attainment of the others.
* goodput — work-groups of on-time service delivered per second; late
  and shed work counts for nothing (the paper's time-constrained lens).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

from repro.serve.workload import Request


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile, q in [0, 100]."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    pos = (q / 100.0) * (len(s) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    frac = pos - lo
    return s[lo] * (1 - frac) + s[hi] * frac


@dataclass
class ServeStats:
    n_requests: int
    served: int                      # finished (on time or late)
    shed: int                        # dropped by admission control
    missed: int                      # finished but past deadline
    degraded: int                    # served with reduced generation
    p50_latency: float
    p99_latency: float
    mean_latency: float
    slo_attainment: float            # on-time / offered
    goodput_wg_s: float              # on-time work-groups per second
    throughput_wg_s: float           # all served work-groups per second
    duration: float
    dispatch: Dict[str, int] = field(default_factory=dict)
    # joule accounting (repro.energy): total energy the serving window
    # burned; 0.0 for joule-blind power models or engines that predate
    # the energy subsystem
    energy_j: float = 0.0

    @property
    def j_per_request(self) -> float:
        """Energy per served request (0.0 when nothing was served or the
        fleet is joule-blind)."""
        return self.energy_j / self.served if self.served else 0.0

    def row(self) -> str:
        row = (f"p50={self.p50_latency:.3f}s p99={self.p99_latency:.3f}s "
               f"slo={self.slo_attainment:.3f} "
               f"goodput={self.goodput_wg_s:.1f}wg/s "
               f"shed={self.shed}/{self.n_requests} missed={self.missed}")
        if self.energy_j > 0:
            row += (f" energy={self.energy_j:.1f}J "
                    f"({self.j_per_request:.2f}J/req)")
        return row


def summarize(requests: Sequence[Request], *,
              duration: Optional[float] = None,
              dispatch: Optional[Dict[str, int]] = None,
              energy_j: float = 0.0) -> ServeStats:
    n = len(requests)
    served = [r for r in requests if not r.shed and r.finish is not None]
    lats = [r.latency for r in served]
    on_time = [r for r in served if r.met_slo]
    if duration is None:
        fins = [r.finish for r in served]
        t0 = min((r.arrival for r in requests), default=0.0)
        duration = (max(fins) - t0) if fins else 0.0
    dur = max(duration, 1e-12)
    return ServeStats(
        n_requests=n,
        served=len(served),
        shed=sum(1 for r in requests if r.shed),
        missed=len(served) - len(on_time),
        degraded=sum(1 for r in served if r.degraded),
        p50_latency=percentile(lats, 50),
        p99_latency=percentile(lats, 99),
        mean_latency=sum(lats) / len(lats) if lats else float("nan"),
        slo_attainment=len(on_time) / n if n else 0.0,
        goodput_wg_s=sum(r.size for r in on_time) / dur,
        throughput_wg_s=sum(r.size for r in served) / dur,
        duration=duration,
        dispatch=dict(dispatch or {}),
        energy_j=energy_j,
    )
