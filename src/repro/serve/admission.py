"""EDF admission with shed/degrade: one policy object, every admitter.

Extracted verbatim from ``CoexecServer._admit`` so the *same* decision
procedure runs at every level of the stack:

* the replica server (``CoexecServer``) admits its local dispatch round
  with it (``unit_work=True`` — the threaded server prices every request
  at one work-group, matching the requests/s scale of its EWMA powers);
* the fleet router (``repro.fleet.FleetRouter``) admits against the
  *aggregate* fleet capacity and residual before placement — shedding is
  decided at the router, not the replica;
* the discrete-event serving simulator accepts one as an injection hook
  (``simulate_serving(..., admission=...)``) so fleet co-simulation and
  the threaded paths cannot drift apart.

The procedure (EDF-within-round):

1. sort pending by (deadline, rid) — earliest deadline first;
2. cap the round at ~one *round quantum* of fleet work (iteration-level
   scheduling: the leftover stays queued so re-sorting / re-prediction
   happens every quantum, not once per backlog);
3. predict each request's completion from the aggregate power estimate
   (plus any residual in-flight work) and shed — or degrade, granting
   proportionally fewer decode tokens — requests predicted to miss, so
   doomed work cannot drag every later request past its deadline too.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple


@dataclass
class AdmissionConfig:
    policy: str = "shed"             # "shed" | "degrade" | "none"
    gen: int = 16                    # full decode-token grant per request
    min_gen: int = 1                 # floor for degraded requests
    round_quantum_s: float = math.inf  # max EDF-first work per round
    # True: every request is one unit of work regardless of Request.size
    # (the threaded server's requests/s accounting); False: use .size
    # (the simulator's / router's work-group accounting)
    unit_work: bool = False

    def __post_init__(self):
        if self.policy not in ("shed", "degrade", "none"):
            raise ValueError(f"admission policy must be 'shed', 'degrade' "
                             f"or 'none', got {self.policy!r}")


class EdfAdmission:
    """Reusable EDF admission + shed/degrade decision procedure.

    Stateless between calls: everything it needs arrives as arguments, so
    one instance can serve any number of rounds, servers or routers.
    """

    def __init__(self, cfg: Optional[AdmissionConfig] = None, **kw):
        self.cfg = cfg if cfg is not None else AdmissionConfig(**kw)

    def admit(self, pending: List, now: float, *,
              total_power: float,
              residual_wg: float = 0.0,
              calibrated: bool = True,
              completed: Optional[List] = None
              ) -> Tuple[List, List]:
        """EDF-order ``pending``; shed/degrade predicted misses in place.

        Returns ``(admitted, leftover)`` — the leftover (beyond the round
        quantum) stays queued for the next round.  ``total_power`` is the
        admitting scope's aggregate capacity (a replica's EWMA powers, or
        the fleet's); ``residual_wg`` is in-flight work already committed
        ahead of this round (the router's outstanding-work estimate —
        without it the predictor only sees THIS round's queue and admits
        doomed requests under backlog).  ``calibrated=False`` disables
        prediction entirely (everything admits) until at least one round
        of measured powers exists.  Shed requests are flagged in place;
        when ``completed`` is given they are also moved there with
        ``finish=None`` (the threaded server's bookkeeping).
        """
        cfg = self.cfg
        pending.sort(key=lambda r: (r.deadline, r.rid))
        for r in pending:
            r.gen_alloc = cfg.gen
        do_filter = calibrated and cfg.policy != "none"
        cap = (total_power * cfg.round_quantum_s if total_power > 0
               else math.inf)
        admitted: List = []
        leftover: List = []
        cum = 0.0
        for r in pending:
            w = 1.0 if cfg.unit_work else float(r.size)
            if admitted and cum + w > cap:
                leftover.append(r)
                continue
            cum += w
            if not do_filter or total_power <= 0:
                admitted.append(r)
                continue
            pred_finish = now + (residual_wg + cum) / total_power
            if pred_finish <= r.deadline:
                admitted.append(r)
                continue
            if cfg.policy == "degrade":
                # degrade never drops: scale the generation budget to the
                # remaining slack, down to min_gen for already-late work
                slack = r.deadline - now
                frac = (slack / (pred_finish - now)
                        if slack > 0 else 0.0)
                r.gen_alloc = max(cfg.min_gen, int(cfg.gen * frac))
                r.degraded = r.gen_alloc < cfg.gen
                admitted.append(r)
            else:
                r.shed = True
                if completed is not None:
                    r.finish = None
                    completed.append(r)
                cum -= w                # shed work frees the queue behind it
        return admitted, leftover

    def __repr__(self) -> str:
        return f"EdfAdmission({self.cfg!r})"


def sequence_total(requests: Sequence, unit_work: bool) -> float:
    """Total admission-scale work of ``requests`` under a work model."""
    if unit_work:
        return float(len(requests))
    return float(sum(r.size for r in requests))
