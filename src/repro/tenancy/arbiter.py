"""The fleet arbiter: fair-share device grants for concurrent sessions.

One :class:`FleetArbiter` owns the devices, the :class:`WorkerPool`, and
the shared :class:`BufferArena`.  Tenant sessions register with a
:class:`TenantConfig` (weight, priority, exclusive) and from then on
every device-loop packet pull asks the arbiter for permission first:

``begin_packet(device)`` -- granted only if the tenant wins the current
election AND the device's previous holder has no packet in flight there
(grants flip **only at packet boundaries**, never mid-packet, so every
tenant's runs keep the solo-session exact-cover/phase/energy
identities).  A denied session reclaims its scheduler lease
(``SchedulerBase.reclaim_lease``) and re-polls; the reclaimed packets go
back to the retry pool and are re-pulled when the grant returns.

The election is weighted virtual time (stride scheduling): finishing a
packet of ``wg`` work-groups advances the tenant's virtual time by
``wg / weight``, and the fleet is granted to the active tenant with the
lowest virtual time -- so long-run work shares converge to the quota
weights.  Higher ``priority`` classes win outright while they have
demand.  A tenant (re)activating after idling has its virtual time
caught up to the active minimum, so sleepers cannot hoard credit.

``exclusive=True`` tenants fence the fleet: ``begin_run`` queues on a
FIFO fence, the election starves co-tenants' new grants, and the run
starts only once every other tenant has zero packets in flight anywhere
-- bounded takeover latency of one packet per device.  Per-packet
``(tenant, device, t0, t1)`` windows are recorded so isolation is
*verifiable*, not assumed (:func:`exclusive_overlaps`).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, NamedTuple, Optional, Sequence

from repro.core.membuf import ArenaPartition, BufferArena
from repro.core.runtime import WorkerPool
from repro.core.scheduler import SchedStats

__all__ = [
    "FleetArbiter",
    "PacketWindow",
    "TenantConfig",
    "TenantHandle",
    "exclusive_overlaps",
    "fair_share_index",
]


@dataclass(frozen=True)
class TenantConfig:
    """Static identity + policy of one tenant.

    ``weight`` is the fair-share quota weight (work shares converge to
    ``weight / sum(weights of active tenants)``); ``priority`` classes
    are strict (higher always wins while it has demand); ``exclusive``
    tenants fence the whole fleet for each run.  ``arena_cap_bytes``
    optionally bounds the tenant's free bytes in the shared arena.
    """

    name: str
    weight: float = 1.0
    priority: int = 0
    exclusive: bool = False
    arena_cap_bytes: Optional[int] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if "::" in self.name:
            raise ValueError("tenant name must not contain '::'")
        if not (self.weight > 0):
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")


class PacketWindow(NamedTuple):
    """One executed packet's wall-clock occupancy of one device."""

    tenant: str
    device: int
    t0: float
    t1: float
    wg: int


class TenantHandle:
    """A registered tenant's live state (owned by the arbiter's lock).

    Sessions hold one of these; the runtime calls ``begin_packet`` /
    ``end_packet`` around every device pull and ``begin_run`` /
    ``end_run`` around every run.  All mutation happens under the
    arbiter's condition variable.
    """

    def __init__(self, arbiter: "FleetArbiter", config: TenantConfig,
                 demand: Optional[Callable[[], bool]],
                 partition: ArenaPartition):
        self.arbiter = arbiter
        self.config = config
        self.arena = partition
        self._demand = demand
        self.usage_wg = 0          # total work-groups executed
        self.vt = 0.0              # virtual time (wg / weight)
        self.inflight: Dict[int, int] = {}   # device -> packets in flight
        self.active_runs = 0
        self.runs = 0
        self.denials = 0           # begin_packet refusals (observability)
        self.sched_stats = SchedStats()      # per-tenant rollup across runs
        self.closed = False

    @property
    def name(self) -> str:
        return self.config.name

    def has_demand(self) -> bool:
        if self._demand is None:
            return self.active_runs > 0
        try:
            return bool(self._demand())
        except Exception:
            return False

    def inflight_total(self) -> int:
        return sum(self.inflight.values())

    # -- runtime hooks (delegate to the arbiter) ----------------------------
    def begin_packet(self, device: int) -> bool:
        return self.arbiter._begin_packet(self, device)

    def end_packet(self, device: int, wg: int, t0: float) -> None:
        self.arbiter._end_packet(self, device, wg, t0)

    def begin_run(self) -> None:
        self.arbiter._begin_run(self)

    def end_run(self) -> None:
        self.arbiter._end_run(self)

    def merge_stats(self, stats: SchedStats) -> None:
        with self.arbiter._cv:
            self.sched_stats.merge(stats)

    def __repr__(self) -> str:
        return (f"TenantHandle({self.name!r}, w={self.config.weight}, "
                f"prio={self.config.priority}, usage={self.usage_wg}wg, "
                f"vt={self.vt:.1f})")


class FleetArbiter:
    """Owns the devices, pool, and arena; grants devices to tenants.

    See the module docstring for the grant/election/fence semantics.
    ``record_windows=True`` keeps up to ``max_windows`` per-packet device
    windows for isolation audits (benchmarks/tests); disable it for
    long-lived services.
    """

    def __init__(self, devices: Sequence, *, name: str = "fleet",
                 arena_capacity_bytes: int = 256 << 20, arena_ring: int = 2,
                 record_windows: bool = True, max_windows: int = 200_000):
        self.devices = list(devices)
        if not self.devices:
            raise ValueError("FleetArbiter needs at least one device")
        self.name = name
        self.pool = WorkerPool(name=f"{name}-pool")
        self.arena = BufferArena(capacity_bytes=arena_capacity_bytes,
                                 ring=arena_ring, name=f"{name}-arena")
        self._cv = threading.Condition()
        self._tenants: Dict[str, TenantHandle] = {}
        self._grant: Dict[int, Optional[TenantHandle]] = {}
        self._fence: Deque[TenantHandle] = deque()
        self._exclusive: Optional[TenantHandle] = None
        self._windows: List[PacketWindow] = []
        self._history: Dict[str, Dict] = {}  # departed tenants' final rows
        self._record_windows = bool(record_windows)
        self._max_windows = int(max_windows)
        self._closed = False
        self.grants = 0        # grant flips between tenants
        self.preemptions = 0   # flips that took the device from a tenant
        #   that still had demand (i.e. true preemptions, not handoffs)

    # -- tenant lifecycle ---------------------------------------------------
    def register(self, config: TenantConfig,
                 demand: Optional[Callable[[], bool]] = None) -> TenantHandle:
        """Admit a tenant.  ``demand`` is polled during elections; it
        should be cheap and lock-light (the session passes its graph's
        ``remaining() > 0``).  The newcomer's virtual time joins at the
        current minimum so it neither starves nor monopolizes."""
        with self._cv:
            if self._closed:
                raise RuntimeError(f"arbiter {self.name!r} is closed")
            if config.name in self._tenants:
                raise ValueError(f"tenant {config.name!r} already registered")
            partition = ArenaPartition(self.arena, config.name,
                                       cap_bytes=config.arena_cap_bytes)
            handle = TenantHandle(self, config, demand, partition)
            vts = [h.vt for h in self._tenants.values() if not h.closed]
            if vts:
                handle.vt = min(vts)
            self._tenants[config.name] = handle
            return handle

    def unregister(self, handle: TenantHandle) -> None:
        """Retire a tenant: drop its grants, fence slot, and arena keys.
        Idempotent; the session calls this from ``close()``."""
        with self._cv:
            handle.closed = True
            self._tenants.pop(handle.name, None)
            self._history[handle.name] = self._row_locked(handle)
            for dev, holder in list(self._grant.items()):
                if holder is handle:
                    self._grant[dev] = None
            try:
                self._fence.remove(handle)
            except ValueError:
                pass
            if self._exclusive is handle:
                self._exclusive = None
            self._cv.notify_all()
        handle.arena.close()

    def close(self) -> None:
        """Shut the fleet down.  Close tenant sessions first; any still
        registered are force-unregistered."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            stale = list(self._tenants.values())
        for h in stale:
            self.unregister(h)
        self.arena.close()
        self.pool.close()

    # -- election -----------------------------------------------------------
    def _elect_locked(self, asking: TenantHandle) -> TenantHandle:
        """Who should the fleet serve right now?  Exclusive holder first,
        then the fence head (starve co-tenants so the fence can drain),
        then the highest priority class with demand, lowest virtual time
        within it.  With no demand anywhere, the asking tenant wins --
        drain-tail probes must never stall."""
        if self._exclusive is not None:
            return self._exclusive
        if self._fence:
            return self._fence[0]
        cands = [h for h in self._tenants.values()
                 if not h.closed and h.active_runs > 0 and h.has_demand()]
        if not cands:
            return asking
        top = max(h.config.priority for h in cands)
        cands = [h for h in cands if h.config.priority == top]
        return min(cands, key=lambda h: (h.vt, h.name))

    def _begin_packet(self, handle: TenantHandle, device: int) -> bool:
        """Permission to pull one packet on ``device``.  False means:
        reclaim your lease and re-poll -- either you lost the election or
        the previous holder still has a packet mid-flight there."""
        with self._cv:
            if handle.closed or self._closed:
                return False
            winner = self._elect_locked(handle)
            if winner is not handle:
                handle.denials += 1
                return False
            holder = self._grant.get(device)
            if (holder is not None and holder is not handle
                    and holder.inflight.get(device, 0) > 0):
                handle.denials += 1
                return False  # packet boundary not reached yet
            if holder is not handle:
                self._grant[device] = handle
                self.grants += 1
                if holder is not None and not holder.closed \
                        and holder.has_demand():
                    self.preemptions += 1
            handle.inflight[device] = handle.inflight.get(device, 0) + 1
            return True

    def _end_packet(self, handle: TenantHandle, device: int, wg: int,
                    t0: float) -> None:
        """Packet done (or the pull came up empty: ``wg == 0``).  Accrues
        usage/virtual time, records the device window, and wakes fence
        waiters when the tenant goes idle on this device."""
        with self._cv:
            n = handle.inflight.get(device, 0) - 1
            handle.inflight[device] = max(0, n)
            if wg > 0:
                handle.usage_wg += wg
                handle.vt += wg / handle.config.weight
                if (self._record_windows
                        and len(self._windows) < self._max_windows):
                    self._windows.append(PacketWindow(
                        handle.name, device, t0, time.perf_counter(), wg))
            if handle.inflight[device] <= 0:
                self._cv.notify_all()

    # -- run lifecycle ------------------------------------------------------
    def _others_idle_locked(self, handle: TenantHandle) -> bool:
        return all(h is handle or h.inflight_total() == 0
                   for h in self._tenants.values())

    def _begin_run(self, handle: TenantHandle) -> None:
        with self._cv:
            if handle.config.exclusive and self._exclusive is not handle:
                self._fence.append(handle)
                while not (self._fence and self._fence[0] is handle
                           and self._others_idle_locked(handle)):
                    if handle.closed or self._closed:
                        try:
                            self._fence.remove(handle)
                        except ValueError:
                            pass
                        raise RuntimeError(
                            f"tenant {handle.name!r} closed at the fence")
                    self._cv.wait()
                self._fence.popleft()
                self._exclusive = handle
            if handle.active_runs == 0:
                others = [h.vt for h in self._tenants.values()
                          if h is not handle and not h.closed
                          and h.active_runs > 0]
                if others:
                    handle.vt = max(handle.vt, min(others))
            handle.active_runs += 1
            handle.runs += 1

    def _end_run(self, handle: TenantHandle) -> None:
        with self._cv:
            handle.active_runs -= 1
            if handle.active_runs == 0 and self._exclusive is handle:
                self._exclusive = None
            self._cv.notify_all()

    # -- observability ------------------------------------------------------
    def windows(self) -> List[PacketWindow]:
        with self._cv:
            return list(self._windows)

    def _row_locked(self, h: TenantHandle) -> Dict:
        return {
            "weight": h.config.weight,
            "priority": h.config.priority,
            "exclusive": h.config.exclusive,
            "usage_wg": h.usage_wg,
            "vt": h.vt,
            "runs": h.runs,
            "denials": h.denials,
            "sched": dataclasses.asdict(h.sched_stats),
        }

    def tenant_stats(self, include_departed: bool = False) -> Dict[str, Dict]:
        """Per-tenant accounting snapshot: usage, share vs quota, and the
        scheduler-stats rollup.  ``share``/``quota`` are normalized over
        the returned tenants.  ``include_departed=True`` adds the final
        rows of unregistered tenants (a re-registered name's live row
        wins), so post-hoc fairness audits survive session close."""
        with self._cv:
            out = {h.name: self._row_locked(h)
                   for h in self._tenants.values()}
            if include_departed:
                for name, row in self._history.items():
                    out.setdefault(name, dict(row))
            total_wg = sum(r["usage_wg"] for r in out.values())
            total_w = sum(r["weight"] for r in out.values())
            for r in out.values():
                r["share"] = r["usage_wg"] / total_wg if total_wg else 0.0
                r["quota"] = r["weight"] / total_w if total_w else 0.0
            return out

    def __repr__(self) -> str:
        with self._cv:
            return (f"FleetArbiter({self.name!r}, devices={len(self.devices)},"
                    f" tenants={sorted(self._tenants)}, grants={self.grants},"
                    f" preemptions={self.preemptions})")


# --------------------------------------------------------------------------
# Audit helpers
# --------------------------------------------------------------------------


def exclusive_overlaps(windows: Sequence[PacketWindow],
                       tenant: str) -> int:
    """Number of per-device packet windows of ``tenant`` that overlap in
    wall-clock time with any co-tenant's window on the same device.  Zero
    is the exclusive-mode isolation guarantee."""
    n = 0
    by_dev: Dict[int, List[PacketWindow]] = {}
    for w in windows:
        by_dev.setdefault(w.device, []).append(w)
    for ws in by_dev.values():
        mine = [w for w in ws if w.tenant == tenant]
        theirs = [w for w in ws if w.tenant != tenant]
        for a in mine:
            for b in theirs:
                if a.t0 < b.t1 and b.t0 < a.t1:
                    n += 1
    return n


def fair_share_index(stats: Dict[str, Dict]) -> float:
    """min over tenants of ``1 - |share/quota - 1|`` (clamped at 0):
    1.0 is perfect weighted fairness, 0.9 means the worst tenant's share
    is within +-10% of its quota.  Tenants with zero quota are skipped."""
    idx = 1.0
    for s in stats.values():
        if s["quota"] <= 0:
            continue
        idx = min(idx, 1.0 - abs(s["share"] / s["quota"] - 1.0))
    return max(0.0, idx)
