"""Multi-tenant fleet arbitration: N concurrent sessions on one fleet.

The paper's co-execution runtime assumes one host program owns every
device; production traffic is many clients.  This package is the
coordination layer that removes that assumption: a :class:`FleetArbiter`
owns the WorkerPool + BufferArena and grants devices to tenant sessions
through fair-share credits (weighted virtual time), priority admission,
and an exclusive mode that fences the whole fleet.  Preemption happens
only at packet-lease boundaries, so every per-tenant run keeps the
exact-cover, phase, and energy identities of a solo session.
"""
from repro.tenancy.arbiter import (
    FleetArbiter,
    PacketWindow,
    TenantConfig,
    TenantHandle,
    exclusive_overlaps,
    fair_share_index,
)

__all__ = [
    "FleetArbiter",
    "PacketWindow",
    "TenantConfig",
    "TenantHandle",
    "exclusive_overlaps",
    "fair_share_index",
]
