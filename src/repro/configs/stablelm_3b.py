"""stablelm-3b [dense] — 32L d_model=2560 32H (kv=32, i.e. MHA) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b family; unverified]"""
from repro.configs.base import ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG, n_kv_heads=4)
