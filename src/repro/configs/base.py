"""Configuration system for the repro framework.

Two kinds of configs:
  * ``ModelConfig`` — architecture definition (one per assigned arch in
    ``repro.configs.<id>``). A single unified decoder stack covers the dense /
    MoE / hybrid / SSM / VLM / audio families via the per-layer pattern fields.
  * ``ShapeConfig`` — the assigned input-shape cells (train_4k, prefill_32k,
    decode_32k, long_500k).

Every arch module exposes ``CONFIG`` (full size, dry-run only) and ``smoke()``
(reduced same-family config that runs a real step on CPU).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int = 0              # routed experts (0 = no MoE anywhere)
    n_shared: int = 0              # always-on shared experts (DeepSeek style)
    top_k: int = 1
    d_ff: int = 0                  # per-expert hidden dim (0 -> model d_ff)
    every: int = 1                 # MoE layer every `every` layers (jamba: 2)
    first_dense: int = 0           # leading dense layers (deepseek: 1)
    capacity_factor: float = 1.25  # token-dropping capacity factor
    router_jitter: float = 0.0
    # dispatch formulation: "grouped" keeps the scatter/gather local to each
    # batch row (GSPMD-friendly: the expert redistribution lowers to an
    # all-to-all); "global" is the naive whole-batch scatter that GSPMD can
    # only partition by full rematerialization (kept for the §Perf ablation)
    dispatch: str = "grouped"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 256
    qk_norm: bool = False
    attn_kind: str = "gqa"         # gqa | mla | none
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    # hybrid (jamba): attention mixer every `attn_every` layers (at offset
    # `attn_offset` within each period); all other mixers are Mamba blocks.
    attn_every: int = 0            # 0 -> attention everywhere (none if ssm)
    attn_offset: int = 0
    # modality frontend ("" | "vit_stub" | "encodec_stub")
    frontend: str = ""
    n_codebooks: int = 1           # audio: EnCodec codebooks, emb summed
    n_patches: int = 256           # vlm: stub image patch embs per sample
    # numerics / memory policy
    dtype: str = "bfloat16"        # activation/param dtype for full configs
    # dtype of the materialized attention score/prob buffers in the blocked
    # softmax (running max/denominator stay f32).  Kept f32 by default: the
    # bf16 variant was REFUTED by measurement (§Perf qwen3 iteration A —
    # extra converts break producer-consumer fusion and add traffic).
    score_dtype: str = "float32"
    remat_policy: str = "nothing"  # nothing | dots | everything(=no remat)
    # two-level (sqrt-L) remat: the layer stack runs as scan(groups) x
    # scan(blocks) with the OUTER body checkpointed, so only group-boundary
    # activations are saved.  0 = auto (largest divisor <= sqrt(n_blocks));
    # 1 = flat single-level scan (the §Perf ablation baseline).
    remat_groups: int = 0
    # whether blocks inside a group are ALSO checkpointed ("full": 3rd
    # forward pass per block during its segment's backward, minimal memory)
    # or not ("none": 2 passes, transient segment internals in memory)
    remat_inner: str = "full"
    attn_chunk: int = 2048         # kv-block size for chunked attention
    scan_chunk: int = 128          # mamba chunked-scan inner length
    use_pallas: bool = False       # TPU target: Pallas kernels for attn / scan
    # decode runs the block stack UNROLLED with per-block (unstacked) caches:
    # donation then aliases every cache in place, removing the scan-carry
    # double-buffer copies that dominate decode traffic (§Perf jamba
    # long_500k iteration).  Scan is kept for train/prefill (compile size).
    decode_unroll: bool = True
    # per-arch grad-accumulation override for train cells (0 = shape default);
    # activation-heavy archs (jamba's mamba scan buffers) need more.
    accum_override: int = 0
    # serve cells: also spread parameters over the data axis (2D weight
    # sharding).  Required when params_bf16 / model_axis exceeds HBM
    # (dbrx-132b: 16.5 GiB resident under TP-16 alone).
    serve_2d_weights: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm.dt_rank or -(-self.d_model // 16)

    @property
    def moe_d_ff(self) -> int:
        return self.moe.d_ff or self.d_ff

    def mixer_kind(self, layer_idx: int) -> str:
        """'attn' | 'mamba' for layer `layer_idx`."""
        if self.attn_kind == "none":
            return "mamba"
        if self.attn_every <= 1:
            return "attn"
        return ("attn" if layer_idx % self.attn_every == self.attn_offset
                else "mamba")

    def mlp_kind(self, layer_idx: int) -> str:
        """'dense' | 'moe' for layer `layer_idx`."""
        if self.moe.n_routed == 0 or layer_idx < self.moe.first_dense:
            return "dense"
        phase = (layer_idx - self.moe.first_dense) % self.moe.every
        return "moe" if phase == 0 else "dense"

    @property
    def is_recurrent(self) -> bool:
        """True if the arch has any SSM layers (sub-quadratic decode)."""
        return self.family in ("ssm", "hybrid")

    # Super-block period for scan-over-layers: the stack is a scan over
    # n_layers // period identical blocks of `period` layers.
    @property
    def block_period(self) -> int:
        p = 1
        if self.attn_every > 1:
            p = self.attn_every
        if self.moe.n_routed and self.moe.every > 1:
            import math
            p = p * self.moe.every // math.gcd(p, self.moe.every)
        return p

    def validate(self) -> None:
        body = self.n_layers - self.moe.first_dense
        assert body % self.block_period == 0, (
            f"{self.name}: {body} body layers not divisible by period "
            f"{self.block_period}")
        if self.attn_kind == "gqa":
            assert self.n_heads % self.n_kv_heads == 0


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode
    accum_steps: int = 1          # grad-accumulation microbatch count (train)


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train", accum_steps=8)
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Assigned shape cells for an arch. ``long_500k`` needs sub-quadratic
    attention: run for SSM/hybrid archs, skip for pure full-attention archs
    (skip recorded in DESIGN.md / EXPERIMENTS.md)."""
    cells = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.is_recurrent:
        cells.append(LONG_500K)
    return tuple(cells)


# ---------------------------------------------------------------------------
# Reduced ("smoke") config helper
# ---------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Family-preserving reduction for CPU smoke tests."""
    period = cfg.block_period
    small = dict(
        n_layers=max(period, 2) + cfg.moe.first_dense,
        d_model=64,
        n_heads=4,
        n_kv_heads=(min(cfg.n_kv_heads, 2)
                    if cfg.n_kv_heads < cfg.n_heads else 4),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        score_dtype="float32",
        attn_chunk=64,
        scan_chunk=16,
    )
    if cfg.moe.n_routed:
        # capacity_factor = E makes C >= T*k: no token dropping at smoke scale,
        # so cached decode exactly matches the full forward in tests.
        small["moe"] = replace(cfg.moe, n_routed=4,
                               n_shared=min(cfg.moe.n_shared, 1),
                               top_k=2, d_ff=64, capacity_factor=4.0)
    if cfg.family in ("ssm", "hybrid"):
        small["ssm"] = replace(cfg.ssm, d_state=8)
    if cfg.attn_kind == "mla":
        small["mla"] = MLAConfig(kv_lora_rank=32, rope_head_dim=8,
                                 nope_head_dim=16, v_head_dim=16)
        small["head_dim"] = 0
    small.update(overrides)
    out = replace(cfg, **small)
    out.validate()
    return out


SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")
