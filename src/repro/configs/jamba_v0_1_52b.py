"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336;
Mamba+attn 1:7 interleave (1 attention layer per 8), MoE 16e top-2 every
other layer.  [arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, reduce_config

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    attn_every=8,            # 1:7 attn:mamba
    attn_offset=4,           # attention at layer 4 of each period (jamba)
    moe=MoEConfig(n_routed=16, n_shared=0, top_k=2, d_ff=14336, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10_000.0,
    # mamba chunked-scan buffers are activation-heavy: halve the microbatch
    # (16 is the max: global batch 256 / data*pod shards) and tighten the
    # scan/attention chunk sizes; spread prefill weights over data
    accum_override=16,
    scan_chunk=64,
    attn_chunk=1024,
    serve_2d_weights=True,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG, attn_every=4, attn_offset=2, n_layers=4)


def _check():
    CONFIG.validate()
