"""Calibrated testbed + benchmark suite for the paper's experiments.

Testbed (paper §IV): AMD A10-7850K (CPU, 4 CUs @ 3.1 GHz; iGPU R7 512c
@ 720 MHz) + GTX 950 (768c @ 1.24 GHz).  Problem sizes give ~2 s on the
fastest device (GPU) — the paper's "pessimistic", time-constrained regime.

The relative computing powers and overheads below are calibrated per
benchmark so the simulator reproduces the paper's qualitative and
quantitative structure: HGuided best overall (eff ~0.84 optimized), Static
good on regular programs, Dynamic sensitive to packet count (512-chunk
overhead pathology on NBody, too-large-chunk imbalance on Binomial/Ray2/
Mandelbrot), iGPU zero-copy benefit for the buffers optimization.

Each benchmark also carries its irregularity profile: the per-work-group
cost across the normalized work range (Ray scenes: cost concentrated where
spheres are; Mandelbrot: interior pixels run the full 5000 iterations).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.simulate import SimDevice

GPU_TIME_S = 2.0          # paper: ~2 s on the fastest device


@dataclass(frozen=True)
class BenchSpec:
    name: str
    total_work: int                    # work-groups
    lws: int                           # paper Table I local work size
    # relative computing powers (CPU, iGPU, GPU); GPU = 1
    rel_power: Tuple[float, float, float] = (0.15, 0.45, 1.0)
    # per-packet launch overhead per device (s): host-managed queues
    launch_overhead: Tuple[float, float, float] = (2e-4, 4e-4, 3e-4)
    # transfer seconds per work-group (in+out), paid by discrete devices;
    # the iGPU shares main memory -> zero-copy when opt_buffers
    transfer: Tuple[float, float, float] = (0.0, 1e-5, 2e-5)
    irregularity: Optional[Callable[[float], float]] = None
    regular: bool = True


def _mandel_irr(x: float) -> float:
    # interior band of the set (middle of the image) costs the full budget
    return 0.15 + 2.4 * math.exp(-((x - 0.5) ** 2) / (2 * 0.15 ** 2))


def _ray1_irr(x: float) -> float:
    # scene 1: spheres spread across the frame, mild center weighting
    return 0.45 + 1.6 * math.exp(-((x - 0.55) ** 2) / (2 * 0.22 ** 2))


def _ray2_irr(x: float) -> float:
    # scene 2: tight cluster -> strong hot band
    return 0.25 + 2.8 * math.exp(-((x - 0.45) ** 2) / (2 * 0.10 ** 2))


BENCHES: Dict[str, BenchSpec] = {
    # Gaussian 8192px, lws 128 -> one work-group = one 128-row block
    "gaussian": BenchSpec("gaussian", total_work=4096, lws=8,
                          rel_power=(0.22, 0.48, 1.0),
                          launch_overhead=(2.5e-3, 1.8e-3, 1.5e-3),
                          transfer=(0.0, 3.2e-4, 3.0e-4)),
    "binomial": BenchSpec("binomial", total_work=32768, lws=16,
                          rel_power=(0.08, 0.35, 1.0),
                          launch_overhead=(2.0e-3, 1.4e-3, 1.1e-3),
                          transfer=(0.0, 3.2e-5, 2.8e-5)),
    "nbody": BenchSpec("nbody", total_work=3584, lws=8,
                       rel_power=(0.06, 0.50, 1.0),
                       launch_overhead=(6e-3, 4.5e-3, 4e-3),
                       transfer=(0.0, 4.8e-4, 4.4e-4)),
    "ray1": BenchSpec("ray1", total_work=8192, lws=8,
                      rel_power=(0.13, 0.32, 1.0),
                      launch_overhead=(2.5e-3, 1.9e-3, 1.6e-3),
                      transfer=(0.0, 1.2e-4, 1.2e-4),
                      irregularity=_ray1_irr, regular=False),
    "ray2": BenchSpec("ray2", total_work=8192, lws=8,
                      rel_power=(0.12, 0.30, 1.0),
                      launch_overhead=(2.5e-3, 1.9e-3, 1.6e-3),
                      transfer=(0.0, 1.2e-4, 1.2e-4),
                      irregularity=_ray2_irr, regular=False),
    "mandelbrot": BenchSpec("mandelbrot", total_work=14336, lws=8,
                            rel_power=(0.16, 0.42, 1.0),
                            launch_overhead=(2.3e-3, 1.7e-3, 1.4e-3),
                            transfer=(0.0, 6e-5, 6e-5),
                            irregularity=_mandel_irr, regular=False),
}

DEVICE_NAMES = ("cpu", "igpu", "gpu")

# offline-profiling bias per device: what the scheduler's static profile
# believes relative to the truth for the actual problem (the CPU benchmarks
# optimistically under co-execution contention: runtime+scheduler threads
# steal its cores; the iGPU shares memory bandwidth with the CPU)
PROFILE_BIAS = (1.18, 0.88, 0.97)
# per-device execution jitter: the CPU co-runs the Runtime/Scheduler host
# threads (heavy contention), the iGPU shares memory bandwidth, the GPU is
# comparatively steady
JITTER = (0.26, 0.15, 0.08)


def sim_devices(bench: BenchSpec) -> List[SimDevice]:
    """The paper's 3-device testbed, calibrated so the GPU solves the whole
    problem in ~GPU_TIME_S (including its irregularity profile)."""
    irr_mean = 1.0
    if bench.irregularity is not None:
        steps = 256
        irr_mean = sum(bench.irregularity((i + 0.5) / steps)
                       for i in range(steps)) / steps
    gpu_thr = bench.total_work * irr_mean / GPU_TIME_S
    devs = []
    for i, name in enumerate(DEVICE_NAMES):
        devs.append(SimDevice(
            name=name,
            throughput=gpu_thr * bench.rel_power[i],
            launch_overhead=bench.launch_overhead[i],
            transfer_in=bench.transfer[i] * 0.5,
            transfer_out=bench.transfer[i] * 0.5,
            irregularity=bench.irregularity,
            zero_copy=(name in ("cpu", "igpu")),   # shared main memory
            profile_bias=PROFILE_BIAS[i],
            jitter=JITTER[i],
        ))
    return devs


# The paper's seven scheduling configurations of Fig. 3/4, plus the
# repo's new load-balancing algorithm (lease-amortized dispatch with a
# work-stealing tail).
SCHED_CONFIGS: List[Tuple[str, str, Dict]] = [
    ("Static", "static", {}),
    ("Static rev", "static_rev", {}),
    ("Dyn 64", "dynamic", {"n_packets": 64}),
    ("Dyn 128", "dynamic", {"n_packets": 128}),
    ("Dyn 512", "dynamic", {"n_packets": 512}),
    ("HGuided", "hguided", {}),
    ("HGuided opt", "hguided_opt", {}),
    ("HGuided steal", "hguided_steal", {}),
]


def dispatch_for(sched: str) -> str:
    """The hand-off mode a scheduler is evaluated under: hguided_steal's
    contract IS leased dispatch (lease + steal refills); everything else
    keeps the calibrated per-packet hand-off the paper measured."""
    return "leased" if sched == "hguided_steal" else "per_packet"
