"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048; decoder-only over EnCodec tokens (4 codebooks, embeddings
summed, all codebooks predicted per step); frontend STUB per assignment.
[arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="encodec_stub",
    n_codebooks=4,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG, n_kv_heads=4, n_codebooks=2)
