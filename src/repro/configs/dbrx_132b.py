"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per expert)
vocab=100352; 16 experts top-4 fine-grained.  [hf:databricks/dbrx-base;
unverified]"""
from repro.configs.base import ModelConfig, MoEConfig, reduce_config

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    moe=MoEConfig(n_routed=16, n_shared=0, top_k=4, d_ff=10752, every=1),
    rope_theta=500_000.0,
    # 132B bf16 exceeds HBM under TP-16 alone: spread weights over the data
    # axis for serving too (see configs/base.py)
    serve_2d_weights=True,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG)
