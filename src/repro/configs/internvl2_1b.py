"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655; InternViT frontend is a STUB (precomputed patch embeddings),
backbone = Qwen2-0.5B-like decoder.  [arXiv:2404.16821; hf]

Sharding note: 14 heads and 151655 vocab do not divide the 16-way model
axis — the resolver's divisibility fallback replicates heads and shards
d_ff / d_model instead (see parallel/sharding.py).
"""
from repro.configs.base import ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    frontend="vit_stub",
    n_patches=256,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG, n_heads=2, n_kv_heads=1, n_patches=8)
