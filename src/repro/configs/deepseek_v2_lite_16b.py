"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408 (expert dim)
vocab=102400; MLA kv_lora=512; MoE 64 routed top-6 + 2 shared; first layer
dense.  [arXiv:2405.04434; hf]

Note (DESIGN.md §8): the assignment string pins "MoE 64e top-6"; the HF card
has 160 routed. We follow the assignment string (64 routed) and keep the MLA
dims from the note.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, reduce_config

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,              # dense (first) layer ffn dim
    vocab_size=102400,
    attn_kind="mla",
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff=1408,
                  every=1, first_dense=1),
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG, d_ff=128)
