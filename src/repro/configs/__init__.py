"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    LONG_500K,
    DECODE_32K,
    PREFILL_32K,
    SHAPES,
    SMOKE_SHAPE,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    reduce_config,
    shapes_for,
)

ARCH_IDS = (
    "qwen3-32b",
    "llama3.2-1b",
    "yi-9b",
    "stablelm-3b",
    "deepseek-v2-lite-16b",
    "dbrx-132b",
    "jamba-v0.1-52b",
    "falcon-mamba-7b",
    "internvl2-1b",
    "musicgen-large",
)


def _module(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke()
