"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16, mamba1 arch.  [arXiv:2410.05355; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig, reduce_config

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,                  # mamba blocks have no separate MLP
    vocab_size=65024,
    attn_kind="none",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)


def smoke() -> ModelConfig:
    return reduce_config(CONFIG, n_heads=1, n_kv_heads=1, d_ff=0, head_dim=0)
