"""Gradient compression for the slow cross-pod hop (DCN), with error
feedback.

At 1000+ node scale the intra-pod reduce-scatter runs at ICI speed but the
pod-level all-reduce crosses the datacenter network.  int8 quantization with
per-tensor scales cuts that traffic 4x (vs f32 accumulators) / 2x (vs bf16);
the residual is carried to the next step (error feedback, Seide et al. '14),
which keeps SGD/Adam convergence intact.

The transform is pure-JAX and composes with any step function:

    g_q, new_err = compress_decompress(g + err)

In a multi-controller deployment ``quantize`` runs before the ``psum`` over
the ``pod`` axis and ``dequantize`` after; in the single-program GSPMD
lowering used here we emulate by quantize->dequantize around the grad use —
the roundtrip error (and hence the convergence behaviour) is identical, and
the wire-format saving is recorded in the roofline collective term by
scaling the pod-axis collective bytes (see benchmarks/roofline.py).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _q_leaf(g, err):
    g32 = g.astype(jnp.float32) + (err.astype(jnp.float32)
                                   if err is not None else 0.0)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = g32 - deq
    return deq.astype(g.dtype), new_err.astype(jnp.bfloat16)


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compress_decompress(grads, err) -> Tuple[Any, Any]:
    """Returns (dequantized grads, new error-feedback buffers)."""
    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(err) if err is not None else [None] * len(flat_g)
    out = [_q_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def wire_bytes_saved_fraction() -> float:
    """int8 payload vs bf16 wire format across the pod axis."""
    return 0.5
