"""AdamW with global-norm clipping, cosine schedule and ZeRO-1/3 friendly
state layout (moments are pytrees sharded exactly like the parameters, so the
resolver's FSDP rules shard them over the data axes for free)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # moments dtype: f32 is the default; bf16 halves optimizer HBM (hillclimb
    # lever for the memory roofline term)
    moment_dtype: str = "float32"


class TrainState(NamedTuple):
    step: jnp.ndarray          # ()
    params: Any
    mu: Any
    nu: Any


def lr_at(opt: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(opt.warmup_steps, 1), 1.0)
    t = jnp.clip((step - opt.warmup_steps)
                 / jnp.maximum(opt.total_steps - opt.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = opt.min_lr_frac + (1 - opt.min_lr_frac) * cos
    return opt.lr * warm * frac


def init_state(params, opt: OptConfig) -> TrainState:
    mdt = jnp.dtype(opt.moment_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, mdt)

    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(state: TrainState, grads,
                  opt: OptConfig) -> Tuple[TrainState, Dict]:
    b1, b2 = opt.betas
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(opt, step)
    mdt = jnp.dtype(opt.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mu_hat = mu32 / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu32 / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + opt.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(state.params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return TrainState(step, new_params, new_mu, new_nu), metrics
