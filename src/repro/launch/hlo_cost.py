"""Loop-corrected cost model over compiled HLO text.

``Compiled.cost_analysis()`` counts every ``while`` body exactly ONCE, so a
scan-over-layers / grad-accumulation program under-reports FLOPs, HBM bytes
and collectives by the loop trip counts (verified empirically: an 8-step
scan reports 1/8 of the unrolled FLOPs).  Since the entire framework is
scan-based (that's what keeps the 512-way GSPMD compile tractable), we walk
the compiled module text instead:

  * computations are parsed into instruction lists with a per-computation
    symbol table (every instruction line carries its result type);
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
    body+cond costs are multiplied by the trip count (nested loops compose);
  * ``fusion`` ops contribute the FLOPs of their fused computation and the
    HBM traffic of their operands/result (post-fusion buffer traffic is the
    right HBM model);
  * dots: 2 * prod(result) * prod(lhs contracting dims); elementwise: 1
    flop/element; transcendentals counted via ``transcendentals``;
  * in-place patterns are special-cased so decode doesn't report phantom
    traffic: dynamic-update-slice counts 2x the *update* bytes (not the
    cache), dynamic-slice / gather count the *slice* bytes.

Everything is derived from the compiled artifact — this is the §Roofline
data source.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "not", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "clamp", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "convert", "is-finite", "popcnt", "clz",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "sqrt", "rsqrt", "power",
                   "sine", "cosine", "logistic", "log-plus-one",
                   "exponential-minus-one", "atan2", "cbrt", "erf", "tan"}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->\s*.*\{\s*$")
# NB: tuple types contain /*index=N*/ comments, so allow anything except
# parens inside the tuple alternative (XLA tuple types are never nested in
# instruction result positions).
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_PARAM_NUM = re.compile(r"parameter\((\d+)\)")


def _type_bytes(t: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(t):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(t: str) -> int:
    total = 0
    for _, dims in _SHAPE.findall(t):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_dims(t: str) -> List[int]:
    m = _SHAPE.search(t)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type: str
    op: str
    rest: str            # raw operand + attribute text
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, str] = field(default_factory=dict)   # name -> type
    params: List[str] = field(default_factory=list)       # operand order


@dataclass
class CostTotals:
    flops: float = 0.0
    dot_flops: float = 0.0
    transcendentals: float = 0.0
    traffic_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    unknown_trip_loops: int = 0

    def scaled(self, k: float) -> "CostTotals":
        out = CostTotals(self.flops * k, self.dot_flops * k,
                         self.transcendentals * k,
                         self.traffic_bytes * k, {}, self.unknown_trip_loops)
        for kind, s in self.collectives.items():
            out.collectives[kind] = {kk: vv * k if kk != "group" else vv
                                     for kk, vv in s.items()}
        return out

    def add(self, other: "CostTotals") -> None:
        self.flops += other.flops
        self.dot_flops += other.dot_flops
        self.transcendentals += other.transcendentals
        self.traffic_bytes += other.traffic_bytes
        self.unknown_trip_loops += other.unknown_trip_loops
        for kind, s in other.collectives.items():
            mine = self.collectives.setdefault(
                kind, {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0})
            for kk in ("count", "result_bytes", "wire_bytes"):
                mine[kk] += s.get(kk, 0.0)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = (_COMP_HDR.match(line.strip())
                 if ("{" in line and "->" in line) else None)
            if m:
                cur = Computation(name=m.group(2))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, typ, op, rest = m.group(1), m.group(2), m.group(3), m.group(4)
        # operands: %names before the closing paren of the op call
        depth = 1
        i = 0
        while i < len(rest) and depth > 0:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        opers = _OPERAND.findall(rest[:i])
        ins = Instr(name, typ, op, rest, opers)
        cur.instrs.append(ins)
        cur.table[name] = typ
        if op == "parameter":
            pm = _PARAM_NUM.search("parameter(" + rest)
            idx = int(pm.group(1)) if pm else len(cur.params)
            while len(cur.params) <= idx:
                cur.params.append("")
            cur.params[idx] = name
    return comps


def _wire_bytes(kind: str, out_bytes: float, group: int) -> float:
    g = max(group, 2)
    if kind == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)
    if kind == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.entry = self._find_entry(text)
        self._memo: Dict[str, CostTotals] = {}

    def _find_entry(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    return m.group(2)
        # fallback: last computation
        return list(self.comps)[-1]

    # -- per-instruction local costs --------------------------------------
    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems = _type_elems(ins.type)
        cm = _CONTRACT.search(ins.rest)
        contract = 1
        if cm and ins.operands:
            lhs_t = comp.table.get(ins.operands[0], "")
            dims = _shape_dims(lhs_t)
            for ci in cm.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contract *= dims[int(ci)]
        return 2.0 * out_elems * contract

    def _fusion_flops(self, callee: Computation) -> Tuple[float, float, float]:
        fl = tr = df = 0.0
        for ins in callee.instrs:
            if ins.op == "dot":
                d = self._dot_flops(callee, ins)
                fl += d
                df += d
            elif ins.op in _ELEMENTWISE:
                fl += _type_elems(ins.type)
            elif ins.op in _TRANSCENDENTAL:
                tr += _type_elems(ins.type)
            elif ins.op == "reduce":
                fl += max(_type_elems(callee.table.get(ins.operands[0], "")),
                          _type_elems(ins.type)) if ins.operands else 0
            elif ins.op == "fusion":
                cm = _CALLS.search(ins.rest)
                if cm and cm.group(1) in self.comps:
                    f2, t2, d2 = self._fusion_flops(self.comps[cm.group(1)])
                    fl += f2
                    tr += t2
                    df += d2
        return fl, tr, df

    @staticmethod
    def _resolve_to_param(callee: Computation, name: str,
                          follow_convert: bool = False) -> Optional[str]:
        """Follow view chains (bitcast/copy/reshape/transpose, + convert for
        *read*-size corrections — a fused slice-of-convert-of-param only
        reads the sliced elements; convert must NOT be followed for the DUS
        in-place aliasing correction, where dtype equality is required)."""
        ops = ("bitcast", "copy", "reshape", "transpose") + (
            ("convert",) if follow_convert else ())
        seen = 0
        by_name = {i.name: i for i in callee.instrs}
        while seen < 8:
            if name in callee.params:
                return name
            ins = by_name.get(name)
            if ins is None or ins.op not in ops or not ins.operands:
                return None
            name = ins.operands[0]
            seen += 1
        return None

    def _fusion_traffic(self, comp: Computation, ins: Instr,
                        callee: Computation) -> float:
        """Post-fusion HBM traffic of a fusion call site, with in-place
        corrections for dynamic-(update-)slice / gather whose big operand
        resolves (through view chains) to a fusion parameter."""
        # default: every fusion operand read once + result written
        op_bytes = [_type_bytes(comp.table.get(o, "")) for o in ins.operands]
        result = _type_bytes(ins.type)
        # corrections keyed by callee parameter index
        for fin in callee.instrs:
            if fin.op in ("dynamic-slice", "gather", "slice") and fin.operands:
                src = self._resolve_to_param(callee, fin.operands[0],
                                             follow_convert=True)
                if src is not None:
                    k = callee.params.index(src)
                    if k < len(op_bytes):
                        op_bytes[k] = min(op_bytes[k], _type_bytes(fin.type))
            elif fin.op == "dynamic-update-slice" and len(fin.operands) >= 2:
                src = self._resolve_to_param(callee, fin.operands[0])
                upd_b = _type_bytes(callee.table.get(fin.operands[1], ""))
                cache_b = _type_bytes(callee.table.get(fin.operands[0], ""))
                if src is not None:
                    k = callee.params.index(src)
                    if k < len(op_bytes):
                        op_bytes[k] = min(op_bytes[k], upd_b)
                # the DUS result aliases its buffer operand in-place: replace
                # the buffer-sized write with an update-sized one
                if result >= cache_b > 0:
                    result = result - cache_b + upd_b
        return float(sum(op_bytes) + result)

    # -- computation walk ---------------------------------------------------
    def total(self, comp_name: Optional[str] = None) -> CostTotals:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        out = CostTotals()
        if comp is None:
            return out
        self._memo[comp_name] = out  # guard recursion
        for ins in comp.instrs:
            if ins.op == "while":
                tm = _TRIP.search(ins.rest)
                trip = int(tm.group(1)) if tm else 1
                if not tm:
                    out.unknown_trip_loops += 1
                cb = _COND_BODY.search(ins.rest)
                if cb:
                    sub = CostTotals()
                    sub.add(self.total(cb.group(2)))
                    sub.add(self.total(cb.group(1)))
                    out.add(sub.scaled(trip))
            elif ins.op == "fusion":
                cm = _CALLS.search(ins.rest)
                callee = self.comps.get(cm.group(1)) if cm else None
                if callee is not None:
                    fl, tr, df = self._fusion_flops(callee)
                    out.flops += fl
                    out.dot_flops += df
                    out.transcendentals += tr
                    out.traffic_bytes += self._fusion_traffic(
                        comp, ins, callee)
                    # collectives never appear inside fusions
            elif ins.op in ("call", "custom-call", "conditional"):
                cm = _CALLS.search(ins.rest)
                if cm and cm.group(1) in self.comps:
                    out.add(self.total(cm.group(1)))
                out.traffic_bytes += _type_bytes(ins.type)
            elif ins.op == "dot":
                d = self._dot_flops(comp, ins)
                out.flops += d
                out.dot_flops += d
                out.traffic_bytes += (_type_bytes(ins.type) + sum(
                    _type_bytes(comp.table.get(o, "")) for o in ins.operands))
            elif ins.op == "convolution":
                out.flops += 2.0 * _type_elems(ins.type) * 1  # window unknown
                out.traffic_bytes += (_type_bytes(ins.type) + sum(
                    _type_bytes(comp.table.get(o, "")) for o in ins.operands))
            elif any(ins.op == c or ins.op == c + "-start"
                     or ins.op == c + "-done" for c in COLLECTIVES):
                if ins.op.endswith("-done"):
                    continue
                base = next(c for c in COLLECTIVES if ins.op.startswith(c))
                ob = _type_bytes(ins.type)
                if ins.op.endswith("-start"):
                    ob //= 2
                gm = _GROUPS_IOTA.search(ins.rest)
                if gm:
                    group = int(gm.group(2))
                else:
                    gl = _GROUPS_LIST.search(ins.rest)
                    group = len(gl.group(1).split(",")) if gl else 2
                s = out.collectives.setdefault(
                    base, {"count": 0.0, "result_bytes": 0.0,
                           "wire_bytes": 0.0})
                s["count"] += 1
                s["result_bytes"] += ob
                s["wire_bytes"] += _wire_bytes(base, ob, group)
                out.traffic_bytes += 2.0 * ob
            elif ins.op in ("dynamic-slice", "gather"):
                out.traffic_bytes += 2.0 * _type_bytes(ins.type)
            elif ins.op == "dynamic-update-slice":
                upd = _type_bytes(comp.table.get(ins.operands[1], "")) \
                    if len(ins.operands) > 1 else 0
                out.traffic_bytes += 2.0 * upd
            elif ins.op in ("copy", "transpose", "reshape", "broadcast",
                            "concatenate", "pad", "slice", "reverse",
                            "reduce", "sort", "scatter", "select-and-scatter",
                            "reduce-window", "iota", "rng",
                            "rng-bit-generator",
                            "convert", "select") or ins.op in _ELEMENTWISE \
                    or ins.op in _TRANSCENDENTAL:
                tb = _type_bytes(ins.type) + sum(
                    _type_bytes(comp.table.get(o, "")) for o in ins.operands)
                out.traffic_bytes += tb
                if ins.op in _ELEMENTWISE:
                    out.flops += _type_elems(ins.type)
                elif ins.op in _TRANSCENDENTAL:
                    out.transcendentals += _type_elems(ins.type)
                elif ins.op == "reduce" and ins.operands:
                    out.flops += _type_elems(
                        comp.table.get(ins.operands[0], ""))
        self._memo[comp_name] = out
        return out


def analyze(text: str) -> Dict:
    """Loop-corrected totals for the entry computation (per device, per
    execution)."""
    hc = HloCost(text)
    t = hc.total()
    return {
        "flops": t.flops,
        "dot_flops": t.dot_flops,
        "transcendentals": t.transcendentals,
        "traffic_bytes": t.traffic_bytes,
        "collectives": t.collectives,
        "collective_wire_bytes": sum(
            s["wire_bytes"] for s in t.collectives.values()),
        "unknown_trip_loops": t.unknown_trip_loops,
    }
