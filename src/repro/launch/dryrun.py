import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init.  This process is the ONLY place that sees 512
# placeholder devices; smoke tests and benches see the real single device.

import argparse          # noqa: E402
import gzip              # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, get_config,  # noqa: E402
                           shapes_for)
from repro.launch import hlo_analysis, hlo_cost  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.optim.adamw import OptConfig, TrainState  # noqa: E402
from repro.parallel.sharding import ShardingResolver  # noqa: E402
from repro.training import step as STEP  # noqa: E402

SDS = jax.ShapeDtypeStruct


def _sh_tree(resolver, abstract, axes, *, param):

    def is_ax(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)

    return jax.tree.map(
        lambda ax, leaf: resolver.sharding(ax, leaf.shape, param=param),
        axes, abstract, is_leaf=is_ax)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               save_hlo: bool = False, opt_overrides=None):
    """Lower + compile one (arch x shape x mesh) cell; return the record."""
    cfg = get_config(arch)
    if opt_overrides:
        cfg = apply_overrides(cfg, opt_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    resolver = ShardingResolver(mesh, fsdp=(shape.kind == "train"))
    t0 = time.time()

    if shape.kind == "prefill" and cfg.serve_2d_weights:
        # weights spread over data for prefill (batch amortizes the gathers);
        # decode keeps TP-resident weights (gathering per token is 15x the
        # memory floor) — dbrx decode capacity requires int8 weights or
        # TP-32 in production (see EXPERIMENTS.md)
        resolver = ShardingResolver(mesh, fsdp=True)
    if shape.kind == "train":
        opt = OptConfig()
        state_abs, state_axes = SP.abstract_train_state(cfg, opt)
        batch_abs = SP.input_specs(cfg, shape)
        batch_axes = SP.batch_logical_axes(cfg, shape)
        st_sh = _sh_tree(resolver, state_abs, state_axes, param=True)
        b_sh = _sh_tree(resolver, batch_abs, batch_axes, param=False)
        fn = STEP.make_train_step(cfg, opt, res=resolver,
                                  accum_steps=cfg.accum_override
                                  or shape.accum_steps)
        jfn = jax.jit(fn, in_shardings=(st_sh, b_sh),
                      out_shardings=(st_sh, None), donate_argnums=(0,))
        with mesh:
            lowered = jfn.lower(state_abs, batch_abs)
    elif shape.kind == "prefill":
        params_abs, p_axes = SP.abstract_params(cfg)
        cache_abs, c_axes = SP.abstract_cache(cfg, shape.global_batch,
                                              shape.seq_len)
        batch_abs = SP.input_specs(cfg, shape)
        batch_axes = SP.batch_logical_axes(cfg, shape)
        p_sh = _sh_tree(resolver, params_abs, p_axes, param=True)
        c_sh = _sh_tree(resolver, cache_abs, c_axes, param=False)
        b_sh = _sh_tree(resolver, batch_abs, batch_axes, param=False)
        fn = STEP.make_prefill_step(cfg, res=resolver)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh),
                      out_shardings=(None, c_sh), donate_argnums=(2,))
        with mesh:
            lowered = jfn.lower(params_abs, batch_abs, cache_abs)
    elif shape.kind == "decode":
        if cfg.decode_unroll:
            params_abs, p_axes = SP.abstract_params_unstacked(cfg)
        else:
            params_abs, p_axes = SP.abstract_params(cfg)
        cache_abs, c_axes = SP.abstract_cache(cfg, shape.global_batch,
                                              shape.seq_len)
        ins = SP.input_specs(cfg, shape)
        p_sh = _sh_tree(resolver, params_abs, p_axes, param=True)
        c_sh = _sh_tree(resolver, cache_abs, c_axes, param=False)
        t_sh = NamedSharding(mesh, P())
        fn = STEP.make_decode_step(cfg, res=resolver)
        jfn = jax.jit(fn, in_shardings=(p_sh, t_sh, c_sh, t_sh),
                      out_shardings=(None, c_sh), donate_argnums=(2,))
        with mesh:
            lowered = jfn.lower(params_abs, ins["token"], cache_abs,
                                ins["pos"])
    else:
        raise ValueError(shape.kind)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    t0 = time.time()
    corrected = hlo_cost.analyze(hlo)   # loop-corrected per-device totals
    t_cost = time.time() - t0
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(mesh.devices.size),
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_pass_s": round(t_cost, 2),
        # raw XLA numbers (uncorrected: while bodies counted once)
        "xla_flops_per_device": float(cost.get("flops", -1)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", -1)),
        # loop-corrected per-device totals (see launch/hlo_cost.py)
        "flops_per_device": corrected["flops"],
        "transcendentals_per_device": corrected["transcendentals"],
        "traffic_bytes_per_device": corrected["traffic_bytes"],
        "collectives": corrected["collectives"],
        "collective_wire_bytes_per_device": corrected["collective_wire_bytes"],
        "unknown_trip_loops": corrected["unknown_trip_loops"],
        "memory": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
        "op_histogram": hlo_analysis.op_histogram(hlo),
    }
    if save_hlo:
        os.makedirs("artifacts/hlo", exist_ok=True)
        fp = f"artifacts/hlo/{arch}__{shape_name}__{record['mesh']}.txt.gz"
        with gzip.open(fp, "wt") as f:
            f.write(hlo)
        record["hlo_path"] = fp
    return record


def cell_list():
    cells = []
    for arch in ARCH_IDS:
        for shape in shapes_for(get_config(arch)):
            cells.append((arch, shape.name))
    return cells


def _parse_overrides(pairs):
    """--set key=value config overrides (ints/floats/bools/strings; nested
    moe.* / ssm.* fields supported)."""
    import dataclasses
    out = {}
    for pair in pairs or []:
        key, val = pair.split("=", 1)
        for cast in (int, float):
            try:
                val = cast(val)
                break
            except ValueError:
                continue
        if val in ("true", "True"):
            val = True
        if val in ("false", "False"):
            val = False
        out[key] = val
    return out


def apply_overrides(cfg, overrides):
    import dataclasses
    top = {}
    for key, val in overrides.items():
        if "." in key:
            sub, field_name = key.split(".", 1)
            subcfg = dataclasses.replace(getattr(cfg, sub),
                                         **{field_name: val})
            top[sub] = subcfg
        else:
            top[key] = val
    return dataclasses.replace(cfg, **top)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", dest="overrides",
                    help="cfg override key=value (repeatable); e.g. "
                         "--set remat_policy=dots "
                         "--set moe.capacity_factor=1.0")
    ap.add_argument("--tag", default="",
                    help="artifact suffix for perf iterations")
    args = ap.parse_args()

    cells = cell_list() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    overrides = _parse_overrides(args.overrides)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_tag = "2x16x16" if mp else "16x16"
            suffix = f"__{args.tag}" if args.tag else ""
            fp = os.path.join(
                args.out, f"{arch}__{shape}__{mesh_tag}{suffix}.json")
            if os.path.exists(fp) and not args.force:
                print(f"[skip] {fp}")
                continue
            print(f"[dryrun] {arch} x {shape} x {mesh_tag} {overrides} ...",
                  flush=True)
            try:
                rec = lower_cell(arch, shape, multi_pod=mp,
                                 save_hlo=args.save_hlo,
                                 opt_overrides=overrides or None)
                rec["overrides"] = overrides
                rec["tag"] = args.tag
                with open(fp, "w") as f:
                    json.dump(rec, f, indent=1)
                wire = rec["collective_wire_bytes_per_device"]
                temp = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
                print(f"  ok: compile={rec['compile_s']}s "
                      f"flops/dev={rec['flops_per_device']:.3e} "
                      f"wire/dev={wire:.3e} temp={temp:.2f}GiB",
                      flush=True)
            except Exception:
                failures += 1
                print(f"  FAILED:\n{traceback.format_exc()}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
