"""Post-SPMD HLO analysis: collective inventory and wire-byte estimates.

``cost_analysis()`` has no collective-bytes entry, so we parse the compiled
module text.  Shapes in the partitioned module are *per-device*; wire bytes
use ring-algorithm estimates with the replica-group size parsed from the op:

    all-gather          O * (N-1)/N
    reduce-scatter      O * (N-1)        (O = scattered per-device output)
    all-reduce          2 * O * (N-1)/N  (reduce-scatter + all-gather)
    all-to-all          O * (N-1)/N
    collective-permute  O
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(" + "|".join(_COLL) + r")(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_OPNAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
                        r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
                        r"([\w\-]+)\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _wire_bytes(kind: str, out_bytes: int, group: int) -> float:
    g = max(group, 2)
    if kind == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if kind == "all-gather":
        return out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)
    if kind == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)  # collective-permute


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: count, result bytes, estimated wire bytes
    (all per device, per execution)."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        type_str, kind, start = m.group(1), m.group(2), m.group(3)
        out_b = _shape_bytes(type_str)
        if start:  # async start op: result tuple repeats the operand; halve
            out_b //= 2
        gm = _GROUPS_RE.search(line)
        if gm:
            group = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            group = len(gl.group(1).split(",")) if gl else 2
        s = stats[kind]
        s["count"] += 1
        s["result_bytes"] += out_b
        s["wire_bytes"] += _wire_bytes(kind, out_b, group)
    return dict(stats)


def op_histogram(hlo_text: str, top: int = 25) -> List[Tuple[str, int]]:
    hist: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OPNAME_RE.match(line)
        if m:
            hist[m.group(1)] += 1
    return sorted(hist.items(), key=lambda kv: -kv[1])[:top]


def total_wire_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(s["wire_bytes"] for s in stats.values())
