"""Abstract input/state specs shared by the dry-run and the launchers.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the given cell — weak-type-correct, shardable, no device
allocation.  ``abstract_train_state`` / ``abstract_serve_state`` do the same
for the train state and the serve caches, together with the logical-axes
trees the resolver consumes.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.optim.adamw import OptConfig, TrainState

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train" or shape.kind == "prefill":
        if cfg.frontend == "encodec_stub":
            toks = SDS((B, S, cfg.n_codebooks), jnp.int32)
        else:
            toks = SDS((B, S), jnp.int32)
        out = {"tokens": toks}
        if cfg.frontend == "vit_stub":
            out["patches"] = SDS((B, cfg.n_patches, cfg.d_model), jnp.float32)
        return out
    if shape.kind == "decode":
        if cfg.frontend == "encodec_stub":
            tok = SDS((B, 1, cfg.n_codebooks), jnp.int32)
        else:
            tok = SDS((B, 1), jnp.int32)
        return {"token": tok, "pos": SDS((), jnp.int32)}
    raise ValueError(shape.kind)


def batch_logical_axes(cfg: ModelConfig,
                       shape: ShapeConfig) -> Dict[str, Tuple]:
    n = 3 if cfg.frontend == "encodec_stub" else 2
    if shape.kind in ("train", "prefill"):
        ax = {"tokens": ("batch", "seq", None)[:n]}
        if cfg.frontend == "vit_stub":
            ax["patches"] = ("batch", None, None)
        return ax
    return {"token": ("batch", None, None)[:n], "pos": ()}


def abstract_params(cfg: ModelConfig):
    return T.init_abstract(cfg)


def abstract_params_unstacked(cfg: ModelConfig):
    """Per-layer (unstacked) weights for the unrolled decode path: no
    whole-stack buffer ever exists on device (see §Perf cell C — the CPU
    backend's bf16-dot conversion otherwise materializes f32 copies of the
    full stacked expert weights)."""
    params, axes = T.init_abstract(cfg)
    blocks = params["blocks"]
    n = jax.tree.leaves(blocks)[0].shape[0]
    params = dict(params)
    axes = dict(axes)
    params["blocks"] = [
        jax.tree.map(lambda t: SDS(t.shape[1:], t.dtype), blocks)
        for _ in range(n)
    ]

    def is_ax(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)

    inner_axes = jax.tree.map(lambda ax: ax[1:], axes["blocks"], is_leaf=is_ax)
    axes["blocks"] = [inner_axes] * n
    return params, axes


def abstract_train_state(cfg: ModelConfig, opt: OptConfig):
    params, axes = T.init_abstract(cfg)
    mdt = jnp.dtype(opt.moment_dtype)
    mom = jax.tree.map(lambda p: SDS(p.shape, mdt), params)
    state = TrainState(step=SDS((), jnp.int32), params=params,
                       mu=mom, nu=jax.tree.map(lambda x: x, mom))
    axes_state = TrainState(step=(), params=axes, mu=axes, nu=axes)
    return state, axes_state


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """(cache ShapeDtypeStructs, logical axes) without allocation."""
    captured = {}

    def build():
        c, a = T.init_cache(cfg, batch, max_seq)
        captured["axes"] = a
        return c

    shapes = jax.eval_shape(build)
    return shapes, captured["axes"]


def state_shardings(resolver, state_abstract, axes_state):
    """Map the resolver over a (possibly nested) abstract state."""
    def one(leaf, ax):
        return resolver.sharding(ax, leaf.shape, param=True)
    return jax.tree.map(
        lambda ax, leaf: resolver.sharding(ax, leaf.shape, param=True),
        axes_state, state_abstract,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
