"""Serving launcher: batched prefill + decode with HGuided request
dispatch across model replicas.

The request queue is the co-execution work set (1 work-group = one
request); replicas pull request packets proportional to their measured
throughput — the paper's scheduler applied to serving (see
core/hetero_dp.py for the training analogue).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 32 --prompt-len 64 --gen 16 --replicas 1:1,2:2
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core.device import DeviceGroup
from repro.core.scheduler import DeviceProfile, make_scheduler
from repro.models import transformer as T


class Replica:
    """One model replica with its own decode loop (a mesh sub-slice on a
    real deployment; a throttled executor here)."""

    def __init__(self, name: str, cfg, params, throttle: float = 1.0):
        self.name = name
        self.cfg = cfg
        self.params = params
        self.group = DeviceGroup(name, throttle=throttle)
        self._decode = jax.jit(
            lambda p, t, c, pos: T.decode_step(cfg, p, t, c, pos))

    def serve(self, prompts, gen: int):
        """prompts: (B, P) -> generated tokens (B, gen)."""
        cfg = self.cfg
        B, P = prompts.shape
        cache, _ = T.init_cache(cfg, B, P + gen)
        lg, cache = T.prefill(cfg, self.params, prompts, cache)
        tok = jnp.argmax(lg[:, -1], -1)[:, None]
        out = []
        for i in range(gen):
            out.append(np.asarray(tok))
            lg, cache = self._decode(self.params, tok, cache,
                                     jnp.int32(P + i))
            tok = jnp.argmax(lg[:, -1], -1)[:, None]
        return np.concatenate(out, axis=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--replicas", default="r0:1",
                    help="name:throttle list, e.g. r0:1,r1:2")
    ap.add_argument("--lws", type=int, default=4,
                    help="requests per packet")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    replicas = []
    for part in args.replicas.split(","):
        name, thr = part.split(":")
        replicas.append(Replica(name, cfg, params, throttle=float(thr)))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    assert args.requests % args.lws == 0
    G = args.requests // args.lws
    profiles = [DeviceProfile(r.name, 1.0 / r.group.throttle)
                for r in replicas]
    sched = make_scheduler("hguided_opt", G, 1, profiles)
    results = np.zeros((args.requests, args.gen), np.int32)
    served = {r.name: 0 for r in replicas}
    t0 = time.time()

    def worker(i: int):
        rep = replicas[i]
        while True:
            pkt = sched.next_packet(i)
            if pkt is None:
                return
            sl = slice(pkt.offset * args.lws,
                       (pkt.offset + pkt.size) * args.lws)
            tgen0 = time.perf_counter()
            results[sl] = rep.serve(jnp.asarray(prompts[sl]), args.gen)
            dt = time.perf_counter() - tgen0
            if rep.group.throttle > 1:
                time.sleep(dt * (rep.group.throttle - 1))
                dt *= rep.group.throttle
            served[rep.name] += pkt.size * args.lws
            if hasattr(sched, "observe"):
                sched.observe(i, pkt.size / max(dt, 1e-9))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(replicas))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.time() - t0
    toks = args.requests * args.gen
    print(f"served {args.requests} requests x {args.gen} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s) dispatch={served}")
    # determinism check: replica assignment must not change outputs
    ref = Replica("ref", cfg, params).serve(jnp.asarray(prompts[:4]), args.gen)
    ok = np.array_equal(results[:4], ref)
    print(f"outputs replica-invariant: {ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
