"""Serving launcher: thin CLI over the deadline-aware serving subsystem.

All mechanism lives in repro.serve (workload generation, admission,
co-execution dispatch, accounting); this module only parses flags, builds
replicas and prints the outcome.  For scheduler comparisons at fleet
scale use the simulator twin: benchmarks/serve_slo.py.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 16 --rate 50 --slo 10 --replicas r0:1,r1:2
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke
from repro.core.scheduler import available_schedulers
from repro.serve import (ARRIVALS, CoexecServer, Replica, RequestQueue,
                         ServerConfig, make_requests, trace_arrivals)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--replicas", default="r0:1",
                    help="name:throttle list, e.g. r0:1,r1:2")
    ap.add_argument("--lws", type=int, default=4,
                    help="requests per packet alignment")
    ap.add_argument("--scheduler", default="hguided_deadline",
                    choices=available_schedulers())
    ap.add_argument("--arrival", default="poisson",
                    choices=sorted(ARRIVALS) + ["trace"])
    ap.add_argument("--trace", default=None,
                    help="file with one arrival timestamp per line")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="offered load, requests/s")
    ap.add_argument("--slo", type=float, default=10.0,
                    help="per-request deadline, seconds after arrival")
    ap.add_argument("--policy", default="shed",
                    choices=["shed", "degrade", "none"])
    ap.add_argument("--batch-window", type=float, default=0.0)
    ap.add_argument("--quantum", type=float, default=float("inf"),
                    help="round quantum, seconds of fleet work")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-invariance", action="store_true",
                    help="re-serve a few requests on a reference replica "
                         "and require identical tokens")
    args = ap.parse_args(argv)
    if args.arrival == "trace" and not args.trace:
        ap.error("--arrival trace requires --trace FILE")
    if args.smoke:
        args.requests = min(args.requests, 16)
        args.gen = min(args.gen, 8)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    from repro.models import transformer as T
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    replicas = []
    for part in args.replicas.split(","):
        name, thr = part.split(":")
        replicas.append(Replica(name, cfg, params, throttle=float(thr)))

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    if args.arrival == "trace":
        with open(args.trace) as f:
            arrivals = trace_arrivals([float(x) for x in f if x.strip()])
        arrivals = arrivals[:args.requests]
    else:
        arrivals = ARRIVALS[args.arrival](args.requests, args.rate, rng)
    reqs = make_requests(arrivals, args.slo, prompt_fn=lambda i: prompts[i])

    server = CoexecServer(replicas, ServerConfig(
        scheduler=args.scheduler, lws=args.lws, gen=args.gen,
        policy=args.policy, batch_window_s=args.batch_window,
        round_quantum_s=args.quantum))
    try:
        out = server.run(RequestQueue(reqs))
    finally:
        server.close()
    st = out.stats
    print(f"{len(reqs)} requests @ {args.rate:.0f}/s ({args.arrival}), "
          f"SLO {args.slo:.2f}s, scheduler={args.scheduler}")
    print(st.row())
    print(f"dispatch={st.dispatch} degraded={st.degraded} "
          f"duration={st.duration:.2f}s")

    if args.check_invariance:
        # replica assignment / packing must not change outputs: re-serve a
        # few full-generation requests on a fresh reference replica
        full = [r for r in out.requests
                if not r.shed and r.finish is not None
                and not r.degraded][:4]
        if not full:
            print("outputs replica-invariant: skipped (no full requests)")
            return 0
        ref = Replica("ref", cfg, params)
        batch = np.stack([r.prompt for r in full])
        want = ref.serve(batch, args.gen)
        got = np.stack([out.results[r.rid] for r in full])
        ok = np.array_equal(got, want)
        print(f"outputs replica-invariant: {ok}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
