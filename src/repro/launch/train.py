"""Training launcher.

Single-process reference trainer with checkpoint/restart and optional
heterogeneity-aware co-execution (the paper's technique as the DP layer):

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50 --ckpt-dir /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --hetero cpu:1,igpu:2,gpu:4 --steps 20

On a TPU deployment the same train_step is jit'd with the production mesh
shardings (launch/dryrun.py proves every cell compiles); here the model
runs on CPU at reduced scale unless --full is passed.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.ckpt import checkpoint as CK
from repro.configs import get_config, get_smoke
from repro.configs.base import ShapeConfig
from repro.core.device import DeviceGroup
from repro.core.hetero_dp import HeteroDPTrainer
from repro.data.pipeline import SyntheticPipeline
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.adamw import OptConfig
from repro.training.step import make_train_step


def parse_hetero(spec: str):
    groups = []
    for part in spec.split(","):
        name, throttle = part.split(":")
        groups.append(DeviceGroup(name, throttle=float(throttle)))
    return groups


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--hetero", default="",
                    help="co-execution groups, e.g. cpu:4,igpu:2,gpu:1 "
                         "(name:throttle)")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train",
                        accum_steps=args.accum)
    pipeline = SyntheticPipeline(cfg, shape)
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    state = adamw.init_state(params, opt)
    total, active = T.param_count(cfg)
    print(f"arch={cfg.name} params={total/1e6:.1f}M "
          f"(active {active/1e6:.1f}M) tokens/step={args.batch*args.seq}")

    start_step = 0
    ck = CK.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if (args.resume and args.ckpt_dir
            and CK.latest_step(args.ckpt_dir) is not None):
        state, start_step = CK.restore(state, args.ckpt_dir)
        state = jax.tree.map(jax.numpy.asarray, state)
        print(f"resumed from step {start_step}")

    t0 = time.time()
    if args.hetero:
        groups = parse_hetero(args.hetero)
        trainer = HeteroDPTrainer(cfg, opt, shape, groups, pipeline,
                                  compress=args.compress)
        for step in range(start_step, args.steps):
            state, rep = trainer.step(state, step)
            if step % args.log_every == 0:
                rows = " ".join(f"{k}:{v}" for k, v in rep.device_rows.items())
                print(f"step {step:5d} loss={rep.loss:.4f} "
                      f"t={rep.step_time_s*1e3:.0f}ms "
                      f"balance={rep.balance:.2f} "
                      f"packets={rep.packets} rows[{rows}]")
            if ck and step and step % args.ckpt_every == 0:
                ck.save(state, step)
    else:
        step_fn = jax.jit(make_train_step(cfg, opt, accum_steps=args.accum),
                          donate_argnums=(0,))
        for step in range(start_step, args.steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in pipeline.batch_at(step).items()}
            state, metrics = step_fn(state, batch)
            if step % args.log_every == 0:
                tok_s = args.batch * args.seq * (step - start_step + 1) \
                    / (time.time() - t0)
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} tok/s={tok_s:.0f}")
            if ck and step and step % args.ckpt_every == 0:
                ck.save(state, step)
    if ck:
        ck.save(state, args.steps)
        ck.wait()
        print(f"checkpoint at {args.ckpt_dir} step {args.steps}")
    print(f"done in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
