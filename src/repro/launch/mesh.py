"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run process is
the only one that sees 512 host-platform devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod ("data","model") or 2x16x16 multi-pod
    ("pod","data","model") production mesh (TPU v5e target)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = n_devices or len(jax.devices())
    model = model if n % model == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))
