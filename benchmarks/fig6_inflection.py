"""Paper Fig. 6: execution time vs problem size for binary / ROI modes,
with and without the runtime optimizations; inflection points where
co-execution (HGuided opt) starts beating the fastest single device.

Paper results reproduced here:
  * initialization optimization saves a ~131 ms constant -> moves the
    *binary* inflection point left by ~7.5% on average;
  * buffers optimization (zero-copy for shared-memory devices, no redundant
    bulk copies) -> moves the *ROI* inflection point left by ~17.4%;
  * ROI co-execution pays off above ~15 ms of work; binary above ~1.75 s.
"""
from __future__ import annotations

import json
import os
import time

from repro.configs.paper_suite import BENCHES, sim_devices
from repro.core import metrics as M
from repro.core.simulate import SimConfig, simulate, single_device_time

from benchmarks import common

SIZE_FRACS = (0.002, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.4, 0.7, 1.0, 1.5, 2.2, 3.2)
N_RUNS = 7


def curve(spec, devs, frac, *, opt_init, opt_buffers):
    work = max(3 * spec.lws, int(spec.total_work * frac)
               // spec.lws * spec.lws)
    cfg0 = SimConfig(opt_init=opt_init, opt_buffers=opt_buffers)
    gpu = devs[-1]
    single_roi = single_device_time(work, spec.lws, gpu, cfg0)
    single_bin = single_roi + (cfg0.init_cost_optimized if opt_init
                               else cfg0.init_cost)
    rois, bins = [], []
    for seed in range(N_RUNS):
        cfg = SimConfig(scheduler="hguided_opt", opt_init=opt_init,
                        opt_buffers=opt_buffers, seed=seed)
        r = simulate(work, spec.lws, devs, cfg)
        rois.append(r.total_time)
        bins.append(r.binary_time)
    return (work, sum(rois) / N_RUNS, sum(bins) / N_RUNS,
            single_roi, single_bin)


def inflection(xs, co, single):
    return M.inflection_point(xs, co, single)


def main() -> int:
    t0 = time.time()
    out = {}
    binary_improvements = []
    roi_improvements = []
    for bname, spec in BENCHES.items():
        devs = sim_devices(spec)
        rows = {}
        for tag, oi, ob in (("unopt", False, False),
                            ("opt_init", True, False),
                            ("opt_all", True, True)):
            pts = [curve(spec, devs, f, opt_init=oi, opt_buffers=ob)
                   for f in SIZE_FRACS]
            xs = [p[0] for p in pts]
            rows[tag] = {
                "work": xs,
                "roi_co": [p[1] for p in pts],
                "bin_co": [p[2] for p in pts],
                "roi_single": [p[3] for p in pts],
                "bin_single": [p[4] for p in pts],
                "roi_inflection": inflection(xs, [p[1] for p in pts],
                                             [p[3] for p in pts]),
                "bin_inflection": inflection(xs, [p[2] for p in pts],
                                             [p[4] for p in pts]),
            }
        out[bname] = rows
        # decomposition per the paper: init opt's effect on the binary
        # inflection; buffers opt's marginal effect on the ROI inflection
        bi_u = rows["unopt"]["bin_inflection"]
        bi_o = rows["opt_init"]["bin_inflection"]
        ri_u = rows["opt_init"]["roi_inflection"]
        ri_o = rows["opt_all"]["roi_inflection"]
        if bi_u and bi_o:
            binary_improvements.append(100 * (bi_u - bi_o) / bi_u)
        if ri_u and ri_o:
            roi_improvements.append(100 * (ri_u - ri_o) / ri_u)
        print(f"{bname:12s} binary inflection {bi_u} -> {bi_o} wg | "
              f"roi inflection {ri_u} -> {ri_o} wg")
    bin_avg = sum(binary_improvements) / max(len(binary_improvements), 1)
    roi_avg = sum(roi_improvements) / max(len(roi_improvements), 1)
    print(f"\navg inflection improvement: binary (init opt) {bin_avg:.1f}% "
          f"(paper: 7.5%) | roi (buffers opt) {roi_avg:.1f}% (paper: 17.4%)")
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/fig6.json", "w") as f:
        json.dump(out, f, indent=1)
    ok = bin_avg > 0 and roi_avg > 0
    print(common.csv_line("fig6_inflection", (time.time()-t0)*1e6,
                          f"bin_impr={bin_avg:.1f}%;roi_impr={roi_avg:.1f}%;ok={ok}"))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
