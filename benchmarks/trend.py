"""Benchmark-trend gate: merge headline ratios, compare to the baseline.

CI's ``bench-trend`` job runs ``session_reuse.py``, ``offload_modes.py
--smoke``, ``transfer_overlap.py --smoke``, ``sched_overhead.py
--smoke``, ``dag_pipeline.py --smoke``, ``fleet_slo.py --smoke``,
``energy_pareto.py --smoke``, ``tenant_fairness.py --smoke`` and
``autotune_gain.py --smoke`` with ``--json``, then calls this script to
(a) merge the result files into one ``BENCH_PR.json`` artifact and
(b) fail the job if any **headline ratio** regresses more than
``--tolerance`` (default 10 %) below the committed
``benchmarks/baseline.json``.

Gates are rows in the declarative ``GATES`` table below — one entry per
benchmark: its CLI flag, merged-results key, headline metric name, and
an extractor from the benchmark's ``--json`` payload.  Adding a
benchmark to the trend gate is one table row plus one ``baseline.json``
entry.  All headline ratios are higher-is-better:

* ``session_reuse_min_gap_pct``      — cold->warm binary gap floor
  (executable-cache amortization; paper init-opt floor 7.5 %).
* ``offload_modes_best_gap_pct``     — best binary->ROI gap (paper's
  17.4 % ROI-mode headroom).
* ``transfer_overlap_min_gain_pct``  — min-over-kernels best warm-ROI
  gain of pooled+overlapped over the synchronous per-packet path.
* ``sched_overhead_min_gain_pct``    — min-over-kernels gain of leased
  dispatch (the work-stealing scheduler) over the per-packet-lock
  hand-off at the highest packet count.
* ``dag_pipeline_min_gain_pct``      — dependency-aware DAG dispatch
  gain over level-barrier dispatch at the top packet count.
* ``fleet_slo_min_attainment``       — the deadline fleet router's
  minimum SLO attainment over the stressed offered loads (a fraction in
  [0, 1], not a percentage).
* ``energy_pareto_min_dominance``    — worst-case relative joule saving
  of the ``hguided_energy`` budget frontier over the best time-only
  scheduler, across the deadline-slack grid (fraction in [0, 1]).
* ``tenant_fairness_min_index``      — worst per-scheduler fair-share
  index of three 2:1:1-weighted tenants on a shared fleet (1.0 = exact
  proportional shares at the saturation snapshot; fraction in [0, 1]).
* ``autotune_min_gain_pct``          — min-over-kernels gain of the
  calibrated autotuner's configuration over the hand-picked defaults
  (dynamic ``n_packets=128``, stock lease constants); the benchmark's
  own ``ok`` additionally requires warm cache reuse (zero re-measures,
  identical config) and bit-exact tuned output.

Baseline values are committed *derated* from locally measured numbers so
the gate trips on real regressions, not container noise.

Usage:
  python benchmarks/trend.py --session-reuse sr.json --offload-modes om.json
      --transfer-overlap to.json --sched-overhead so.json
      --dag-pipeline dag.json --fleet-slo fleet.json
      --energy-pareto energy.json --tenant-fairness tenant.json
      --autotune-gain autotune.json
      [--baseline benchmarks/baseline.json]
      [--out BENCH_PR.json] [--tolerance 0.10]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# (CLI flag, merged-results key, headline metric name, extractor).
# Extractors read the benchmark's own --json payload; every metric is
# higher-is-better and gated at baseline * (1 - tolerance).
GATES = [
    ("--session-reuse", "session_reuse", "session_reuse_min_gap_pct",
     lambda d: d["min_gap_pct"]),
    ("--offload-modes", "offload_modes", "offload_modes_best_gap_pct",
     lambda d: max(s["gap_pct"] for s in d["sweeps"])),
    ("--transfer-overlap", "transfer_overlap",
     "transfer_overlap_min_gain_pct", lambda d: d["min_gain_pct"]),
    ("--sched-overhead", "sched_overhead", "sched_overhead_min_gain_pct",
     lambda d: d["min_gain_pct"]),
    ("--dag-pipeline", "dag_pipeline", "dag_pipeline_min_gain_pct",
     lambda d: d["min_gain_pct"]),
    ("--fleet-slo", "fleet_slo", "fleet_slo_min_attainment",
     lambda d: d["min_attainment"]),
    ("--energy-pareto", "energy_pareto", "energy_pareto_min_dominance",
     lambda d: d["min_dominance"]),
    ("--tenant-fairness", "tenant_fairness", "tenant_fairness_min_index",
     lambda d: d["min_index"]),
    ("--autotune-gain", "autotune_gain", "autotune_min_gain_pct",
     lambda d: d["min_gain_pct"]),
]


def headline_metrics(raw: dict) -> dict:
    """Extract every gate's headline ratio from the merged raw results."""
    return {metric: extract(raw[key])
            for _, key, metric, extract in GATES}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    for flag, _, _, _ in GATES:
        ap.add_argument(flag, required=True)
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--out", default="BENCH_PR.json")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional regression vs baseline")
    args = ap.parse_args(argv)

    raw = {}
    for flag, key, _, _ in GATES:
        path = getattr(args, flag.lstrip("-").replace("-", "_"))
        raw[key] = json.loads(pathlib.Path(path).read_text())
    baseline = json.loads(pathlib.Path(args.baseline).read_text())

    metrics = headline_metrics(raw)
    failures = []
    for name, base in baseline["metrics"].items():
        if name not in metrics:
            failures.append(f"{name}: missing from merged results")
            continue
        floor = base * (1.0 - args.tolerance)
        got = metrics[name]
        status = "ok" if got >= floor else "REGRESSED"
        print(f"{name:36s} {got:8.2f} vs baseline {base:8.2f} "
              f"(floor {floor:8.2f}) {status}")
        if got < floor:
            failures.append(
                f"{name}: {got:.2f} < floor {floor:.2f} "
                f"(baseline {base:.2f}, tolerance {args.tolerance:.0%})")
    for key in raw:
        if not raw[key].get("ok", False):
            failures.append(f"{key}: its own acceptance check failed")

    merged = {
        "metrics": metrics,
        "baseline": baseline["metrics"],
        "tolerance": args.tolerance,
        "pass": not failures,
        "failures": failures,
        "raw": raw,
    }
    pathlib.Path(args.out).write_text(json.dumps(merged, indent=2))
    print(f"wrote {args.out}")
    if failures:
        print("\nbench-trend gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench-trend gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
