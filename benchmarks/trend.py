"""Benchmark-trend gate: merge headline ratios, compare to the baseline.

CI's ``bench-trend`` job runs ``session_reuse.py``, ``offload_modes.py
--smoke``, ``transfer_overlap.py --smoke``, ``sched_overhead.py
--smoke``, ``dag_pipeline.py --smoke`` and ``fleet_slo.py --smoke`` with
``--json``, then calls this script to (a) merge the
result files into one ``BENCH_PR.json`` artifact and (b) fail the job if
any **headline ratio** regresses more than ``--tolerance`` (default
10 %) below the committed ``benchmarks/baseline.json``.

Headline ratios (all higher-is-better):

* ``session_reuse_min_gap_pct``      — cold->warm binary gap floor
  (executable-cache amortization; paper init-opt floor 7.5 %).
* ``offload_modes_best_gap_pct``     — best binary->ROI gap (paper's
  17.4 % ROI-mode headroom).
* ``transfer_overlap_min_gain_pct``  — min-over-kernels best warm-ROI
  gain of pooled+overlapped over the synchronous per-packet path.
* ``sched_overhead_min_gain_pct``    — min-over-kernels gain of leased
  dispatch (the work-stealing scheduler) over the per-packet-lock
  hand-off at the highest packet count.
* ``dag_pipeline_min_gain_pct``      — dependency-aware DAG dispatch
  gain over level-barrier dispatch at the top packet count.
* ``fleet_slo_min_attainment``       — the deadline fleet router's
  minimum SLO attainment over the stressed offered loads (a fraction in
  [0, 1], not a percentage).

Baseline values are committed *derated* from locally measured numbers so
the gate trips on real regressions, not container noise.

Usage:
  python benchmarks/trend.py --session-reuse sr.json --offload-modes om.json
      --transfer-overlap to.json --sched-overhead so.json
      --dag-pipeline dag.json --fleet-slo fleet.json
      [--baseline benchmarks/baseline.json]
      [--out BENCH_PR.json] [--tolerance 0.10]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def headline_metrics(sr: dict, om: dict, to: dict, so: dict,
                     dag: dict, fleet: dict) -> dict:
    return {
        "session_reuse_min_gap_pct": sr["min_gap_pct"],
        "offload_modes_best_gap_pct": max(
            s["gap_pct"] for s in om["sweeps"]
        ),
        "transfer_overlap_min_gain_pct": to["min_gain_pct"],
        "sched_overhead_min_gain_pct": so["min_gain_pct"],
        "dag_pipeline_min_gain_pct": dag["min_gain_pct"],
        "fleet_slo_min_attainment": fleet["min_attainment"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--session-reuse", required=True)
    ap.add_argument("--offload-modes", required=True)
    ap.add_argument("--transfer-overlap", required=True)
    ap.add_argument("--sched-overhead", required=True)
    ap.add_argument("--dag-pipeline", required=True)
    ap.add_argument("--fleet-slo", required=True)
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--out", default="BENCH_PR.json")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional regression vs baseline")
    args = ap.parse_args(argv)

    raw = {}
    for key, path in (("session_reuse", args.session_reuse),
                      ("offload_modes", args.offload_modes),
                      ("transfer_overlap", args.transfer_overlap),
                      ("sched_overhead", args.sched_overhead),
                      ("dag_pipeline", args.dag_pipeline),
                      ("fleet_slo", args.fleet_slo)):
        raw[key] = json.loads(pathlib.Path(path).read_text())
    baseline = json.loads(pathlib.Path(args.baseline).read_text())

    metrics = headline_metrics(raw["session_reuse"], raw["offload_modes"],
                               raw["transfer_overlap"],
                               raw["sched_overhead"],
                               raw["dag_pipeline"],
                               raw["fleet_slo"])
    failures = []
    for name, base in baseline["metrics"].items():
        if name not in metrics:
            failures.append(f"{name}: missing from merged results")
            continue
        floor = base * (1.0 - args.tolerance)
        got = metrics[name]
        status = "ok" if got >= floor else "REGRESSED"
        print(f"{name:36s} {got:8.2f} vs baseline {base:8.2f} "
              f"(floor {floor:8.2f}) {status}")
        if got < floor:
            failures.append(
                f"{name}: {got:.2f} < floor {floor:.2f} "
                f"(baseline {base:.2f}, tolerance {args.tolerance:.0%})")
    for key in raw:
        if not raw[key].get("ok", False):
            failures.append(f"{key}: its own acceptance check failed")

    merged = {
        "metrics": metrics,
        "baseline": baseline["metrics"],
        "tolerance": args.tolerance,
        "pass": not failures,
        "failures": failures,
        "raw": raw,
    }
    pathlib.Path(args.out).write_text(json.dumps(merged, indent=2))
    print(f"wrote {args.out}")
    if failures:
        print("\nbench-trend gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench-trend gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
