"""Binary vs ROI offload modes (the paper's headline asymmetry).

The paper's optimizations improve **binary** offloading (init -> offload ->
teardown per run) by 7.5% but **ROI** offloading (repeated sub-region
submits against a persistent, buffer-registered workload) by 17.4% —
because ROI mode amortizes the fixed management costs the binary contract
pays every run.  This bench reproduces the gap on the real threaded engine
with the tiered API's offload modes:

  * BINARY: ``session.submit(prog, region=roi, mode=OffloadMode.BINARY)``
    per iteration — executables built fresh (paying the emulated ~131
    ms/device driver-primitive cost), state evicted after.
  * ROI: ``session.register_workload(prog)`` once, then the same region
    submitted with ``mode=OffloadMode.ROI`` per iteration — warm.

Both modes run the *same* 2-D region of the same image kernel, so the gap
is purely the management overhead the phase breakdown itemizes.

Also round-trips a 2-D region through EVERY registered scheduler (the
acceptance check for row-panel carving): exact output vs the oracle and
exact-cover tiling of the carved region.

Usage:
  PYTHONPATH=src:. python benchmarks/offload_modes.py [--smoke] [--json F]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.api import EngineSession, OffloadMode, Region, available_schedulers, coexec
from repro.core import programs as P
from repro.core.device import DeviceGroup

INIT_COST_S = 0.131          # paper §V-B: ~131 ms fixed init cost
PAPER_ROI_GAIN_PCT = 17.4    # paper's ROI-mode improvement (binary: 7.5%)


def make_devices():
    return [DeviceGroup("cpu", throttle=4.0),
            DeviceGroup("igpu", throttle=2.0),
            DeviceGroup("gpu", throttle=1.0)]


def binary_vs_roi(kernel: str, h: int, w: int, roi_frac: float,
                  reps: int) -> dict:
    """Mean per-submit response of BINARY vs warm-ROI submits of the SAME
    centered sub-region of one image workload."""
    prog = P.PROGRAMS[kernel](h=h, w=w) if kernel == "gaussian2d" \
        else P.PROGRAMS[kernel](px=h)
    full = prog.work_region
    l0, l1 = (d.lws for d in full.dims)
    rows = max(l0, int(full.dims[0].size * roi_frac) // l0 * l0)
    cols = max(l1, int(full.dims[1].size * roi_frac) // l1 * l1)
    r0 = (full.dims[0].size - rows) // 2 // l0 * l0
    c0 = (full.dims[1].size - cols) // 2 // l1 * l1
    roi = Region.rect(rows, cols, lws=(l0, l1), offset=(r0, c0))
    ref = P.reference_output(kernel, h=h, w=w) if kernel == "gaussian2d" \
        else P.reference_output(kernel, px=h)
    ref_roi = ref[r0:r0 + rows, c0 * prog.out_cols:(c0 + cols) * prog.out_cols]

    # fixed equal-chunk carving pins the packet (tile) shapes: repeated
    # offloads re-launch the SAME compiled executables, as the paper's ROI
    # loop does — an adaptive carve would re-specialize XLA tiles per run
    # and the noise would masquerade as management overhead
    skw = dict(scheduler="dynamic", scheduler_kwargs={"n_packets": 6})
    with EngineSession(make_devices(), init_cost_s=INIT_COST_S) as session:
        # register the persistent workload: init (compile + buffer
        # registration) is paid HERE, once — the ROI loop runs warm
        t_reg = time.perf_counter()
        session.register_workload(prog)
        register_s = time.perf_counter() - t_reg
        # one untimed warm-up pins the tile's compiled shape for BOTH modes
        session.submit(prog, region=roi, mode=OffloadMode.ROI,
                       **skw).result()

        roi_times, roi_rois = [], []
        exact = True
        for _ in range(reps):
            r = session.submit(prog, region=roi, mode=OffloadMode.ROI,
                               **skw).result()
            roi_times.append(r.phases.binary)
            roi_rois.append(r.phases.roi_s)
            exact = exact and np.allclose(r.output, ref_roi,
                                          rtol=1e-5, atol=1e-5)

        # the BINARY loop runs against an UNREGISTERED session (a BINARY
        # submit of a registered workload is refused — its teardown would
        # de-warm the ROI contract)
        session.unregister_workload(prog.name)
        bin_times, bin_inits = [], []
        for _ in range(reps):
            r = session.submit(prog, region=roi, mode=OffloadMode.BINARY,
                               **skw).result()
            bin_times.append(r.phases.binary)
            bin_inits.append(r.phases.init_s)
            exact = exact and np.allclose(r.output, ref_roi,
                                          rtol=1e-5, atol=1e-5)

    binary_mean = sum(bin_times) / len(bin_times)
    roi_mean = sum(roi_times) / len(roi_times)
    gap = 100.0 * (binary_mean - roi_mean) / binary_mean
    return {
        "kernel": kernel, "region": repr(roi), "reps": reps,
        "binary_mean_s": binary_mean, "roi_mean_s": roi_mean,
        "binary_init_mean_s": sum(bin_inits) / len(bin_inits),
        "roi_kernel_mean_s": sum(roi_rois) / len(roi_rois),
        "register_s": register_s,
        "gap_pct": gap, "floor_pct": PAPER_ROI_GAIN_PCT,
        "exact": bool(exact),
        "ok": bool(exact and gap >= PAPER_ROI_GAIN_PCT),
    }


def scheduler_roundtrip(h: int, w: int) -> dict:
    """Every registered scheduler must carve a 2-D region as row panels
    that tile it exactly once (lws-aligned), with exact output."""
    ref = P.reference_output("gaussian2d", h=h, w=w)
    out = {}
    for name in available_schedulers():
        prog = P.PROGRAMS["gaussian2d"](h=h, w=w)
        res = coexec(prog, make_devices(), scheduler=name)
        region = prog.work_region
        panels = sorted(p.region.dims[0].offset for p in res.packets)
        spans = sorted((p.region.dims[0].offset, p.region.dims[0].end)
                       for p in res.packets)
        cover = spans and spans[0][0] == region.dims[0].offset
        pos = region.dims[0].offset
        for a, b in spans:
            cover = cover and a == pos
            pos = b
        cover = cover and pos == region.dims[0].end
        full_width = all(p.region.dims[1] == region.dims[1]
                         for p in res.packets)
        aligned = all(p.region.aligned_within(region) for p in res.packets)
        exact = np.allclose(res.output, ref, rtol=1e-5, atol=1e-5)
        out[name] = {"packets": len(res.packets), "exact_cover": bool(cover),
                     "full_width": bool(full_width), "aligned": bool(aligned),
                     "exact_output": bool(exact),
                     "ok": bool(cover and full_width and aligned and exact),
                     "first_panel_rows": panels[:4]}
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps (CI)")
    ap.add_argument("--json", default=None, help="write results JSON here")
    # parse_known_args: benchmarks.run drives every bench's main() with the
    # driver's own argv still in place
    args, _ = ap.parse_known_args(argv)

    t0 = time.time()
    h = w = 256 if args.smoke else 512
    reps = 3 if args.smoke else 5

    print(f"{'kernel':14s}{'binary_ms':>11s}{'roi_ms':>9s}{'gap_%':>8s}"
          f"{'floor_%':>9s}{'exact':>7s}")
    sweeps = []
    kernels = ["gaussian2d"] if args.smoke else ["gaussian2d",
                                                 "mandelbrot2d"]
    for kernel in kernels:
        rec = binary_vs_roi(kernel, h, w, roi_frac=0.5, reps=reps)
        sweeps.append(rec)
        print(f"{kernel:14s}{rec['binary_mean_s']*1e3:11.1f}"
              f"{rec['roi_mean_s']*1e3:9.1f}{rec['gap_pct']:8.1f}"
              f"{PAPER_ROI_GAIN_PCT:9.1f}{str(rec['exact']):>7s}")

    print("\n2-D region round-trip (row-panel carving, every scheduler):")
    rt = scheduler_roundtrip(128, 96)
    for name, rec in sorted(rt.items()):
        print(f"  {name:18s} packets={rec['packets']:3d} "
              f"cover={rec['exact_cover']} width={rec['full_width']} "
              f"aligned={rec['aligned']} exact={rec['exact_output']}")

    ok = (all(r["ok"] for r in sweeps)
          and all(r["ok"] for r in rt.values()))
    best = max(r["gap_pct"] for r in sweeps)
    print(f"\nbest binary->ROI gap {best:.1f}% "
          f"(paper ROI-mode floor: {PAPER_ROI_GAIN_PCT}%); "
          f"round-trip ok={all(r['ok'] for r in rt.values())}")

    payload = {"sweeps": sweeps, "roundtrip": rt, "ok": ok,
               "smoke": args.smoke}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")

    from benchmarks import common
    print(common.csv_line("offload_modes", (time.time() - t0) * 1e6,
                          f"best_gap={best:.1f}%;"
                          f"floor={PAPER_ROI_GAIN_PCT}%;ok={ok}"))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
